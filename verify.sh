#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + full test suite.
# Artifact-gated integration tests (PJRT execution) skip themselves when
# artifacts/ is absent; everything else must pass.
set -euo pipefail
cd "$(dirname "$0")/rust"
cargo build --release
cargo test -q
# Non-default scan execution plans: re-run the scan suite with the
# planner forced to each alternate strategy (the GSPN2_SCAN_PLAN env
# override behind the `scan.plan` config knob). `segment` forces the
# segmented strategy *with the per-direction wavefront schedule and the
# fused-correction drain* — the production phase-2 path — as the
# default decision on every geometry wide enough to segment, so that
# path (not just its dedicated tests) carries the whole scan suite.
GSPN2_SCAN_PLAN=segment cargo test -q scan
GSPN2_SCAN_PLAN=dirfan cargo test -q scan
# `chained` forces the single-pass chained engine (decoupled look-back,
# no phase barrier) on every geometry wide enough to chunk — the
# production low-occupancy path, bit-identical to `segment` at the same
# count — so the whole scan suite runs through its state machine.
GSPN2_SCAN_PLAN=chained cargo test -q scan
# `tiled` forces the row-band streaming mode (every pooled scan runs as
# a stream of band tiles joined through serialized External carries,
# peak workspace bounded by one band) with the planner picking each
# band's inner strategy; `tiled-chained` pins the chained engine inside
# every band, compounding the two carry machines — both bit-identical
# to the monolithic plans, so the whole scan suite rides through the
# band-boundary carry hand-off.
GSPN2_SCAN_PLAN=tiled cargo test -q scan
GSPN2_SCAN_PLAN=tiled-chained cargo test -q scan
# SIMD kernel matrix: the scan suite is `==`-pinned against the scalar
# reference, so re-run it with the lane kernels forced off (every inner
# loop through the scalar path) and — where the host supports it — with
# the vector kernel forced on, exercising the GSPN2_SCAN_SIMD override
# behind the `scan.simd` config knob.
GSPN2_SCAN_SIMD=scalar cargo test -q scan
if [ "$(uname -m)" = "x86_64" ]; then
  GSPN2_SCAN_SIMD=avx2 cargo test -q scan
elif [ "$(uname -m)" = "aarch64" ]; then
  GSPN2_SCAN_SIMD=neon cargo test -q scan
fi
# Overload robustness: the SLO-aware admission / shedding / drain e2e
# suite, re-run explicitly so a change that only breaks the overload
# path can't hide behind the broad suite's pass/fail summary.
cargo test -q --test coordinator_e2e overload
