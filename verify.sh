#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + full test suite.
# Artifact-gated integration tests (PJRT execution) skip themselves when
# artifacts/ is absent; everything else must pass.
set -euo pipefail
cd "$(dirname "$0")/rust"
cargo build --release
cargo test -q
# Non-default scan execution plans: re-run the scan suite with the
# planner forced to each alternate strategy (the GSPN2_SCAN_PLAN env
# override behind the `scan.plan` config knob), so the segmented and
# direction-fan paths are exercised as the *default* decision on every
# push, not only where their dedicated tests force them.
GSPN2_SCAN_PLAN=segment cargo test -q scan
GSPN2_SCAN_PLAN=dirfan cargo test -q scan
