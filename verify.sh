#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + full test suite.
# Artifact-gated integration tests (PJRT execution) skip themselves when
# artifacts/ is absent; everything else must pass.
set -euo pipefail
cd "$(dirname "$0")/rust"
cargo build --release
cargo test -q
