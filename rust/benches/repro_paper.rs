//! The aggregate reproduction bench: regenerates EVERY table and figure
//! of the paper's evaluation into `bench_out/` (the same drivers as
//! `gspn2 repro all`), timing each one. Training-backed proxies run with
//! a small step budget here; use `gspn2 repro proxy2 --proxy-steps 300`
//! for the full-length run recorded in EXPERIMENTS.md.

use gspn2::gpusim::DeviceSpec;
use gspn2::repro;
use gspn2::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("repro_paper");
    let dev = DeviceSpec::a100_sxm4_80gb();
    let out = std::env::var("GSPN2_BENCH_OUT").unwrap_or_else(|_| "bench_out".into());
    let proxy_steps = std::env::var("GSPN2_PROXY_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    for id in repro::ALL {
        let t0 = std::time::Instant::now();
        match repro::run(id, &dev, &out, proxy_steps) {
            Ok(()) => {
                suite.record_value(
                    &format!("repro {id}"),
                    t0.elapsed().as_secs_f64() * 1e3,
                    "ms (driver wall time)",
                );
            }
            Err(e) => {
                eprintln!("repro {id} FAILED: {e:#}");
                suite.record_value(&format!("repro {id} FAILED"), -1.0, "");
            }
        }
    }
    suite.finish();
    println!("\nall paper tables/figures regenerated under {out}/");
}
