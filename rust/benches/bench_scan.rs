//! Micro-benchmarks for the pure-Rust GSPN core: tap normalisation, the
//! canonical scan at several sizes, directional wrappers, the compact
//! unit, and the Eq. 4 dense expansion — plus the fused-vs-reference
//! comparison suite (`BENCH_scan`), the perf-trajectory record for the
//! column-staged fused engine.
//!
//! Run: `cargo bench --bench bench_scan` (results land in bench_out/).
//! `GSPN2_BENCH_SMOKE=1` runs only the fused-vs-reference suite with a
//! short measurement budget — the CI mode that keeps
//! `bench_out/BENCH_scan.json` accumulating on every push.

use std::time::Duration;

use gspn2::scan::fused::{
    fused_merged_4dir, fused_merged_4dir_chained, fused_merged_4dir_fan, fused_merged_4dir_pool,
    fused_merged_4dir_seg_wave_twopass, fused_scan_l2r, fused_scan_l2r_chained,
    fused_scan_l2r_pool, fused_scan_l2r_pool_ws, fused_scan_l2r_seg, fused_scan_l2r_seg_wave,
    fused_scan_l2r_seg_wave_twopass,
};
use gspn2::scan::plan::set_plan_override;
use gspn2::util::BufferPool;
use gspn2::scan::{
    auto_segments, expand_g, merged_4dir_pool, merged_4dir_ref, scan_l2r, scan_l2r_pool,
    scan_l2r_split, simd, CompactGspnUnit, Taps,
};
use gspn2::util::bench::{black_box, BenchConfig, BenchSuite};
use gspn2::util::{Rng, ThreadPool};
use gspn2::Tensor;

/// The acceptance suite: reference vs fused rows at the two pinned
/// geometries (c64 64x64 and c8 256x256), written to
/// `bench_out/BENCH_scan.json`. Speedup rows make the trajectory
/// greppable without post-processing.
fn bench_fused_vs_reference(cfg: BenchConfig) {
    let mut suite = BenchSuite::with_config("BENCH_scan", cfg);
    // Host header: which lane kernel this run's rows were measured
    // under (and what the host exposes), so SIMD rows are
    // interpretable across runners.
    suite.stamp_host("simd", simd::kernel().name().into());
    suite.stamp_host("simd_lanes", simd::lanes().into());
    suite.stamp_host("features", simd::detected_features().into());
    let mut rng = Rng::new(7);
    let pool = ThreadPool::global();

    for (c, h, w) in [(64usize, 64usize, 64usize), (8, 256, 256)] {
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let taps = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));

        let r_ref = suite.bench(&format!("scan_l2r c{c} {h}x{w} (reference)"), || {
            black_box(scan_l2r(&x, &taps, &lam, 0));
        });
        let r_fused = suite.bench(&format!("scan_l2r c{c} {h}x{w} (fused)"), || {
            black_box(fused_scan_l2r(&x, &taps, &lam, 0));
        });
        let r_fused_pool =
            suite.bench(&format!("scan_l2r c{c} {h}x{w} (fused pool)"), || {
                black_box(fused_scan_l2r_pool(&x, &taps, &lam, 0, pool));
            });
        suite.record_value(
            &format!("speedup scan_l2r c{c} {h}x{w} fused/ref"),
            r_ref.mean_ns / r_fused.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("speedup scan_l2r c{c} {h}x{w} fused-pool/ref"),
            r_ref.mean_ns / r_fused_pool.mean_ns,
            "x",
        );

        let t_tb = Taps::normalize(&Tensor::randn(&[1, 1, 3, w, h], &mut rng, 1.0));
        let tr = [&taps, &taps, &t_tb, &t_tb];
        let logits = [0.3f32, -0.1, 0.6, 0.0];
        let m_ref = suite.bench(&format!("merged_4dir c{c} {h}x{w} (reference)"), || {
            black_box(merged_4dir_ref(&x, tr, &lam, &logits, 0));
        });
        let m_fused = suite.bench(&format!("merged_4dir c{c} {h}x{w} (fused)"), || {
            black_box(fused_merged_4dir(&x, tr, &lam, &logits, 0));
        });
        let m_fused_pool =
            suite.bench(&format!("merged_4dir c{c} {h}x{w} (fused pool)"), || {
                black_box(fused_merged_4dir_pool(&x, tr, &lam, &logits, 0, pool));
            });
        suite.record_value(
            &format!("speedup merged_4dir c{c} {h}x{w} fused/ref"),
            m_ref.mean_ns / m_fused.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("speedup merged_4dir c{c} {h}x{w} fused-pool/ref"),
            m_ref.mean_ns / m_fused_pool.mean_ns,
            "x",
        );
    }

    // Low-occupancy geometries (the §5.1 regime): few planes, huge H·W.
    // The "plane" row runs the PR 2 engine at its effective parallelism
    // cap — plane-parallel work cannot use more threads than planes, so
    // an nplanes-thread pool measures exactly what the old engine does
    // on any wider pool. The "auto" rows let the occupancy scheduler
    // segment on an 8-thread pool (the acceptance configuration) and on
    // the host-sized global pool (what serving actually gets here).
    for (n, c, h, w) in [(1usize, 4usize, 512usize, 512usize), (1, 1, 1024, 1024)] {
        let nplanes = n * c;
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = Taps::normalize(&Tensor::randn(&[n, 1, 3, h, w], &mut rng, 1.0));
        let plane_pool = ThreadPool::new(nplanes);
        let seg_pool = ThreadPool::new(8);
        let tag = format!("n{n}c{c} {h}x{w}");

        let r_plane = suite.bench(&format!("scan_l2r {tag} (fused plane, PR2)"), || {
            black_box(fused_scan_l2r_pool(&x, &taps, &lam, 0, &plane_pool));
        });
        let s8 = auto_segments(nplanes, w, seg_pool.threads()).unwrap_or(1);
        let r_seg8 = suite.bench(
            &format!("scan_l2r {tag} (fused auto seg={s8}, 8 threads)"),
            || {
                black_box(fused_scan_l2r_pool(&x, &taps, &lam, 0, &seg_pool));
            },
        );
        suite.record_value(
            &format!("speedup scan_l2r {tag} seg8/plane"),
            r_plane.mean_ns / r_seg8.mean_ns,
            "x",
        );
        let gt = pool.threads();
        let sg = auto_segments(nplanes, w, gt).unwrap_or(1);
        let r_seg_host = suite.bench(
            &format!("scan_l2r {tag} (fused auto seg={sg}, {gt} threads host)"),
            || {
                black_box(fused_scan_l2r_pool(&x, &taps, &lam, 0, pool));
            },
        );
        suite.record_value(
            &format!("speedup scan_l2r {tag} host/plane"),
            r_plane.mean_ns / r_seg_host.mean_ns,
            "x",
        );
    }

    // Barrier vs wavefront vs the PR 4 two-pass (the PR 4 and PR 5
    // acceptance rows): the segmented decomposition at n2c2 512x512 on
    // 8 threads — 4 planes, so each plane's phase-2 work has three
    // other planes' phase-1 scans to hide behind. "wavefront" is the
    // production schedule (per-direction continuations, carry
    // correction fused into the scatter drain: the retained panel is
    // read once, never re-written); "two-pass" is the PR 4 schedule
    // (one continuation per plane, correction as a separate in-place
    // panel pass before the drain re-reads it). Exact same bits
    // everywhere; only schedule and memory traffic differ. The
    // fused-drain/two-pass row is the PR 5 acceptance comparison
    // (>= 1.1x at 8 real cores; CI's 4-core runner shows the
    // trajectory).
    {
        let (n, c, h, w) = (2usize, 2usize, 512usize, 512usize);
        let nplanes = n * c;
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = Taps::normalize(&Tensor::randn(&[n, 1, 3, h, w], &mut rng, 1.0));
        let pool8 = ThreadPool::new(8);
        let s = auto_segments(nplanes, w, pool8.threads()).unwrap_or(2);
        let tag = format!("n{n}c{c} {h}x{w}");
        let r_barrier = suite.bench(
            &format!("scan_l2r {tag} (seg={s} barrier, 8 threads)"),
            || {
                black_box(fused_scan_l2r_seg(&x, &taps, &lam, 0, s, &pool8));
            },
        );
        let r_twopass = suite.bench(
            &format!("scan_l2r {tag} (seg={s} PR4 two-pass wavefront, 8 threads)"),
            || {
                black_box(fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, 0, s, &pool8));
            },
        );
        let r_wave = suite.bench(
            &format!("scan_l2r {tag} (seg={s} fused-drain wavefront, 8 threads)"),
            || {
                black_box(fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, s, &pool8));
            },
        );
        suite.record_value(
            &format!("speedup scan_l2r {tag} wavefront/barrier"),
            r_barrier.mean_ns / r_wave.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("speedup scan_l2r {tag} fused-drain/two-pass"),
            r_twopass.mean_ns / r_wave.mean_ns,
            "x",
        );
        // The PR 8 acceptance row: the single-pass chained engine
        // (decoupled look-back — no phase barrier, no retained-panel
        // array, no second panel read) vs the PR 5 fused-drain
        // wavefront, same bits, same chunk count. Target >= 1.15x at 8
        // real cores; CI's runner shows the trajectory.
        let r_chained = suite.bench(
            &format!("scan_l2r {tag} (seg={s} chained single-pass, 8 threads)"),
            || {
                black_box(fused_scan_l2r_chained(&x, &taps, &lam, 0, s, &pool8));
            },
        );
        suite.record_value(
            &format!("speedup scan_l2r {tag} chained/fused-drain"),
            r_wave.mean_ns / r_chained.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("speedup scan_l2r {tag} chained/barrier"),
            r_barrier.mean_ns / r_chained.mean_ns,
            "x",
        );
        // The SIMD acceptance rows: the same chained pass with the lane
        // kernels forced off (every inner loop through the pinned scalar
        // reference — same bits, no vector issue). The detected-kernel
        // row above is `r_chained`; the ratio is the measured lane win
        // on this host. Safe to flip process-globally here: the bench
        // binary is one thread of control and scalar vs vector is
        // bit-identical anyway.
        let kern = simd::kernel();
        simd::set_simd_override("scalar").unwrap();
        let r_chained_scalar = suite.bench(
            &format!("scan_l2r {tag} (seg={s} chained, forced scalar, 8 threads)"),
            || {
                black_box(fused_scan_l2r_chained(&x, &taps, &lam, 0, s, &pool8));
            },
        );
        simd::set_simd_override("auto").unwrap();
        suite.record_value(
            &format!("speedup scan_l2r {tag} chained {}/scalar", kern.name()),
            r_chained_scalar.mean_ns / r_chained.mean_ns,
            "x",
        );
        // The bf16 panel-mode rows: same chained pass with staged taps
        // and job-local panels stored as bf16 words (recurrence and
        // carries stay f32). Process-global is safe for the same
        // single-threaded reason; restored to the exact f32 default
        // before the next block.
        simd::set_precision_override("bf16").unwrap();
        let r_chained_bf16 = suite.bench(
            &format!("scan_l2r {tag} (seg={s} chained, bf16 panels, 8 threads)"),
            || {
                black_box(fused_scan_l2r_chained(&x, &taps, &lam, 0, s, &pool8));
            },
        );
        simd::set_precision_override("f32").unwrap();
        suite.record_value(
            &format!("speedup scan_l2r {tag} chained bf16/f32"),
            r_chained.mean_ns / r_chained_bf16.mean_ns,
            "x",
        );
    }

    // Mid-occupancy direction fan (the regime that previously neither
    // segmented nor fanned): a 4-direction merged pass with 2 planes on
    // 8 threads. The "plane" row caps effective parallelism at nplanes
    // threads (what the plane path achieves on any wider pool); the fan
    // rows run the per-(plane, direction) decomposition — bit-identical
    // output, 4x the width — barrier and wavefront.
    {
        let (n, c, h, w) = (1usize, 2usize, 384usize, 384usize);
        let nplanes = n * c;
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = Taps::normalize(&Tensor::randn(&[n, 1, 3, h, w], &mut rng, 1.0));
        let t_tb = Taps::normalize(&Tensor::randn(&[n, 1, 3, w, h], &mut rng, 1.0));
        let tr = [&t_lr, &t_lr, &t_tb, &t_tb];
        let logits = [0.3f32, -0.1, 0.6, 0.0];
        let plane_pool = ThreadPool::new(nplanes);
        let pool8 = ThreadPool::new(8);
        let tag = format!("n{n}c{c} {h}x{w}");
        let m_plane = suite.bench(&format!("merged_4dir {tag} (plane cap)"), || {
            black_box(fused_merged_4dir_pool(&x, tr, &lam, &logits, 0, &plane_pool));
        });
        let m_fan_barrier =
            suite.bench(&format!("merged_4dir {tag} (dirfan barrier, 8 threads)"), || {
                black_box(fused_merged_4dir_fan(&x, tr, &lam, &logits, 0, false, &pool8));
            });
        // The PR 4 single-continuation fan (one two-pass drain per
        // plane; s = 1, so the "two passes" are carry-free — this row
        // isolates the per-direction continuation split).
        let m_fan_twopass = suite.bench(
            &format!("merged_4dir {tag} (dirfan PR4 single-cont, 8 threads)"),
            || {
                black_box(fused_merged_4dir_seg_wave_twopass(
                    &x, tr, &lam, &logits, 0, 1, &pool8,
                ));
            },
        );
        let m_fan_wave = suite.bench(
            &format!("merged_4dir {tag} (dirfan per-dir wavefront, 8 threads)"),
            || {
                black_box(fused_merged_4dir_fan(&x, tr, &lam, &logits, 0, true, &pool8));
            },
        );
        suite.record_value(
            &format!("speedup merged_4dir {tag} dirfan/plane"),
            m_plane.mean_ns / m_fan_wave.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("speedup merged_4dir {tag} dirfan wavefront/barrier"),
            m_fan_barrier.mean_ns / m_fan_wave.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("speedup merged_4dir {tag} per-dir/PR4 single-cont"),
            m_fan_twopass.mean_ns / m_fan_wave.mean_ns,
            "x",
        );
        // The chained engine in the dirfan band: per-direction chunk
        // chains at the forced count (what `scan.plan = chained` runs
        // here), against the production per-direction wavefront fan.
        let sc = auto_segments(nplanes, w.min(h), pool8.threads()).unwrap_or(2);
        let m_chained = suite.bench(
            &format!("merged_4dir {tag} (chained seg={sc}, 8 threads)"),
            || {
                black_box(fused_merged_4dir_chained(&x, tr, &lam, &logits, 0, sc, &pool8));
            },
        );
        suite.record_value(
            &format!("speedup merged_4dir {tag} chained/dirfan-wavefront"),
            m_fan_wave.mean_ns / m_chained.mean_ns,
            "x",
        );
        // SIMD acceptance rows in the dirfan band: the production
        // per-direction wavefront fan with the lane kernels forced off.
        let kern = simd::kernel();
        simd::set_simd_override("scalar").unwrap();
        let m_fan_scalar = suite.bench(
            &format!("merged_4dir {tag} (dirfan wavefront, forced scalar, 8 threads)"),
            || {
                black_box(fused_merged_4dir_fan(&x, tr, &lam, &logits, 0, true, &pool8));
            },
        );
        simd::set_simd_override("auto").unwrap();
        suite.record_value(
            &format!("speedup merged_4dir {tag} dirfan {}/scalar", kern.name()),
            m_fan_scalar.mean_ns / m_fan_wave.mean_ns,
            "x",
        );
    }

    // Bounded-memory tiled streaming at high resolution (the PR 10
    // acceptance rows): one 2048x2048 plane, a fresh workspace pool
    // per mode so each mode's `peak_leased` high-water mark is its own,
    // recorded alongside latency. The plan override is forced so the
    // untiled row can never auto-tile (the pool cap is generous enough
    // that the tiling guard would stay quiet anyway) and the tiled row
    // streams the same chained engine through row bands joined by
    // serialized External carries. Same bits; acceptance is the memory
    // row — tiled peak bytes on lease <= 1/2 untiled. Process-global
    // override is safe here for the same single-thread-of-control
    // reason as the SIMD flips above.
    {
        let (c, h, w) = (1usize, 2048usize, 2048usize);
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let taps = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let pool8 = ThreadPool::new(8);
        let tag = format!("c{c} {h}x{w}");
        set_plan_override("chained").unwrap();
        let ws_untiled = BufferPool::new(512 << 20);
        let r_untiled = suite.bench(
            &format!("scan_l2r {tag} (untiled chained, 8 threads)"),
            || {
                black_box(fused_scan_l2r_pool_ws(&x, &taps, &lam, 0, &pool8, &ws_untiled));
            },
        );
        let untiled_peak = ws_untiled.stats().peak_leased;
        set_plan_override("tiled-chained").unwrap();
        let ws_tiled = BufferPool::new(512 << 20);
        let r_tiled = suite.bench(
            &format!("scan_l2r {tag} (tiled-chained stream, 8 threads)"),
            || {
                black_box(fused_scan_l2r_pool_ws(&x, &taps, &lam, 0, &pool8, &ws_tiled));
            },
        );
        set_plan_override("auto").unwrap();
        let tiled_peak = ws_tiled.stats().peak_leased;
        suite.record_value(
            &format!("speedup scan_l2r {tag} tiled/untiled"),
            r_untiled.mean_ns / r_tiled.mean_ns,
            "x",
        );
        suite.record_value(
            &format!("peak bytes_leased scan_l2r {tag} untiled"),
            untiled_peak as f64,
            "B",
        );
        suite.record_value(
            &format!("peak bytes_leased scan_l2r {tag} tiled"),
            tiled_peak as f64,
            "B",
        );
        suite.record_value(
            &format!("mem shrink scan_l2r {tag} untiled/tiled"),
            untiled_peak as f64 / tiled_peak.max(1) as f64,
            "x",
        );
    }

    suite.finish();
}

fn main() {
    // Smoke mode (CI): only the fused-vs-reference acceptance suite,
    // short measurement windows.
    if std::env::var("GSPN2_BENCH_SMOKE").is_ok() {
        bench_fused_vs_reference(BenchConfig {
            warmup: Duration::from_millis(40),
            measure: Duration::from_millis(250),
            min_samples: 5,
            max_samples: 200,
        });
        return;
    }

    let mut suite = BenchSuite::new("scan_core");
    let mut rng = Rng::new(0);

    // Tap normalisation.
    let raw = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
    suite.bench("normalize_taps 64x64 shared", || {
        black_box(Taps::normalize(&raw));
    });
    let raw_pc = Tensor::randn(&[1, 8, 3, 64, 64], &mut rng, 1.0);
    suite.bench("normalize_taps 64x64 per-channel c8", || {
        black_box(Taps::normalize(&raw_pc));
    });

    // Canonical scan across sizes: reference vs the column-staged fused
    // engine, serial.
    for (c, h, w) in [(8usize, 64usize, 64usize), (8, 128, 128), (8, 256, 256), (64, 64, 64)] {
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        suite.bench(&format!("scan_l2r c{c} {h}x{w}"), || {
            black_box(scan_l2r(&x, &a, &lam, 0));
        });
        suite.bench(&format!("scan_l2r c{c} {h}x{w} (fused)"), || {
            black_box(fused_scan_l2r(&x, &a, &lam, 0));
        });
    }

    // Shared-pool fan-out vs the serial plane loop above: the reference
    // pool path submits one job per plane; the fused path submits
    // block-granular jobs sized off the pool.
    {
        let pool = ThreadPool::global();
        for (c, h, w) in [(8usize, 128usize, 128usize), (64, 64, 64)] {
            let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
            let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
            let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
            suite.bench(
                &format!("scan_l2r c{c} {h}x{w} (shared pool, {} workers)", pool.threads()),
                || {
                    black_box(scan_l2r_pool(&x, &a, &lam, 0, pool));
                },
            );
            suite.bench(&format!("scan_l2r c{c} {h}x{w} (fused pool)"), || {
                black_box(fused_scan_l2r_pool(&x, &a, &lam, 0, pool));
            });
        }
    }

    // Chunked (GSPN-local) variant.
    {
        let x = Tensor::randn(&[1, 8, 128, 128], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, 128, 128], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, 8, 128, 128], &mut rng, 1.0);
        suite.bench("scan_l2r c8 128x128 kchunk=16", || {
            black_box(scan_l2r(&x, &a, &lam, 16));
        });
        suite.bench("scan_l2r c8 128x128 kchunk=16 (fused)", || {
            black_box(fused_scan_l2r(&x, &a, &lam, 16));
        });
    }

    // Segment-parallel decomposition (the §5.1 extension), now served by
    // the fused engine: the unfused scan_l2r_split rows stay as the
    // bit-identity reference; production callers route through the
    // fused scheduler (`fused auto` row) or the forced-segment hook.
    {
        let (c, h, w) = (1usize, 256usize, 256usize);
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        suite.bench("scan_l2r c1 256x256 (sequential)", || {
            black_box(scan_l2r(&x, &a, &lam, 0));
        });
        suite.bench("scan_split c1 256x256 seg=8 t=1 (unfused ref)", || {
            black_box(scan_l2r_split(&x, &a, &lam, 8, 1));
        });
        // threads > 1 bounds the job count submitted to the shared pool.
        let t = ThreadPool::global().threads().clamp(2, 8);
        suite.bench(&format!("scan_split c1 256x256 seg=8 t={t} (unfused ref)"), || {
            black_box(scan_l2r_split(&x, &a, &lam, 8, t));
        });
        let pool = ThreadPool::global();
        suite.bench("scan_l2r c1 256x256 seg=8 (fused segmented)", || {
            black_box(fused_scan_l2r_seg(&x, &a, &lam, 0, 8, pool));
        });
        suite.bench("scan_l2r c1 256x256 (fused auto)", || {
            black_box(fused_scan_l2r_pool(&x, &a, &lam, 0, pool));
        });
    }

    // Four directions merged: the serial reference composition vs the
    // fused engine, serial and block-pooled.
    {
        let x = Tensor::randn(&[1, 4, 64, 64], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 4, 64, 64], &mut rng, 1.0);
        let t_lr = Taps::normalize(&Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0));
        let t_tb = Taps::normalize(&Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0));
        suite.bench("merged_4dir c4 64x64 (reference)", || {
            black_box(merged_4dir_ref(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0));
        });
        suite.bench("merged_4dir c4 64x64 (fused)", || {
            black_box(fused_merged_4dir(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0));
        });
        let pool = ThreadPool::global();
        suite.bench("merged_4dir c4 64x64 (fused pool)", || {
            black_box(merged_4dir_pool(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0, pool));
        });
    }

    // The full compact unit (projections + 4 scans), now through the
    // fused scan+merge+modulate path and the parallel projections.
    {
        let unit = CompactGspnUnit::init(&mut rng, 32, 4, 0, false);
        let x = Tensor::randn(&[1, 32, 64, 64], &mut rng, 1.0);
        suite.bench("CompactGspnUnit c32 p4 64x64 (fused)", || {
            black_box(unit.forward(&x));
        });
        suite.bench("CompactGspnUnit c32 p4 64x64 (reference)", || {
            black_box(unit.forward_ref(&x));
        });
    }

    // Eq. 4 dense expansion (validation-path cost).
    {
        let taps = Taps::normalize(&Tensor::randn(&[1, 1, 3, 8, 8], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, 1, 8, 8], &mut rng, 1.0);
        suite.bench("expand_g 8x8", || {
            black_box(expand_g(&taps, &lam, 0, 0));
        });
    }

    suite.finish();

    // The acceptance suite, full measurement budget.
    bench_fused_vs_reference(BenchConfig::default());
}
