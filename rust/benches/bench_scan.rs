//! Micro-benchmarks for the pure-Rust GSPN core: tap normalisation, the
//! canonical scan at several sizes, directional wrappers, the compact
//! unit, and the Eq. 4 dense expansion.
//!
//! Run: `cargo bench --bench bench_scan` (results land in bench_out/).

use gspn2::scan::{expand_g, merged_4dir, scan_l2r, scan_l2r_split, CompactGspnUnit, Taps};
use gspn2::util::bench::{black_box, BenchSuite};
use gspn2::util::Rng;
use gspn2::Tensor;

fn main() {
    let mut suite = BenchSuite::new("scan_core");
    let mut rng = Rng::new(0);

    // Tap normalisation.
    let raw = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
    suite.bench("normalize_taps 64x64 shared", || {
        black_box(Taps::normalize(&raw));
    });
    let raw_pc = Tensor::randn(&[1, 8, 3, 64, 64], &mut rng, 1.0);
    suite.bench("normalize_taps 64x64 per-channel c8", || {
        black_box(Taps::normalize(&raw_pc));
    });

    // Canonical scan across sizes.
    for (c, h, w) in [(8usize, 64usize, 64usize), (8, 128, 128), (8, 256, 256), (64, 64, 64)] {
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        suite.bench(&format!("scan_l2r c{c} {h}x{w}"), || {
            black_box(scan_l2r(&x, &a, &lam, 0));
        });
    }

    // Chunked (GSPN-local) variant.
    {
        let x = Tensor::randn(&[1, 8, 128, 128], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, 128, 128], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, 8, 128, 128], &mut rng, 1.0);
        suite.bench("scan_l2r c8 128x128 kchunk=16", || {
            black_box(scan_l2r(&x, &a, &lam, 16));
        });
    }

    // Segment-parallel decomposition (the §5.1 extension): sequential vs
    // split with 1 thread (pure overhead) vs split with host threads.
    {
        let (c, h, w) = (1usize, 256usize, 256usize);
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        suite.bench("scan_l2r c1 256x256 (sequential)", || {
            black_box(scan_l2r(&x, &a, &lam, 0));
        });
        suite.bench("scan_split c1 256x256 seg=8 t=1", || {
            black_box(scan_l2r_split(&x, &a, &lam, 8, 1));
        });
        let t = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
        suite.bench(&format!("scan_split c1 256x256 seg=8 t={t}"), || {
            black_box(scan_l2r_split(&x, &a, &lam, 8, t));
        });
    }

    // Four directions merged.
    {
        let x = Tensor::randn(&[1, 4, 64, 64], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 4, 64, 64], &mut rng, 1.0);
        let t_lr = Taps::normalize(&Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0));
        let t_tb = Taps::normalize(&Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0));
        suite.bench("merged_4dir c4 64x64", || {
            black_box(merged_4dir(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0));
        });
    }

    // The full compact unit (projections + 4 scans).
    {
        let unit = CompactGspnUnit::init(&mut rng, 32, 4, 0, false);
        let x = Tensor::randn(&[1, 32, 64, 64], &mut rng, 1.0);
        suite.bench("CompactGspnUnit c32 p4 64x64", || {
            black_box(unit.forward(&x));
        });
    }

    // Eq. 4 dense expansion (validation-path cost).
    {
        let taps = Taps::normalize(&Tensor::randn(&[1, 1, 3, 8, 8], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, 1, 8, 8], &mut rng, 1.0);
        suite.bench("expand_g 8x8", || {
            black_box(expand_g(&taps, &lam, 0, 0));
        });
    }

    suite.finish();
}
