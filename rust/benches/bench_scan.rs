//! Micro-benchmarks for the pure-Rust GSPN core: tap normalisation, the
//! canonical scan at several sizes, directional wrappers, the compact
//! unit, and the Eq. 4 dense expansion.
//!
//! Run: `cargo bench --bench bench_scan` (results land in bench_out/).

use gspn2::scan::{
    expand_g, merged_4dir, merged_4dir_pool, scan_l2r, scan_l2r_pool, scan_l2r_split,
    CompactGspnUnit, Taps,
};
use gspn2::util::bench::{black_box, BenchSuite};
use gspn2::util::{Rng, ThreadPool};
use gspn2::Tensor;

fn main() {
    let mut suite = BenchSuite::new("scan_core");
    let mut rng = Rng::new(0);

    // Tap normalisation.
    let raw = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
    suite.bench("normalize_taps 64x64 shared", || {
        black_box(Taps::normalize(&raw));
    });
    let raw_pc = Tensor::randn(&[1, 8, 3, 64, 64], &mut rng, 1.0);
    suite.bench("normalize_taps 64x64 per-channel c8", || {
        black_box(Taps::normalize(&raw_pc));
    });

    // Canonical scan across sizes.
    for (c, h, w) in [(8usize, 64usize, 64usize), (8, 128, 128), (8, 256, 256), (64, 64, 64)] {
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        suite.bench(&format!("scan_l2r c{c} {h}x{w}"), || {
            black_box(scan_l2r(&x, &a, &lam, 0));
        });
    }

    // Shared-pool plane fan-out vs the serial plane loop above: the same
    // per-plane kernel (bit-identical output), (N·C)-way parallel on the
    // process-wide pool. Multi-plane inputs are where the pool must win.
    {
        let pool = ThreadPool::global();
        for (c, h, w) in [(8usize, 128usize, 128usize), (64, 64, 64)] {
            let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
            let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
            let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
            suite.bench(
                &format!("scan_l2r c{c} {h}x{w} (shared pool, {} workers)", pool.threads()),
                || {
                    black_box(scan_l2r_pool(&x, &a, &lam, 0, pool));
                },
            );
        }
    }

    // Chunked (GSPN-local) variant.
    {
        let x = Tensor::randn(&[1, 8, 128, 128], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, 128, 128], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, 8, 128, 128], &mut rng, 1.0);
        suite.bench("scan_l2r c8 128x128 kchunk=16", || {
            black_box(scan_l2r(&x, &a, &lam, 16));
        });
    }

    // Segment-parallel decomposition (the §5.1 extension): sequential vs
    // split with 1 thread (pure overhead) vs split on the shared pool
    // (t>1 submits to ThreadPool::global(), no per-call spawns).
    {
        let (c, h, w) = (1usize, 256usize, 256usize);
        let x = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        let a = Taps::normalize(&Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, c, h, w], &mut rng, 1.0);
        suite.bench("scan_l2r c1 256x256 (sequential)", || {
            black_box(scan_l2r(&x, &a, &lam, 0));
        });
        suite.bench("scan_split c1 256x256 seg=8 t=1", || {
            black_box(scan_l2r_split(&x, &a, &lam, 8, 1));
        });
        // threads > 1 bounds the job count submitted to the shared pool.
        let t = ThreadPool::global().threads().clamp(2, 8);
        suite.bench(&format!("scan_split c1 256x256 seg=8 t={t} (pool)"), || {
            black_box(scan_l2r_split(&x, &a, &lam, 8, t));
        });
    }

    // Four directions merged: serial vs the pooled directional fan-out.
    {
        let x = Tensor::randn(&[1, 4, 64, 64], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 4, 64, 64], &mut rng, 1.0);
        let t_lr = Taps::normalize(&Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0));
        let t_tb = Taps::normalize(&Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0));
        suite.bench("merged_4dir c4 64x64", || {
            black_box(merged_4dir(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0));
        });
        let pool = ThreadPool::global();
        suite.bench("merged_4dir c4 64x64 (shared pool)", || {
            black_box(merged_4dir_pool(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0, pool));
        });
    }

    // The full compact unit (projections + 4 scans).
    {
        let unit = CompactGspnUnit::init(&mut rng, 32, 4, 0, false);
        let x = Tensor::randn(&[1, 32, 64, 64], &mut rng, 1.0);
        suite.bench("CompactGspnUnit c32 p4 64x64", || {
            black_box(unit.forward(&x));
        });
    }

    // Eq. 4 dense expansion (validation-path cost).
    {
        let taps = Taps::normalize(&Tensor::randn(&[1, 1, 3, 8, 8], &mut rng, 1.0));
        let lam = Tensor::randn(&[1, 1, 8, 8], &mut rng, 1.0);
        suite.bench("expand_g 8x8", || {
            black_box(expand_g(&taps, &lam, 0, 0));
        });
    }

    suite.finish();
}
