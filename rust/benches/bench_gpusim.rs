//! Simulator benchmarks: raw `simulate()` throughput (the repro pipeline
//! calls it thousands of times in sweeps), the full Fig-3 pipeline, the
//! diffusion model, and the classifier throughput model.

use gspn2::gpusim::{
    attention, simulate, Backend, DeviceSpec, DiffusionModel, KernelConfig, ScanWorkload, FIG3,
};
use gspn2::model;
use gspn2::util::bench::{black_box, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("gpusim");
    let dev = DeviceSpec::a100_sxm4_80gb();

    let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
    let g1 = KernelConfig::gspn1();
    let g2 = KernelConfig::gspn2();
    suite.bench("simulate GSPN-1 (one config)", || {
        black_box(simulate(&dev, &wl, &g1));
    });
    suite.bench("simulate GSPN-2 (one config)", || {
        black_box(simulate(&dev, &wl, &g2));
    });

    suite.bench("pipeline Fig3 (6 stages)", || {
        black_box(FIG3.run(&dev));
    });

    // A full resolution x channel sweep like the Fig-4 driver performs.
    suite.bench("sweep 5 res x 7 ch x 2 kernels", || {
        for res in [128usize, 256, 512, 1024, 2048] {
            for c in [8usize, 32, 64, 128, 256, 512, 1024] {
                let w = ScanWorkload::fwd(4, c, res, res);
                black_box(simulate(&dev, &w, &g1));
                black_box(simulate(&dev, &w, &g2));
            }
        }
    });

    let m = DiffusionModel::sdxl_like();
    suite.bench("diffusion generate_s 4K (gspn2)", || {
        black_box(m.generate_s(&dev, 4096, Backend::Gspn2));
    });
    suite.bench("diffusion generate_s 4K (flash)", || {
        black_box(m.generate_s(&dev, 4096, Backend::SdxlFlash));
    });

    let arch = model::gspn2_tiny();
    suite.bench("classifier_throughput model (tiny)", || {
        black_box(attention::classifier_throughput(&dev, &arch, 224, 64));
    });

    suite.bench("arch cost accounting (tiny @224)", || {
        black_box(arch.cost(224));
    });

    suite.finish();
}
