//! Coordinator benchmarks: the pure batching policy at load, the
//! metrics hot path, trace generation, and — when artifacts exist — the
//! PJRT execute path raw vs through the full serving stack (the
//! "coordinator overhead" number EXPERIMENTS.md §Perf tracks).
//!
//! Always emits `bench_out/BENCH_serve.json` first: trace-driven
//! steady and bursty serving rows (p50/p99/p999/max, throughput,
//! workspace pool hit rate) against the cpu-fused backend — no
//! artifacts required. `GSPN2_BENCH_SMOKE=1` runs only that suite with
//! a short trace, the CI mode that keeps BENCH_serve.json accumulating
//! next to BENCH_scan.json on every push.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use gspn2::config::ServeConfig;
use gspn2::coordinator::{
    generate_trace, BatchPolicy, Batcher, Bucket, BurstConfig, ClassMix, Coordinator,
    Metrics, Payload, Priority, Request, SubmitError, SubmitOptions, TraceConfig,
};
use gspn2::runtime::{artifacts_available, Engine, Value};
use gspn2::tensor::concat_axis0;
use gspn2::util::bench::{black_box, BenchSuite};
use gspn2::util::{Rng, ThreadPool};
use gspn2::Tensor;

fn bucket() -> Bucket {
    Bucket { c: 8, h: 64, w: 64, kchunk: 0, per_channel: false }
}

fn mk_req(id: u64, tx: &mpsc::Sender<gspn2::coordinator::Response>) -> Request {
    Request {
        id,
        payload: Payload::Scan {
            x: Tensor::zeros(&[1, 8, 64, 64]),
            a_raw: Tensor::zeros(&[1, 1, 3, 64, 64]),
            lam: Tensor::zeros(&[1, 8, 64, 64]),
        },
        kchunk: 0,
        arrived: Instant::now(),
        priority: Priority::default(),
        deadline: None,
        tenant: 0,
        reply: tx.clone(),
    }
}

/// Trace-driven serving rows: replay a deterministic arrival trace
/// (open-loop, with real sleeps) against a fresh cpu-backend
/// coordinator per phase — Metrics histograms are cumulative, so
/// per-phase latency numbers need a per-phase server.
fn bench_serve_json() {
    let smoke = std::env::var("GSPN2_BENCH_SMOKE").is_ok();
    let mut suite = BenchSuite::new("BENCH_serve");
    // Host header mirrors BENCH_scan: serving rows run the fused scan
    // engine underneath, so record which lane kernel served them.
    {
        use gspn2::scan::simd;
        suite.stamp_host("simd", simd::kernel().name().into());
        suite.stamp_host("simd_lanes", simd::lanes().into());
        suite.stamp_host("features", simd::detected_features().into());
    }
    let requests = if smoke { 60 } else { 400 };
    let rate = if smoke { 400.0 } else { 300.0 };
    for (label, burst) in [("steady", None), ("bursty", Some(BurstConfig::default()))] {
        let coord = Coordinator::start(&ServeConfig {
            backend: "cpu".into(),
            workers: 1,
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 0, // unbounded: rejections would skew the rows
            ..ServeConfig::default()
        })
        .expect("cpu coordinator");
        let trace = generate_trace(&TraceConfig {
            rate_rps: rate,
            requests,
            shapes: vec![((8, 64, 64), 0.8), ((8, 96, 96), 0.2)],
            seed: 0,
            burst,
            classes: None,
        });
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(trace.len());
        for ev in trace {
            if let Some(wait) = ev.at.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            if let Ok(rx) = coord.submit_scan(ev.x, ev.a_raw, ev.lam, 0) {
                rxs.push(rx);
            }
        }
        for rx in &rxs {
            let _ = rx.recv_timeout(Duration::from_secs(60));
        }
        let m = coord.shutdown();
        let h = &m.total;
        suite.record_value(&format!("serve {label} p50"), h.percentile_ns(50.0) / 1e3, "µs");
        suite.record_value(&format!("serve {label} p99"), h.percentile_ns(99.0) / 1e3, "µs");
        suite.record_value(&format!("serve {label} p999"), h.percentile_ns(99.9) / 1e3, "µs");
        suite.record_value(&format!("serve {label} max"), h.max_ns() as f64 / 1e3, "µs");
        suite.record_value(&format!("serve {label} throughput"), m.throughput_rps(), "req/s");
        suite.record_value(&format!("serve {label} completed"), m.completed as f64, "req");
        suite.record_value(
            &format!("serve {label} pool hit rate"),
            m.ws_hit_rate() * 100.0,
            "%",
        );
    }

    // Sustained overload: offered load far beyond one worker's capacity,
    // mixed priorities, against a shed-configured coordinator. The rows
    // are the graceful-degradation evidence: high-priority p99 stays
    // bounded (its traffic is never shed at admission) while the low
    // class absorbs the overload as sheds/expiries.
    {
        let requests = if smoke { 120 } else { 800 };
        let coord = Coordinator::start(&ServeConfig {
            backend: "cpu".into(),
            workers: 1,
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 32,
            shed_queue_frac: 0.5,
            slo_p99_us: 20_000,
            slo_high_us: 500_000,
            slo_low_us: 2_000,
            ..ServeConfig::default()
        })
        .expect("cpu coordinator");
        let trace = generate_trace(&TraceConfig {
            rate_rps: 5_000.0,
            requests,
            shapes: vec![((8, 64, 64), 1.0)],
            seed: 7,
            burst: None,
            classes: Some(ClassMix { high: 0.3, low: 0.5, tenants: 4 }),
        });
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(trace.len());
        for ev in trace {
            if let Some(wait) = ev.at.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            let opts = SubmitOptions {
                priority: ev.priority,
                tenant: ev.tenant,
                ..Default::default()
            };
            match coord.submit_scan_with(ev.x, ev.a_raw, ev.lam, 0, opts) {
                Ok(rx) => rxs.push(rx),
                // Refusals are the point of this phase; the coordinator's
                // split counters carry the tallies into the rows below.
                Err(SubmitError::Shed | SubmitError::Backpressure) => {}
                Err(e) => panic!("unexpected admission error under overload: {e}"),
            }
        }
        for rx in &rxs {
            let _ = rx.recv_timeout(Duration::from_secs(60));
        }
        let m = coord.shutdown();
        for p in Priority::ALL {
            let i = p.index();
            if m.class_completed[i] == 0 {
                continue;
            }
            let h = &m.class_total[i];
            let l = p.label();
            suite.record_value(
                &format!("overload {l} p50"),
                h.percentile_ns(50.0) / 1e3,
                "µs",
            );
            suite.record_value(
                &format!("overload {l} p99"),
                h.percentile_ns(99.0) / 1e3,
                "µs",
            );
            suite.record_value(
                &format!("overload {l} p999"),
                h.percentile_ns(99.9) / 1e3,
                "µs",
            );
            suite.record_value(
                &format!("overload {l} completed"),
                m.class_completed[i] as f64,
                "req",
            );
        }
        suite.record_value("overload shed", m.rej_shed as f64, "req");
        suite.record_value("overload expired", m.rej_expired as f64, "req");
        suite.record_value("overload backpressure", m.rej_backpressure as f64, "req");
        suite.record_value(
            "overload error budget spent",
            m.error_budget() * 100.0,
            "%",
        );
    }
    suite.finish();
}

fn main() {
    bench_serve_json();
    if std::env::var("GSPN2_BENCH_SMOKE").is_ok() {
        return;
    }

    let mut suite = BenchSuite::new("coordinator");

    // Batching policy throughput (no PJRT): enqueue + pop cycles.
    {
        let (tx, _rx) = mpsc::channel();
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(0),
            queue_cap: 0,
            eager_idle: false,
        });
        b.register_bucket(bucket(), vec![1, 2, 4]);
        let mut id = 0u64;
        suite.bench("batcher enqueue+pop (batch of 4)", || {
            for _ in 0..4 {
                b.enqueue(bucket(), mk_req(id, &tx)).expect("registered bucket");
                id += 1;
            }
            black_box(b.pop_batch(Instant::now()));
        });
    }

    // Queue mechanics alone (1-element payloads isolate the BTreeMap +
    // VecDeque cost from the ~450 KB payload allocation above).
    {
        let (tx, _rx) = mpsc::channel();
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(0),
            queue_cap: 0,
            eager_idle: false,
        });
        b.register_bucket(bucket(), vec![1, 2, 4]);
        let mut id = 0u64;
        suite.bench("batcher queue ops only (batch of 4, tiny payload)", || {
            for _ in 0..4 {
                let r = Request {
                    id,
                    payload: Payload::Scan {
                        x: Tensor::zeros(&[1, 1, 1, 1]),
                        a_raw: Tensor::zeros(&[1, 1, 3, 1, 1]),
                        lam: Tensor::zeros(&[1, 1, 1, 1]),
                    },
                    kchunk: 0,
                    arrived: Instant::now(),
                    priority: Priority::default(),
                    deadline: None,
                    tenant: 0,
                    reply: tx.clone(),
                };
                b.enqueue(bucket(), r).expect("registered bucket");
                id += 1;
            }
            black_box(b.pop_batch(Instant::now()));
        });
    }

    // Intra-batch input assembly (the serving-path CPU work inside
    // run_scan_batch): three fused-input concats, serial vs fanned out
    // on the same shared pool the scan reference uses.
    {
        let mut rng = Rng::new(9);
        let xs: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0)).collect();
        let avs: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0)).collect();
        let lams: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0)).collect();
        let xr: Vec<&Tensor> = xs.iter().collect();
        let ar: Vec<&Tensor> = avs.iter().collect();
        let lr: Vec<&Tensor> = lams.iter().collect();
        suite.bench("batch assembly 3x concat n=4 (serial)", || {
            black_box((concat_axis0(&xr), concat_axis0(&ar), concat_axis0(&lr)));
        });
        let pool = ThreadPool::global();
        suite.bench("batch assembly 3x concat n=4 (shared pool)", || {
            let groups: Vec<&[&Tensor]> = vec![&xr, &ar, &lr];
            black_box(pool.map(groups, concat_axis0));
        });
    }

    // Metrics hot path.
    {
        let mut m = Metrics::new();
        suite.bench("metrics record_request", || {
            m.record_request(Priority::Normal, None, 1_000, 50_000, 51_000, 4);
        });
        black_box(m.completed);
    }

    // Trace generation.
    suite.bench("trace generate 100 reqs", || {
        black_box(gspn2::coordinator::generate_trace(&TraceConfig {
            requests: 100,
            ..TraceConfig::default()
        }));
    });

    if !artifacts_available("artifacts") {
        eprintln!("artifacts/ missing: skipping PJRT-path benches");
        suite.finish();
        return;
    }

    // Raw engine execute (n=1 and n=4) — the baseline the serve path is
    // compared against.
    {
        let engine = Engine::cpu("artifacts").expect("engine");
        let mut rng = Rng::new(0);
        let mk = |rng: &mut Rng, n: usize| {
            vec![
                Value::F32(Tensor::randn(&[n, 8, 64, 64], rng, 1.0)),
                Value::F32(Tensor::randn(&[n, 1, 3, 64, 64], rng, 1.0)),
                Value::F32(Tensor::randn(&[n, 8, 64, 64], rng, 1.0)),
            ]
        };
        let in1 = mk(&mut rng, 1);
        let in4 = mk(&mut rng, 4);
        engine.run("scan_h64w64c8n1", &in1).unwrap(); // warm compile
        engine.run("scan_h64w64c8n4", &in4).unwrap();
        suite.bench("engine.run scan n=1 (per request)", || {
            black_box(engine.run("scan_h64w64c8n1", &in1).unwrap());
        });
        let r4 = suite.bench("engine.run scan n=4 (per batch)", || {
            black_box(engine.run("scan_h64w64c8n4", &in4).unwrap());
        });
        suite.record_value(
            "engine.run scan n=4 per-request share",
            r4.mean_ns / 4.0 / 1e3,
            "µs",
        );
    }

    // Full serving stack, closed loop: per-request latency including
    // router/batcher/worker hop.
    {
        let coord = Coordinator::start(&ServeConfig {
            workers: 1,
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 256,
            ..ServeConfig::default()
        })
        .expect("coordinator");
        let mut rng = Rng::new(1);
        // Warm up the worker's compile cache.
        let warm = coord
            .submit_scan(
                Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0),
                Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0),
                Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0),
                0,
            )
            .unwrap();
        let _ = warm.recv();
        let x = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
        let a = Tensor::randn(&[1, 1, 3, 64, 64], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 8, 64, 64], &mut rng, 1.0);
        suite.bench("serve path single request (batch=1)", || {
            let rx = coord.submit_scan(x.clone(), a.clone(), lam.clone(), 0).unwrap();
            black_box(rx.recv().unwrap());
        });
        coord.shutdown();
    }

    suite.finish();
}
