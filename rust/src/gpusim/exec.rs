//! Execution-time model: launches, waves, latency floors, and the final
//! time composition for GSPN-1 (per-step micro-kernels) and GSPN-2
//! (single fused kernel).

use super::device::DeviceSpec;
use super::memory::{self, Traffic};
use super::workload::{KernelConfig, ScanWorkload};

/// Dependent-chain latency of one fused scan step inside a block (µs):
/// VPU/FFMA chain plus an L1/smem round trip — no HBM on the critical
/// path because x/taps/lambda prefetch ahead of the carry dependency.
pub const STEP_LAT_US: f64 = 0.10;

/// Latency floor of one GSPN-1 micro-kernel wave (µs): a dependent HBM
/// round trip (the previous column must land in DRAM before the next
/// micro-kernel can consume it) plus scheduling.
pub const WAVE_LAT_US: f64 = 1.5;

/// GSPN-1's flat 1D block size (§3.3).
pub const GSPN1_BLOCK_THREADS: usize = 512;

#[derive(Clone, Debug)]
pub struct SimResult {
    pub time_ms: f64,
    pub launch_ms: f64,
    pub mem_ms: f64,
    pub latency_ms: f64,
    pub launches: usize,
    pub blocks: usize,
    pub waves: usize,
    pub occupancy: f64,
    pub efficiency: f64,
    pub hbm_gb: f64,
    /// Achieved useful throughput over the whole execution (Table 1).
    pub achieved_gbs: f64,
    pub pct_peak: f64,
}

/// Simulate one directional pass of the workload under `cfg` on `dev`.
pub fn simulate(dev: &DeviceSpec, wl: &ScanWorkload, cfg: &KernelConfig) -> SimResult {
    if cfg.fused {
        simulate_fused(dev, wl, cfg)
    } else {
        simulate_per_step(dev, wl, cfg)
    }
}

/// GSPN-1: one micro-kernel per scan step (Fig 2a).
fn simulate_per_step(dev: &DeviceSpec, wl: &ScanWorkload, cfg: &KernelConfig) -> SimResult {
    let fused = false;
    let c_eff = cfg.effective_channels(wl.c);
    let tr = memory::traffic(cfg, wl);
    let steps = wl.steps() * 1; // chunks run inside the same grid
    // Per-step slice of the total traffic.
    let step_bytes = tr.hbm_bytes / wl.w as f64;
    let step_mem_us =
        step_bytes / (dev.peak_bw_gbs * tr.efficiency * 1e9) * 1e6 * tr.time_overhead;

    // Blocks per step kernel: the flattened (W-orthogonal) work.
    let work_items = wl.n * c_eff * wl.h * wl.chunks().max(1);
    let blocks = work_items.div_ceil(GSPN1_BLOCK_THREADS).max(1);
    let capacity = dev.concurrency_capacity(GSPN1_BLOCK_THREADS, 0);
    let waves = blocks.div_ceil(capacity);
    let latency_us = waves as f64 * WAVE_LAT_US;

    let launches = steps * dev.launches_for_grid(blocks);
    let launch_ms = launches as f64 * dev.launch_us / 1e3;
    let mem_ms = step_mem_us * steps as f64 / 1e3;
    let latency_ms = latency_us * steps as f64 / 1e3;
    // Launches serialise; within a step, memory and wave latency overlap.
    let time_ms = launch_ms + steps as f64 * step_mem_us.max(latency_us) / 1e3;
    finish(dev, tr, fused, time_ms, launch_ms, mem_ms, latency_ms, launches, blocks, waves,
           dev.occupancy(GSPN1_BLOCK_THREADS, 0))
}

/// GSPN-2: single fused kernel; grid = (chunks, N, C/cSlice) (§4.1).
fn simulate_fused(dev: &DeviceSpec, wl: &ScanWorkload, cfg: &KernelConfig) -> SimResult {
    let c_eff = cfg.effective_channels(wl.c);
    let tr = memory::traffic(cfg, wl);

    let c_slice = if cfg.blocks2d { cfg.c_slice.min(c_eff).max(1) } else { 1 };
    let threads_x = wl.h.min(dev.max_threads_per_block);
    let threads = (threads_x * c_slice).min(dev.max_threads_per_block);
    let smem_bytes = if cfg.sram { c_slice * wl.h.min(1024) * 4 } else { 0 };

    let split = cfg.split.max(1).min(wl.steps().max(1));
    let blocks = (wl.chunks() * wl.n * c_eff.div_ceil(c_slice) * split).max(1);
    let capacity = dev.concurrency_capacity(threads, smem_bytes);
    let waves = blocks.div_ceil(capacity);

    // Per-block serial critical path: the scan's dependent chain. With
    // segment-parallel decomposition the chain shortens to steps/split,
    // but runs twice (local scan + carry fixup, phase 1/3 of
    // crate::scan::split) with operator composition alongside phase 1
    // (~0.5x extra) and a `split`-long sequential carry chain (phase 2).
    let block_lat_us = if split > 1 {
        let seg_steps = wl.steps().div_ceil(split) as f64;
        (2.5 * seg_steps + split as f64) * STEP_LAT_US
    } else {
        wl.steps() as f64 * STEP_LAT_US
    };
    let latency_ms = waves as f64 * block_lat_us / 1e3;

    let launches = dev.launches_for_grid(blocks);
    let launch_ms = launches as f64 * dev.launch_us / 1e3;
    let mem_ms = tr.mem_ms(dev);
    // Memory streaming overlaps the in-block dependency chain; the longer
    // one bounds execution.
    let time_ms = launch_ms + mem_ms.max(latency_ms);
    finish(dev, tr, true, time_ms, launch_ms, mem_ms, latency_ms, launches, blocks, waves,
           dev.occupancy(threads, smem_bytes))
}

#[allow(clippy::too_many_arguments)]
fn finish(
    dev: &DeviceSpec,
    tr: Traffic,
    fused: bool,
    time_ms: f64,
    launch_ms: f64,
    mem_ms: f64,
    latency_ms: f64,
    launches: usize,
    blocks: usize,
    waves: usize,
    occupancy: f64,
) -> SimResult {
    // Achieved throughput (the Table-1 quantity). Fused kernels stream
    // at their pattern efficiency while resident — the Nsight DRAM-busy
    // view (prefetch keeps the bus fed during the dependent chain), so
    // achieved ~= efficiency x peak. GSPN-1's per-step micro-kernels idle
    // the bus between launches: achieved = bytes / total wall time.
    let achieved = if fused {
        dev.peak_bw_gbs * tr.efficiency
    } else {
        Traffic { useful_bytes: tr.hbm_bytes, ..tr }.achieved_gbs(time_ms)
    };
    SimResult {
        time_ms,
        launch_ms,
        mem_ms,
        latency_ms,
        launches,
        blocks,
        waves,
        occupancy,
        efficiency: tr.efficiency,
        hbm_gb: tr.hbm_bytes / 1e9,
        achieved_gbs: achieved,
        pct_peak: achieved / dev.peak_bw_gbs * 100.0,
    }
}

/// Multi-directional propagation on separate streams (§4.3): directions
/// overlap; total time is bounded below by aggregate bandwidth and above
/// by the serial sum.
pub fn simulate_dirs(
    dev: &DeviceSpec,
    wl: &ScanWorkload,
    cfg: &KernelConfig,
    dirs: usize,
    streams: bool,
) -> f64 {
    let one = simulate(dev, wl, cfg);
    if !streams || !cfg.fused {
        // GSPN-1 serialises directions (and each is launch-bound anyway).
        return one.time_ms * dirs as f64;
    }
    // Streams overlap launch + latency; memory is additive (shared bus).
    let mem_total = one.mem_ms * dirs as f64;
    let overlapped = one.launch_ms + one.latency_ms.max(mem_total);
    overlapped.max(one.time_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::workload::OptStage;

    fn a100() -> DeviceSpec {
        DeviceSpec::a100_sxm4_80gb()
    }

    #[test]
    fn fig3_headline_speedup_band() {
        // 1024x1024, batch 16, 8 channels: paper 71.4 ms -> 1.8 ms (40x,
        // conclusion claims "up to 52x").
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let g1 = simulate(&a100(), &wl, &KernelConfig::gspn1());
        let g2 = simulate(&a100(), &wl, &KernelConfig::gspn2());
        assert!((55.0..95.0).contains(&g1.time_ms), "GSPN-1 {} ms", g1.time_ms);
        assert!((1.0..2.5).contains(&g2.time_ms), "GSPN-2 {} ms", g2.time_ms);
        let speedup = g1.time_ms / g2.time_ms;
        assert!((30.0..60.0).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn gspn1_is_launch_and_memory_bound() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let r = simulate(&a100(), &wl, &KernelConfig::gspn1());
        assert_eq!(r.launches, 1024);
        assert!(r.launch_ms > 3.0, "launch {} ms", r.launch_ms);
        assert!(r.mem_ms > r.launch_ms);
    }

    #[test]
    fn stage_times_monotone_at_8_channels() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let mut prev = f64::INFINITY;
        for s in OptStage::ALL {
            let t = simulate(&a100(), &wl, &s.config()).time_ms;
            assert!(t <= prev * 1.02, "{s:?}: {t} ms after {prev} ms");
            prev = t;
        }
    }

    #[test]
    fn sram_hurts_at_one_channel() {
        // Fig S3: 1024x1024, bs 256, 1 channel -> SRAM is a 0.9x slowdown.
        let wl = ScanWorkload::fwd(256, 1, 1024, 1024);
        let pre = simulate(&a100(), &wl, &OptStage::Coalesced.config()).time_ms;
        let post = simulate(&a100(), &wl, &OptStage::Sram.config()).time_ms;
        let ratio = pre / post;
        assert!((0.8..0.98).contains(&ratio), "SRAM ratio {ratio}");
    }

    #[test]
    fn sram_helps_at_eight_channels() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let pre = simulate(&a100(), &wl, &OptStage::Coalesced.config()).time_ms;
        let post = simulate(&a100(), &wl, &OptStage::Sram.config()).time_ms;
        assert!(post < pre, "SRAM did not help: {post} vs {pre}");
    }

    #[test]
    fn blocks2d_neutral_at_one_channel() {
        let wl = ScanWorkload::fwd(256, 1, 1024, 1024);
        let pre = simulate(&a100(), &wl, &OptStage::Sram.config()).time_ms;
        let post = simulate(&a100(), &wl, &OptStage::Blocks2d.config()).time_ms;
        let gain = pre / post;
        assert!((0.95..1.05).contains(&gain), "2D gain at C=1: {gain}");
    }

    #[test]
    fn table1_bands() {
        // All 8 Table-1 configs: GSPN-1 in the 2-8% band, GSPN-2 >= 90%.
        let rows = [
            (32, 196, 32usize, 32usize),
            (1, 768, 64, 64),
            (1, 1152, 64, 64),
            (1, 32, 64, 64),
            (1, 32, 128, 128),
            (1, 64, 256, 256),
            (8, 64, 256, 256),
            (1, 128, 512, 512),
        ];
        for (n, c, h, w) in rows {
            let wl = ScanWorkload::fwd(n, c, h, w);
            let g1 = simulate(&a100(), &wl, &KernelConfig::gspn1());
            let g2 = simulate(&a100(), &wl, &KernelConfig::gspn2());
            assert!(
                g1.pct_peak < 10.0,
                "GSPN-1 {n}x{c}x{h}x{w}: {:.1}%",
                g1.pct_peak
            );
            assert!(
                g2.pct_peak > 85.0,
                "GSPN-2 {n}x{c}x{h}x{w}: {:.1}%",
                g2.pct_peak
            );
        }
    }

    #[test]
    fn speedup_large_across_resolutions() {
        // Fig 4 upper row: GSPN-2 wins at every resolution, by a large
        // factor at high resolution (paper: up to 36.8x fwd at 1024^2).
        let dev = a100();
        let mut speedups = Vec::new();
        for res in [128usize, 256, 512, 1024] {
            let wl = ScanWorkload::fwd(4, 8, res, res);
            let s = simulate(&dev, &wl, &KernelConfig::gspn1()).time_ms
                / simulate(&dev, &wl, &KernelConfig::gspn2()).time_ms;
            assert!(s > 20.0, "speedup at {res}: only {s}x");
            speedups.push(s);
        }
        assert!(speedups[3] > speedups[0], "no growth from 128 to 1024");
    }

    #[test]
    fn backward_speedup_also_large() {
        let wl = ScanWorkload::bwd(16, 8, 1024, 1024);
        let g1 = simulate(&a100(), &wl, &KernelConfig::gspn1()).time_ms;
        let g2 = simulate(&a100(), &wl, &KernelConfig::gspn2()).time_ms;
        assert!(g1 / g2 > 15.0, "bwd speedup {}", g1 / g2);
    }

    #[test]
    fn compressive_dominates_at_high_channels() {
        // Fig S4: 1024x1024, bs 1, 1152 ch. Shared taps + proxy (C/8)
        // should deliver a many-fold gain over the 2D-blocks stage.
        let wl = ScanWorkload::fwd(1, 1152, 1024, 1024);
        let pre = simulate(&a100(), &wl, &OptStage::Blocks2d.config()).time_ms;
        let post = simulate(&a100(), &wl, &KernelConfig::with_proxy(8)).time_ms;
        let gain = pre / post;
        assert!((4.0..12.0).contains(&gain), "compressive gain {gain}");
        assert!((30.0..70.0).contains(&pre), "pre-stage {pre} ms (paper 49.8)");
        assert!((4.0..9.0).contains(&post), "post {post} ms (paper 6.4)");
    }

    #[test]
    fn streams_overlap_directions() {
        let dev = a100();
        let wl = ScanWorkload::fwd(1, 8, 256, 256);
        let cfg = KernelConfig::gspn2();
        let serial = simulate_dirs(&dev, &wl, &cfg, 4, false);
        let streamed = simulate_dirs(&dev, &wl, &cfg, 4, true);
        assert!(streamed < serial, "{streamed} !< {serial}");
        assert!(streamed >= simulate(&dev, &wl, &cfg).time_ms);
    }

    #[test]
    fn grid_limit_triggers_multi_launch() {
        let dev = a100();
        // Enough chunks x batch x channels to exceed 65535 blocks.
        let wl = ScanWorkload { kchunk: 8, ..ScanWorkload::fwd(64, 256, 64, 512) };
        let cfg = KernelConfig { blocks2d: false, c_slice: 1, ..KernelConfig::gspn2() };
        let r = simulate(&dev, &wl, &cfg);
        assert!(r.blocks > dev.grid_axis_limit);
        assert!(r.launches > 1);
    }
}
