//! HBM traffic and achieved-bandwidth model.
//!
//! Mechanisms (each toggled by one `KernelConfig` flag, each traceable to
//! a section of the paper):
//!
//! * **Coalescing** (§4.3): GSPN-1's flat layout walks H with stride W, so
//!   every 4-byte element pulls its own 128-byte DRAM line: sector
//!   efficiency 4/128 = 1/32, further degraded ~11% by DRAM row switching
//!   -> `UNCOALESCED_EFF = 0.028`. The transposed GSPN-2 layout streams
//!   contiguous columns -> `COALESCED_EFF = 0.84` of peak.
//! * **2D blocks** (§4.3): (H x cSlice) blocks raise per-SM memory-level
//!   parallelism; +10% achieved bandwidth when there are >= 4 channels to
//!   slice (`BLOCKS2D_BOOST`), neutral otherwise (matches Fig S3's 1.0x).
//! * **L1 reuse of h_{i-1}** (§5.1 "L1 Cache Effectiveness"): without
//!   explicit SRAM staging the hidden column hits L1 ~35% of the time
//!   under streaming pressure, but ~90% when the channel count is tiny
//!   (<= 2) and streams don't thrash it — the paper's own explanation of
//!   why SRAM *hurts* in the 1-channel config (Fig S3, 0.9x).
//! * **SRAM staging** (§4.3): eliminates the h_{i-1} HBM reread entirely
//!   but costs ~10% management overhead (`SMEM_OVERHEAD`).
//! * **Channel-shared taps** (§4.2): tap planes are fetched from HBM once
//!   and re-served to other channel blocks from L2 at `L2_COST` of an
//!   HBM word.
//! * **Cache pressure** (§B, Fig S4): per-channel tap streams at large C
//!   thrash L2; achieved bandwidth degrades by `1 + 0.65 ln(C/64)` beyond
//!   64 channels (calibrated on Fig S4's 49.8 ms @ 1152 channels;
//!   uncoalesced kernels take the square root — they are already
//!   sector-limited).
//! * **Compressive proxy** (§4.2/§D): the scan runs on C/ratio channels;
//!   the down/up projections add `2(C + C_proxy)` coalesced words/pixel.

use super::device::DeviceSpec;
use super::workload::{KernelConfig, ScanWorkload};

pub const UNCOALESCED_EFF: f64 = 0.028;
pub const COALESCED_EFF: f64 = 0.84;
pub const BLOCKS2D_BOOST: f64 = 1.10;
pub const EFF_CAP: f64 = 0.95;
pub const L1_HIT_STREAM: f64 = 0.35;
pub const L1_HIT_SMALL_C: f64 = 0.90;
pub const SMALL_C_THRESHOLD: usize = 2;
pub const L2_COST: f64 = 0.35;
pub const SMEM_OVERHEAD: f64 = 1.10;
pub const PRESSURE_KNEE_C: usize = 64;
pub const PRESSURE_ALPHA: f64 = 0.65;

/// Traffic accounting for one kernel execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    /// Bytes that must cross the HBM bus (useful + L2-amortised shares).
    pub hbm_bytes: f64,
    /// Logical tensor bytes touched (the Nsight "useful" number).
    pub useful_bytes: f64,
    /// Achieved fraction of peak bandwidth for this access pattern.
    pub efficiency: f64,
    /// Extra multiplicative time overhead (SRAM management).
    pub time_overhead: f64,
}

impl Traffic {
    /// Memory time in milliseconds on `dev`.
    pub fn mem_ms(&self, dev: &DeviceSpec) -> f64 {
        let gbs = dev.peak_bw_gbs * self.efficiency;
        self.hbm_bytes / (gbs * 1e9) * 1e3 * self.time_overhead
    }

    /// Achieved useful throughput (GB/s) given a total runtime.
    pub fn achieved_gbs(&self, total_ms: f64) -> f64 {
        self.useful_bytes / (total_ms * 1e-3) / 1e9
    }
}

/// L1 hit rate for the h_{i-1} reread (see module docs).
pub fn l1_hit_rate(c_total: usize) -> f64 {
    if c_total <= SMALL_C_THRESHOLD {
        L1_HIT_SMALL_C
    } else {
        L1_HIT_STREAM
    }
}

/// Cache-pressure slowdown from per-channel tap streams at large C.
pub fn pressure_factor(cfg: &KernelConfig, c: usize) -> f64 {
    if cfg.shared_taps || c <= PRESSURE_KNEE_C {
        1.0
    } else {
        1.0 + PRESSURE_ALPHA * (c as f64 / PRESSURE_KNEE_C as f64).ln()
    }
}

/// Achieved-bandwidth fraction for the configured access pattern.
pub fn efficiency(cfg: &KernelConfig, c_eff: usize, c_orig: usize) -> f64 {
    let base = if cfg.coalesced { COALESCED_EFF } else { UNCOALESCED_EFF };
    let boosted = if cfg.blocks2d && cfg.c_slice > 1 && c_eff >= 4 {
        (base * BLOCKS2D_BOOST).min(EFF_CAP)
    } else {
        base
    };
    let p = pressure_factor(cfg, c_orig);
    if cfg.coalesced {
        boosted / p
    } else {
        boosted / p.sqrt()
    }
}

/// HBM words per pixel *per effective channel* for the scan kernel.
/// Returns (hbm_words, useful_words).
pub fn words_per_pixel(cfg: &KernelConfig, wl: &ScanWorkload, c_eff: usize) -> (f64, f64) {
    let f32w = 1.0;
    // Streamed operands: x, lambda, and the h write.
    let mut hbm;
    let useful;
    if wl.backward {
        // Reads: g, x, lam, h (forward activations); writes: dx, dlam,
        // da (3 planes, per-channel before the shared-tap reduction).
        hbm = 4.0 * f32w + 2.0 * f32w + 3.0 * f32w;
        let tap_words = 3.0;
        let (tap_hbm, _tap_useful) = tap_traffic(cfg, tap_words, c_eff);
        hbm += tap_hbm;
        useful = 9.0 + tap_words;
        return (hbm, useful);
    }
    hbm = 3.0 * f32w; // x + lam + h write
    // h_{i-1} reread: SRAM removes it; otherwise L1 catches part of it.
    if !cfg.sram {
        if cfg.fused {
            hbm += 1.0 - l1_hit_rate(wl.c);
        } else {
            // GSPN-1: every step round-trips h through HBM (Fig 2a).
            hbm += 1.0;
        }
    }
    let (tap_hbm, _) = tap_traffic(cfg, 3.0, c_eff);
    hbm += tap_hbm;
    useful = 3.0 + 1.0 + 3.0; // x, lam, write, h reread, taps
    (hbm, useful)
}

/// Tap traffic per pixel per effective channel: shared taps hit HBM once
/// and are re-served from L2. Returns (hbm_equivalent_words, useful).
fn tap_traffic(cfg: &KernelConfig, tap_words: f64, c_eff: usize) -> (f64, f64) {
    if cfg.shared_taps && c_eff > 1 {
        let hbm_share = tap_words / c_eff as f64;
        let l2_share = tap_words * (1.0 - 1.0 / c_eff as f64) * L2_COST;
        (hbm_share + l2_share, tap_words)
    } else {
        (tap_words, tap_words)
    }
}

/// Full traffic model for a workload under a kernel configuration.
pub fn traffic(cfg: &KernelConfig, wl: &ScanWorkload) -> Traffic {
    let c_eff = cfg.effective_channels(wl.c);
    let (wpp, useful_wpp) = words_per_pixel(cfg, wl, c_eff);
    let px = wl.pixels() as f64;
    let mut hbm_bytes = wpp * 4.0 * px * c_eff as f64;
    let mut useful_bytes = useful_wpp * 4.0 * px * c_eff as f64;
    // Segment-parallel decomposition: the carry-fixup pass (phase 3 of
    // crate::scan::split) re-reads and re-writes h for every segment but
    // the first, with taps re-served from L2.
    if cfg.split > 1 {
        let fix_frac = (cfg.split - 1) as f64 / cfg.split as f64;
        let fix_words = 2.0 + 3.0 * L2_COST;
        hbm_bytes += fix_words * 4.0 * px * c_eff as f64 * fix_frac;
    }
    // Compressive proxy projections: read C write Cp, then read Cp write C
    // (coalesced GEMM traffic).
    if cfg.proxy_ratio > 1 && c_eff < wl.c {
        let proj_words = 2.0 * (wl.c + c_eff) as f64;
        hbm_bytes += proj_words * 4.0 * px;
        useful_bytes += proj_words * 4.0 * px;
    }
    Traffic {
        hbm_bytes,
        useful_bytes,
        efficiency: efficiency(cfg, c_eff, wl.c),
        time_overhead: if cfg.sram { SMEM_OVERHEAD } else { 1.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::workload::OptStage;

    #[test]
    fn uncoalesced_is_sector_limited() {
        assert!(UNCOALESCED_EFF < 1.0 / 32.0 * 1.1);
        assert!(UNCOALESCED_EFF > 0.02);
    }

    #[test]
    fn efficiency_ordering_across_stages() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let mut prev = 0.0;
        for s in OptStage::ALL {
            let cfg = s.config();
            let e = efficiency(&cfg, cfg.effective_channels(wl.c), wl.c);
            assert!(e >= prev - 1e-12, "{s:?} decreased efficiency");
            prev = e;
        }
        assert!(prev > 0.90, "final efficiency {prev} not in the 91-93% band");
    }

    #[test]
    fn l1_hit_depends_on_channels() {
        assert_eq!(l1_hit_rate(1), L1_HIT_SMALL_C);
        assert_eq!(l1_hit_rate(2), L1_HIT_SMALL_C);
        assert_eq!(l1_hit_rate(8), L1_HIT_STREAM);
    }

    #[test]
    fn pressure_only_with_per_channel_taps_at_large_c() {
        let g1 = KernelConfig::gspn1();
        let g2 = KernelConfig::gspn2();
        assert_eq!(pressure_factor(&g1, 64), 1.0);
        assert!(pressure_factor(&g1, 1152) > 2.5);
        assert_eq!(pressure_factor(&g2, 1152), 1.0);
    }

    #[test]
    fn sram_removes_h_reread_but_costs_overhead() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let pre = OptStage::Coalesced.config();
        let post = OptStage::Sram.config();
        let (w_pre, _) = words_per_pixel(&pre, &wl, 8);
        let (w_post, _) = words_per_pixel(&post, &wl, 8);
        assert!(w_post < w_pre);
        assert_eq!(traffic(&post, &wl).time_overhead, SMEM_OVERHEAD);
    }

    #[test]
    fn shared_taps_cut_tap_traffic() {
        let wl = ScanWorkload::fwd(1, 64, 256, 256);
        let per = OptStage::Blocks2d.config();
        let shared = OptStage::Compressive.config();
        let t_per = traffic(&per, &wl);
        let t_shared = traffic(&shared, &wl);
        assert!(t_shared.hbm_bytes < t_per.hbm_bytes * 0.8);
    }

    #[test]
    fn proxy_reduces_scan_but_adds_projection() {
        let wl = ScanWorkload::fwd(1, 1152, 1024, 1024);
        let no_proxy = KernelConfig::gspn2();
        let proxy = KernelConfig::with_proxy(8);
        let t0 = traffic(&no_proxy, &wl);
        let t1 = traffic(&proxy, &wl);
        assert!(t1.hbm_bytes < t0.hbm_bytes * 0.75, "{} vs {}", t1.hbm_bytes, t0.hbm_bytes);
    }

    #[test]
    fn backward_moves_more_bytes_than_forward() {
        let f = ScanWorkload::fwd(4, 16, 512, 512);
        let b = ScanWorkload::bwd(4, 16, 512, 512);
        let cfg = KernelConfig::gspn2();
        assert!(traffic(&cfg, &b).hbm_bytes > traffic(&cfg, &f).hbm_bytes);
    }
}
