//! Adaptive kernel-configuration policy.
//!
//! The paper's appendix B closes with: *"one could dynamically select
//! between a GSPN-1-like configuration and the full GSPN-2 based on the
//! input dimensions and batch size to achieve optimal performance across
//! diverse computational scenarios."* This module implements that
//! strategy. Every rule is a mechanism the paper measures:
//!
//! * **SRAM off at tiny C** — Fig S3 shows explicit shared-memory staging
//!   is a 0.9x *slowdown* at 1 channel because L1 already covers the
//!   carry; we disable it when `C <= 2` (the `memory::l1_hit_rate` knee).
//! * **2D blocks only with channels to slice** — Fig S3 shows ~1.0x at
//!   1 channel; we require `C_eff >= 2` and clamp `c_slice` to `C_eff`.
//! * **Proxy compression only under concurrency saturation** — §4.2:
//!   compress just enough to bring the grid under the device's resident-
//!   block capacity (never beyond the paper's 8x ratio), instead of a
//!   fixed ratio that would waste capacity at small C.
//! * **Segment-parallel split at low occupancy** — §5.1 flags 20-30%
//!   occupancy for small BSxC; we split the scan axis (see
//!   [`crate::scan::split`]) until the grid covers the SMs, bounded by
//!   the fixup-pass overhead.
//!
//! The policy is *static per request shape* — exactly what a serving
//! coordinator knows at batch time. `examples/adaptive_kernels.rs` walks
//! the policy across the paper's workload regimes, and `repro adaptive`
//! regenerates the comparison table.

use super::device::DeviceSpec;
use super::exec::simulate;
use super::memory::SMALL_C_THRESHOLD;
use super::workload::{KernelConfig, ScanWorkload};

/// Maximum proxy compression the policy will apply (the paper's C/8).
pub const MAX_PROXY_RATIO: usize = 8;
/// Maximum segment-parallel decomposition (fixup overhead bound).
pub const MAX_SPLIT: usize = 16;

/// A chosen configuration plus the rules that fired (for logs/metrics).
#[derive(Clone, Debug)]
pub struct Choice {
    pub cfg: KernelConfig,
    pub rationale: Vec<&'static str>,
}

/// Pick the kernel configuration for one workload on one device.
pub fn choose(dev: &DeviceSpec, wl: &ScanWorkload) -> Choice {
    let mut cfg = KernelConfig::gspn2();
    let mut why = Vec::new();

    // Rule 1: SRAM staging only pays when the L1 stream misses (C > 2).
    if wl.c <= SMALL_C_THRESHOLD {
        cfg.sram = false;
        why.push("sram-off: C <= 2, L1 covers the carry (Fig S3 0.9x)");
    }

    // Rule 2: 2D blocks need channels to slice.
    let c_now = cfg.effective_channels(wl.c);
    if c_now < 2 {
        cfg.blocks2d = false;
        cfg.c_slice = 1;
        why.push("2d-off: single channel, nothing to slice (Fig S3 1.0x)");
    } else {
        cfg.c_slice = cfg.c_slice.min(c_now);
    }

    // Rule 3: proxy compression when the grid saturates the concurrency
    // ceiling — but only a ratio the execution model confirms pays for
    // its projection traffic (2(C + C_proxy) extra words/pixel, §D).
    let capacity = capacity_for(dev, wl, &cfg);
    if grid_blocks(wl, &cfg) > capacity {
        let base_ms = simulate(dev, wl, &cfg).time_ms;
        let mut best = (base_ms, 0usize);
        let mut ratio = 2;
        while ratio <= MAX_PROXY_RATIO {
            let t = simulate(dev, wl, &KernelConfig { proxy_ratio: ratio, ..cfg }).time_ms;
            if t < best.0 {
                best = (t, ratio);
            }
            ratio *= 2;
        }
        if best.1 > 0 {
            cfg.proxy_ratio = best.1;
            why.push("proxy-on: grid exceeds resident-block capacity (§4.2)");
            // Re-check rule 2 against the compressed channel count.
            let c_eff = cfg.effective_channels(wl.c);
            if c_eff < 2 {
                cfg.blocks2d = false;
                cfg.c_slice = 1;
            } else {
                cfg.c_slice = cfg.c_slice.min(c_eff);
            }
        }
    }

    // Rule 4: split the scan axis when the grid underfills the SMs *and*
    // the kernel is latency-bound (splitting a bandwidth-bound kernel
    // only adds fixup traffic). The policy searches candidate degrees
    // with the execution model itself — one simulate() call is ~30 ns,
    // cheap enough for a serving coordinator's batch-time decision.
    let blocks = grid_blocks(wl, &cfg);
    let base = simulate(dev, wl, &cfg);
    if blocks < dev.sms && base.latency_ms > base.mem_ms && wl.steps() > 2 * MAX_SPLIT {
        let mut best = (base.time_ms, 1);
        let mut split = 2;
        while split <= MAX_SPLIT {
            let t = simulate(dev, wl, &KernelConfig { split, ..cfg }).time_ms;
            if t < best.0 {
                best = (t, split);
            }
            split *= 2;
        }
        if best.1 > 1 {
            cfg.split = best.1;
            why.push("split-on: latency-bound grid underfills SMs (§5.1)");
        }
    }

    Choice { cfg, rationale: why }
}

/// Simulate both the fixed GSPN-2 config and the adaptive choice; return
/// (fixed_ms, adaptive_ms, choice).
pub fn compare(dev: &DeviceSpec, wl: &ScanWorkload) -> (f64, f64, Choice) {
    let fixed = simulate(dev, wl, &KernelConfig::gspn2()).time_ms;
    let choice = choose(dev, wl);
    let adaptive = simulate(dev, wl, &choice.cfg).time_ms;
    (fixed, adaptive, choice)
}

fn grid_blocks(wl: &ScanWorkload, cfg: &KernelConfig) -> usize {
    let c_eff = cfg.effective_channels(wl.c);
    let c_slice = if cfg.blocks2d { cfg.c_slice.min(c_eff).max(1) } else { 1 };
    (wl.chunks() * wl.n * c_eff.div_ceil(c_slice) * cfg.split.max(1)).max(1)
}

fn capacity_for(dev: &DeviceSpec, wl: &ScanWorkload, cfg: &KernelConfig) -> usize {
    let c_eff = cfg.effective_channels(wl.c);
    let c_slice = if cfg.blocks2d { cfg.c_slice.min(c_eff).max(1) } else { 1 };
    let threads_x = wl.h.min(dev.max_threads_per_block);
    let threads = (threads_x * c_slice).min(dev.max_threads_per_block);
    let smem = if cfg.sram { c_slice * wl.h.min(1024) * 4 } else { 0 };
    dev.concurrency_capacity(threads, smem)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> DeviceSpec {
        DeviceSpec::a100_sxm4_80gb()
    }

    #[test]
    fn sram_disabled_at_one_channel() {
        let wl = ScanWorkload::fwd(256, 1, 1024, 1024);
        let c = choose(&a100(), &wl);
        assert!(!c.cfg.sram);
        assert!(!c.cfg.blocks2d);
    }

    #[test]
    fn sram_kept_at_eight_channels() {
        let wl = ScanWorkload::fwd(16, 8, 1024, 1024);
        let c = choose(&a100(), &wl);
        assert!(c.cfg.sram);
        assert!(c.cfg.blocks2d);
    }

    #[test]
    fn proxy_engages_only_under_saturation() {
        let small = ScanWorkload::fwd(1, 32, 256, 256);
        assert_eq!(choose(&a100(), &small).cfg.proxy_ratio, 0);
        let big = ScanWorkload::fwd(64, 1152, 256, 256);
        let c = choose(&a100(), &big);
        assert!(c.cfg.proxy_ratio >= 2, "no proxy for saturated grid: {c:?}");
        assert!(c.cfg.proxy_ratio <= MAX_PROXY_RATIO);
    }

    #[test]
    fn split_engages_at_low_occupancy() {
        // 1 batch, 4 channels: far fewer blocks than 108 SMs.
        let wl = ScanWorkload::fwd(1, 4, 1024, 1024);
        let c = choose(&a100(), &wl);
        assert!(c.cfg.split > 1, "no split: {c:?}");
        assert!(c.cfg.split <= MAX_SPLIT);
    }

    #[test]
    fn split_off_when_grid_is_full() {
        let wl = ScanWorkload::fwd(64, 64, 512, 512);
        assert_eq!(choose(&a100(), &wl).cfg.split, 1);
    }

    #[test]
    fn adaptive_never_materially_slower_than_fixed() {
        // The appendix-B claim: shape-adaptive selection should match or
        // beat the one-size config across diverse workloads.
        let dev = a100();
        for (n, c, r) in [
            (1usize, 1usize, 1024usize),
            (1, 4, 1024),
            (1, 8, 512),
            (16, 8, 1024),
            (256, 1, 1024),
            (1, 1152, 1024),
            (64, 256, 256),
            (8, 64, 256),
        ] {
            let wl = ScanWorkload::fwd(n, c, r, r);
            let (fixed, adaptive, choice) = compare(&dev, &wl);
            assert!(
                adaptive <= fixed * 1.01,
                "adaptive {adaptive:.3} ms > fixed {fixed:.3} ms at n{n} c{c} r{r}: {choice:?}"
            );
        }
    }

    #[test]
    fn adaptive_wins_big_in_the_low_occupancy_regime() {
        let dev = a100();
        let wl = ScanWorkload::fwd(1, 1, 2048, 2048);
        let (fixed, adaptive, _) = compare(&dev, &wl);
        assert!(adaptive < fixed * 0.8, "{adaptive} vs {fixed}");
    }

    #[test]
    fn adaptive_never_slower_property_random_workloads() {
        // Property: across random (n, c, res) draws, the adaptive choice
        // is never materially slower than the fixed GSPN-2 config.
        use crate::util::proptest::{check, ensure};
        check("adaptive <= fixed across random workloads", |g| {
            let dev = a100();
            let n = 1usize << g.int_in(0, 8); // 1..256
            let c = 1usize << g.int_in(0, 10); // 1..1024
            let res = 64usize << g.int_in(0, 4); // 64..1024
            let wl = ScanWorkload::fwd(n, c, res, res);
            let (fixed, adaptive, choice) = compare(&dev, &wl);
            ensure(
                adaptive <= fixed * 1.01,
                format!(
                    "adaptive {adaptive:.4} > fixed {fixed:.4} at n{n} c{c} r{res}: {choice:?}"
                ),
            )
        });
    }

    #[test]
    fn adaptive_on_all_devices() {
        for dev in DeviceSpec::all() {
            let wl = ScanWorkload::fwd(1, 1, 1024, 1024);
            let (fixed, adaptive, _) = compare(&dev, &wl);
            assert!(adaptive <= fixed * 1.01, "{}: {adaptive} > {fixed}", dev.name);
        }
    }

    #[test]
    fn rationale_strings_attached() {
        let wl = ScanWorkload::fwd(1, 1, 1024, 1024);
        let c = choose(&a100(), &wl);
        assert!(c.rationale.iter().any(|r| r.starts_with("sram-off")));
        assert!(c.rationale.iter().any(|r| r.starts_with("split-on")));
    }
}
