//! Cost models for the comparison baselines: softmax / flash attention,
//! linear attention, Mamba-style selective scan — plus the composite
//! diffusion-pipeline model behind Fig 1 and Fig 5 and the classifier
//! throughput model behind Fig S1 / Table S2.
//!
//! Compute peaks are A100 datasheet numbers (312 TFLOP/s bf16 tensor,
//! 19.5 TFLOP/s fp32 SIMT); achieved fractions are the standard ~60%
//! GEMM / ~40% attention figures from the FlashAttention papers.

use super::device::DeviceSpec;
use super::exec::simulate_dirs;
use super::workload::{KernelConfig, ScanWorkload};

pub const TENSOR_PEAK_TFLOPS: f64 = 312.0;
pub const GEMM_EFF: f64 = 0.60;
pub const ATTN_EFF: f64 = 0.40;

/// One global softmax-attention layer over T tokens, head dim d, channels
/// c. FlashAttention-style: IO is linear in T, compute stays quadratic.
pub fn attention_time_ms(dev: &DeviceSpec, t: usize, c: usize, flash: bool) -> f64 {
    let t = t as f64;
    let c = c as f64;
    // QKV + output projections (4 dense GEMMs).
    let proj_flops = 8.0 * t * c * c;
    // QK^T and AV.
    let attn_flops = 4.0 * t * t * c;
    let compute_ms =
        (proj_flops / (TENSOR_PEAK_TFLOPS * GEMM_EFF) + attn_flops / (TENSOR_PEAK_TFLOPS * ATTN_EFF))
            / 1e12
            * 1e3;
    let bytes = if flash {
        // O(T x c) streaming IO.
        12.0 * t * c * 4.0
    } else {
        // Materialised T x T attention matrix, read + written.
        (12.0 * t * c + 2.0 * t * t) * 4.0
    };
    let mem_ms = bytes / (dev.peak_bw_gbs * 0.85 * 1e9) * 1e3;
    compute_ms.max(mem_ms)
}

/// Linear attention (kernel feature maps): O(T c^2) compute.
pub fn linear_attention_time_ms(dev: &DeviceSpec, t: usize, c: usize) -> f64 {
    let t = t as f64;
    let c = c as f64;
    let flops = 8.0 * t * c * c + 4.0 * t * c * c;
    let compute_ms = flops / (TENSOR_PEAK_TFLOPS * GEMM_EFF) / 1e12 * 1e3;
    let mem_ms = 16.0 * t * c * 4.0 / (dev.peak_bw_gbs * 0.85 * 1e9) * 1e3;
    compute_ms.max(mem_ms)
}

/// Mamba-style selective scan over T tokens, state dim n, channels c:
/// bandwidth-bound chunked prefix scan.
pub fn mamba_scan_time_ms(dev: &DeviceSpec, t: usize, c: usize, state: usize) -> f64 {
    let bytes = (t * c * (6 + 2 * state)) as f64 * 4.0;
    let mem_ms = bytes / (dev.peak_bw_gbs * 0.80 * 1e9) * 1e3;
    let flops = (t * c * state * 6) as f64;
    let compute_ms = flops / (19.5e12 * 0.5) * 1e3;
    mem_ms.max(compute_ms)
}

/// GSPN module time: 4 directional passes on streams (GSPN-2) or serial
/// micro-kernels (GSPN-1), over an (n, c, h, w) feature map. The proxy
/// down/up projections (when `proxy_ratio > 1`) run ONCE, outside the
/// per-direction scans.
pub fn gspn_module_time_ms(
    dev: &DeviceSpec,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    cfg: &KernelConfig,
) -> f64 {
    let c_eff = cfg.effective_channels(c).max(1);
    // Scans see the proxy-compressed channel count directly; clear the
    // ratio so the simulator does not re-add projection traffic per pass.
    let scan_cfg = KernelConfig { proxy_ratio: 0, ..*cfg };
    let wl = ScanWorkload::fwd(n, c_eff, h, w);
    let scans_ms = simulate_dirs(dev, &wl, &scan_cfg, 4, cfg.fused);
    let proj_ms = if cfg.proxy_ratio > 1 && c_eff < c {
        let words = 2.0 * (c + c_eff) as f64;
        let bytes = words * 4.0 * (n * h * w) as f64;
        bytes / (dev.peak_bw_gbs * 0.90 * 1e9) * 1e3
    } else {
        0.0
    };
    scans_ms + proj_ms
}

// ---------------------------------------------------------------------------
// Fig 5: text-to-image pipeline model
// ---------------------------------------------------------------------------

/// SDXL-like denoising pipeline at a given output resolution.
///
/// The UNet runs on an 8x-downsampled latent; attention layers sit at 1/2
/// and 1/4 of the latent resolution (SDXL places self-attention in the
/// lower-resolution stages), conv layers everywhere. GSPN variants swap
/// each attention layer for a 4-direction GSPN module with C_proxy = C/8
/// (the paper's §5.3 setting).
#[derive(Clone, Debug)]
pub struct DiffusionModel {
    /// Attention-bearing layers: (downsample factor from latent, channels).
    pub attn_layers: Vec<(usize, usize)>,
    /// Conv compute per latent pixel (FLOPs) for the whole UNet.
    pub conv_flops_per_px: f64,
    pub steps: usize,
}

impl DiffusionModel {
    pub fn sdxl_like() -> DiffusionModel {
        DiffusionModel {
            // SDXL's ~70 transformer blocks sit at latent/2 (640ch) and
            // latent/4 (1280ch).
            attn_layers: vec![(2, 640); 24]
                .into_iter()
                .chain(vec![(4, 1280); 46])
                .collect(),
            conv_flops_per_px: 2.0e6,
            steps: 30,
        }
    }

    /// Latent side length for an output resolution.
    pub fn latent(res: usize) -> usize {
        (res / 8).max(1)
    }

    fn conv_time_ms(&self, res: usize) -> f64 {
        let lat = Self::latent(res);
        let px = (lat * lat) as f64;
        self.conv_flops_per_px * px / (TENSOR_PEAK_TFLOPS * GEMM_EFF * 1e12) * 1e3
    }

    /// Per-denoising-step time with dense (or flash) attention.
    pub fn attn_step_ms(&self, dev: &DeviceSpec, res: usize, flash: bool) -> f64 {
        let lat = Self::latent(res);
        let mut t = self.conv_time_ms(res);
        for &(ds, c) in &self.attn_layers {
            let side = (lat / ds).max(1);
            t += attention_time_ms(dev, side * side, c.min(128), flash);
        }
        t
    }

    /// Per-step time with GSPN modules in place of attention.
    pub fn gspn_step_ms(&self, dev: &DeviceSpec, res: usize, cfg: &KernelConfig) -> f64 {
        let lat = Self::latent(res);
        let mut t = self.conv_time_ms(res);
        for &(ds, c) in &self.attn_layers {
            let side = (lat / ds).max(1);
            t += gspn_module_time_ms(dev, 1, c, side, side, cfg);
        }
        t
    }

    /// Full-image generation time (all denoising steps), seconds.
    pub fn generate_s(&self, dev: &DeviceSpec, res: usize, backend: Backend) -> f64 {
        let per_step = match backend {
            Backend::SdxlDense => self.attn_step_ms(dev, res, false),
            Backend::SdxlFlash => self.attn_step_ms(dev, res, true),
            Backend::Gspn1 => self.gspn_step_ms(dev, res, &KernelConfig::gspn1()),
            Backend::Gspn2 => self.gspn_step_ms(dev, res, &KernelConfig::with_proxy(8)),
        };
        per_step * self.steps as f64 / 1e3
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    SdxlDense,
    SdxlFlash,
    Gspn1,
    Gspn2,
}

impl Backend {
    pub const ALL: [Backend; 4] =
        [Backend::SdxlDense, Backend::SdxlFlash, Backend::Gspn1, Backend::Gspn2];

    pub fn name(self) -> &'static str {
        match self {
            Backend::SdxlDense => "SDXL (dense attn)",
            Backend::SdxlFlash => "SDXL (flash attn)",
            Backend::Gspn1 => "GSPN-1",
            Backend::Gspn2 => "GSPN-2",
        }
    }
}

// ---------------------------------------------------------------------------
// Fig S1 / Table S2: classifier throughput model
// ---------------------------------------------------------------------------

/// ImageNet-style inference throughput (img/s) of a GSPN classifier.
///
/// GEMM-dominated compute from the MAC accounting plus the simulated scan
/// time of every block's 4-direction module at its stage resolution.
pub fn classifier_throughput(
    dev: &DeviceSpec,
    arch: &crate::model::GspnArch,
    img: usize,
    batch: usize,
) -> f64 {
    // Small-conv inference at 224^2 achieves nowhere near tensor peak:
    // ViT-small-class models on A100 sustain ~15-20 effective TFLOP/s
    // (launch latency + small GEMMs); calibrated on Fig S1's reported
    // 1544 img/s for GSPN-2-T.
    const CLASSIFIER_EFF_TFLOPS: f64 = 18.0;
    let macs = arch.cost(img).macs as f64 * batch as f64;
    let gemm_ms = 2.0 * macs / (CLASSIFIER_EFF_TFLOPS * 1e12) * 1e3;
    let cfg = KernelConfig::gspn2();
    let mut scan_ms = 0.0;
    let mut res = img / arch.patch;
    for (si, (&_dim, &depth)) in arch.dims.iter().zip(&arch.depths).enumerate() {
        if si > 0 {
            res /= 2;
        }
        let wl = ScanWorkload::fwd(batch, arch.c_proxy, res, res);
        let per_block = simulate_dirs(dev, &wl, &cfg, 4, true);
        scan_ms += per_block * depth as f64;
    }
    // Fixed per-image framework overhead (dataloader/normalisation).
    let overhead_ms = 0.05 * batch as f64;
    batch as f64 / ((gemm_ms + scan_ms + overhead_ms) / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> DeviceSpec {
        DeviceSpec::a100_sxm4_80gb()
    }

    #[test]
    fn attention_quadratic_scan_linear() {
        let dev = a100();
        let t1 = attention_time_ms(&dev, 4096, 64, true);
        let t2 = attention_time_ms(&dev, 16384, 64, true);
        assert!(t2 / t1 > 8.0, "attention not ~quadratic: {}", t2 / t1);
        let cfg = KernelConfig::gspn2();
        let s1 = gspn_module_time_ms(&dev, 1, 64, 64, 64, &cfg);
        let s2 = gspn_module_time_ms(&dev, 1, 64, 128, 128, &cfg);
        assert!(s2 / s1 < 8.0, "scan super-quadratic: {}", s2 / s1);
    }

    #[test]
    fn dense_attention_slower_than_flash_at_scale() {
        let dev = a100();
        assert!(
            attention_time_ms(&dev, 16384, 64, false)
                > attention_time_ms(&dev, 16384, 64, true)
        );
    }

    #[test]
    fn fig5_speedup_grows_with_resolution() {
        let dev = a100();
        let m = DiffusionModel::sdxl_like();
        let mut prev = 0.0;
        for res in [1024usize, 2048, 4096, 8192, 16384] {
            let base = m.generate_s(&dev, res, Backend::SdxlFlash);
            let ours = m.generate_s(&dev, res, Backend::Gspn2);
            let speedup = base / ours;
            assert!(speedup > prev * 0.95, "speedup fell at {res}: {speedup}");
            prev = speedup;
        }
        assert!(prev > 30.0, "16K speedup only {prev}x (paper: 93x)");
    }

    #[test]
    fn fig5_4k_speedup_band() {
        let dev = a100();
        let m = DiffusionModel::sdxl_like();
        let base = m.generate_s(&dev, 4096, Backend::SdxlFlash);
        let ours = m.generate_s(&dev, 4096, Backend::Gspn2);
        let s = base / ours;
        assert!((8.0..120.0).contains(&s), "4K speedup {s}x (paper: 32x)");
    }

    #[test]
    fn gspn2_pipeline_faster_than_gspn1() {
        let dev = a100();
        let m = DiffusionModel::sdxl_like();
        for res in [1024usize, 4096] {
            assert!(
                m.generate_s(&dev, res, Backend::Gspn2)
                    < m.generate_s(&dev, res, Backend::Gspn1)
            );
        }
    }

    #[test]
    fn mamba_and_linear_attention_sane() {
        let dev = a100();
        let lin = linear_attention_time_ms(&dev, 16384, 64);
        let dense = attention_time_ms(&dev, 16384, 64, false);
        assert!(lin < dense);
        let mam = mamba_scan_time_ms(&dev, 16384, 64, 16);
        assert!(mam > 0.0 && mam < dense);
    }

    #[test]
    fn throughput_decreases_with_proxy_dim() {
        // Table S2 trend: larger C_proxy -> lower img/s.
        let dev = a100();
        let mut prev = f64::INFINITY;
        for p in [2usize, 4, 8, 16, 32] {
            let arch = crate::model::GspnArch { c_proxy: p, ..crate::model::gspn2_tiny() };
            let thr = classifier_throughput(&dev, &arch, 224, 64);
            assert!(thr < prev, "throughput rose at C_proxy={p}: {thr}");
            prev = thr;
        }
    }

    #[test]
    fn tiny_throughput_magnitude() {
        // Fig S1 reports 1544 img/s for GSPN-2-T; accept a broad band.
        let dev = a100();
        let thr = classifier_throughput(&dev, &crate::model::gspn2_tiny(), 224, 64);
        assert!((400.0..5000.0).contains(&thr), "throughput {thr}");
    }
}
