//! First-principles A100 execution simulator.
//!
//! The paper's system contribution is a CUDA kernel; this environment has
//! no GPU, so every table and figure of the evaluation is regenerated on
//! a mechanistic simulator (DESIGN.md §1 documents the substitution):
//!
//! * [`device`] — published hardware constants + occupancy calculator.
//! * [`workload`] — scan workloads and the cumulative optimisation stages.
//! * [`memory`] — HBM traffic / coalescing / cache model (the calibrated
//!   constants live here, each documented against the paper section that
//!   motivates it).
//! * [`exec`] — launch / wave / latency composition for GSPN-1's per-step
//!   micro-kernels and GSPN-2's fused kernel.
//! * [`pipeline`] — the Fig 3 / S3 / S4 step-by-step stage runner.
//! * [`attention`] — baseline cost models (softmax/flash/linear/Mamba) and
//!   the Fig 5 diffusion-pipeline + Fig S1 throughput models.

pub mod adaptive;
pub mod attention;
pub mod device;
pub mod exec;
pub mod memory;
pub mod pipeline;
pub mod workload;

pub use adaptive::{choose as adaptive_choose, Choice};
pub use attention::{Backend, DiffusionModel};
pub use device::DeviceSpec;
pub use exec::{simulate, simulate_dirs, SimResult};
pub use pipeline::{run_pipeline, PaperPipeline, StageResult, FIG3, FIG_S3, FIG_S4};
pub use workload::{KernelConfig, OptStage, ScanWorkload};
