//! Workload and kernel-configuration descriptors for the simulator.

/// One directional GSPN scan over an (N, C, H, W) f32 tensor; the scan
/// axis is W (H is the cross/parallel axis), matching the paper's
/// benchmark convention (forward time of a single directional pass).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScanWorkload {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// GSPN-local chunk length along the scan axis (0 = global).
    pub kchunk: usize,
    /// Backward pass (adjoint reverse scan) instead of forward.
    pub backward: bool,
}

impl ScanWorkload {
    pub fn fwd(n: usize, c: usize, h: usize, w: usize) -> ScanWorkload {
        ScanWorkload { n, c, h, w, kchunk: 0, backward: false }
    }

    pub fn bwd(n: usize, c: usize, h: usize, w: usize) -> ScanWorkload {
        ScanWorkload { n, c, h, w, kchunk: 0, backward: true }
    }

    pub fn pixels(&self) -> u64 {
        (self.n * self.h * self.w) as u64
    }

    /// Independent chunks along the scan axis.
    pub fn chunks(&self) -> usize {
        if self.kchunk == 0 {
            1
        } else {
            self.w.div_ceil(self.kchunk)
        }
    }

    /// Scan steps each chunk performs.
    pub fn steps(&self) -> usize {
        if self.kchunk == 0 {
            self.w
        } else {
            self.kchunk.min(self.w)
        }
    }
}

/// Cumulative optimisation stages of Figure 3 / S3 / S4, in paper order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptStage {
    /// GSPN-1 baseline: one kernel per scan step, flat 1D blocks,
    /// uncoalesced (H-strided) access.
    Gspn1,
    /// §4.1 single fused kernel (still uncoalesced).
    Fused,
    /// §4.3 coalesced global-memory access (transposed layout).
    Coalesced,
    /// §4.3 shared-memory staging of h_{i-1}.
    Sram,
    /// §4.1/4.3 2D thread blocks (H x cSlice).
    Blocks2d,
    /// §4.2 compact channel propagation (channel-shared w_i).
    Compressive,
}

impl OptStage {
    pub const ALL: [OptStage; 6] = [
        OptStage::Gspn1,
        OptStage::Fused,
        OptStage::Coalesced,
        OptStage::Sram,
        OptStage::Blocks2d,
        OptStage::Compressive,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OptStage::Gspn1 => "GSPN-1 baseline",
            OptStage::Fused => "+ Unified kernel",
            OptStage::Coalesced => "+ Coalesced memory",
            OptStage::Sram => "+ SRAM hidden states",
            OptStage::Blocks2d => "+ 2D thread blocks",
            OptStage::Compressive => "+ Compressive channels",
        }
    }

    /// The kernel configuration with every optimisation up to and
    /// including this stage enabled (the cumulative bars of Fig 3).
    pub fn config(self) -> KernelConfig {
        KernelConfig {
            fused: self >= OptStage::Fused,
            coalesced: self >= OptStage::Coalesced,
            sram: self >= OptStage::Sram,
            blocks2d: self >= OptStage::Blocks2d,
            shared_taps: self >= OptStage::Compressive,
            proxy_ratio: 0, // the kernel pipeline shares taps; proxy
                            // compression is a model-level knob (see
                            // `KernelConfig::with_proxy`)
            c_slice: if self >= OptStage::Blocks2d { 4 } else { 1 },
            split: 1,
        }
    }
}

/// Feature toggles of the simulated kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Single fused kernel (vs one launch per scan step).
    pub fused: bool,
    /// Lane-contiguous (transposed) layout -> coalesced HBM access.
    pub coalesced: bool,
    /// Explicit shared-memory staging of the hidden-state column.
    pub sram: bool,
    /// 2D thread blocks (H x cSlice).
    pub blocks2d: bool,
    /// Channel-shared propagation weights (Cw = 1), §4.2.
    pub shared_taps: bool,
    /// Compressive proxy: C_proxy = max(1, C / proxy_ratio); 0 = off.
    pub proxy_ratio: usize,
    /// Channels per block along threadIdx.y (the cSlice knob).
    pub c_slice: usize,
    /// Segment-parallel scan decomposition degree (1 = off). Splits the
    /// scan axis into `split` segments processed by independent blocks,
    /// with a carry-fixup pass (see `crate::scan::split`); raises
    /// occupancy in the small-BSxC regime the paper's §5.1 flags.
    pub split: usize,
}

impl KernelConfig {
    pub fn gspn1() -> KernelConfig {
        OptStage::Gspn1.config()
    }

    /// The full GSPN-2 kernel (all Fig-3 stages on, no proxy reduction).
    pub fn gspn2() -> KernelConfig {
        OptStage::Compressive.config()
    }

    /// Full GSPN-2 plus the compressive proxy dimension (§4.2 / §D),
    /// e.g. ratio 8 for the paper's C_proxy = C/8 diffusion setting.
    pub fn with_proxy(ratio: usize) -> KernelConfig {
        KernelConfig { proxy_ratio: ratio, ..Self::gspn2() }
    }

    /// Full GSPN-2 plus segment-parallel decomposition (`split` segments
    /// along the scan axis) for the low-occupancy regime.
    pub fn with_split(split: usize) -> KernelConfig {
        KernelConfig { split: split.max(1), ..Self::gspn2() }
    }

    /// Effective channel count the scan runs over.
    pub fn effective_channels(&self, c: usize) -> usize {
        if self.proxy_ratio > 1 {
            (c / self.proxy_ratio).max(1)
        } else {
            c
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_configs_are_cumulative() {
        let mut prev_on = 0;
        for s in OptStage::ALL {
            let c = s.config();
            let on = [c.fused, c.coalesced, c.sram, c.blocks2d, c.shared_taps]
                .iter()
                .filter(|&&b| b)
                .count();
            assert!(on >= prev_on, "stage {s:?} lost an optimisation");
            prev_on = on;
        }
        assert_eq!(prev_on, 5);
    }

    #[test]
    fn gspn1_is_all_off() {
        let c = KernelConfig::gspn1();
        assert!(!c.fused && !c.coalesced && !c.sram && !c.blocks2d && !c.shared_taps);
    }

    #[test]
    fn proxy_channels() {
        let c = KernelConfig::with_proxy(8);
        assert_eq!(c.effective_channels(1152), 144);
        assert_eq!(c.effective_channels(8), 1);
        assert_eq!(c.effective_channels(4), 1);
        assert_eq!(KernelConfig::gspn2().effective_channels(64), 64);
    }

    #[test]
    fn workload_chunks_steps() {
        let w = ScanWorkload { kchunk: 16, ..ScanWorkload::fwd(1, 8, 64, 64) };
        assert_eq!(w.chunks(), 4);
        assert_eq!(w.steps(), 16);
        let g = ScanWorkload::fwd(2, 4, 32, 48);
        assert_eq!(g.chunks(), 1);
        assert_eq!(g.steps(), 48);
        assert_eq!(g.pixels(), 2 * 32 * 48);
    }
}
