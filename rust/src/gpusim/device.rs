//! GPU device specification and occupancy calculator.
//!
//! All numbers are published hardware constants (A100-SXM4 datasheet /
//! CUDA occupancy tables), not fits — see DESIGN.md §5. The handful of
//! *calibration* constants live in `memory.rs` and are documented there.

/// Static device description.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: String,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Max resident thread blocks per SM (compute capability 8.0).
    pub max_blocks_per_sm: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Max threads per block.
    pub max_threads_per_block: usize,
    /// Shared memory per SM (KiB), carveout-max configuration.
    pub smem_kb_per_sm: usize,
    /// L1/tex cache per SM (KiB) — unified with smem on A100 (192 total).
    pub l1_kb_per_sm: usize,
    /// L2 cache (MiB).
    pub l2_mb: usize,
    /// HBM peak bandwidth (GB/s). A100-80GB HBM2e: ~1995 effective.
    pub peak_bw_gbs: f64,
    /// Kernel launch overhead (µs), CUDA driver literature value.
    pub launch_us: f64,
    /// DRAM access latency (µs) — a dependent HBM round trip.
    pub hbm_latency_us: f64,
    /// CUDA per-grid-axis block limit (x axis is 2^31-1, y/z are 65535;
    /// GSPN-1's flat 1D grids hit the 65535 legacy limit when misused).
    pub grid_axis_limit: usize,
}

impl DeviceSpec {
    /// NVIDIA A100-SXM4-80GB (compute capability 8.0).
    pub fn a100_sxm4_80gb() -> DeviceSpec {
        DeviceSpec {
            name: "A100-SXM4-80GB".into(),
            sms: 108,
            max_blocks_per_sm: 32,
            max_threads_per_sm: 2048,
            max_threads_per_block: 1024,
            smem_kb_per_sm: 164,
            l1_kb_per_sm: 192,
            l2_mb: 40,
            peak_bw_gbs: 1995.0,
            launch_us: 4.0,
            hbm_latency_us: 0.5,
            grid_axis_limit: 65_535,
        }
    }

    /// A smaller part (A30-like) used by ablations to show the model is
    /// not A100-specific.
    pub fn a30() -> DeviceSpec {
        DeviceSpec {
            name: "A30".into(),
            sms: 56,
            max_blocks_per_sm: 32,
            max_threads_per_sm: 2048,
            max_threads_per_block: 1024,
            smem_kb_per_sm: 164,
            l1_kb_per_sm: 192,
            l2_mb: 24,
            peak_bw_gbs: 933.0,
            launch_us: 4.0,
            hbm_latency_us: 0.5,
            grid_axis_limit: 65_535,
        }
    }

    /// NVIDIA H100-SXM5-80GB (compute capability 9.0): more SMs and HBM3
    /// bandwidth move the concurrency knee and the roofline, used by the
    /// cross-device sweep to show the model is not A100-specific.
    pub fn h100_sxm5_80gb() -> DeviceSpec {
        DeviceSpec {
            name: "H100-SXM5-80GB".into(),
            sms: 132,
            max_blocks_per_sm: 32,
            max_threads_per_sm: 2048,
            max_threads_per_block: 1024,
            smem_kb_per_sm: 228,
            l1_kb_per_sm: 256,
            l2_mb: 50,
            peak_bw_gbs: 3352.0,
            launch_us: 3.5,
            hbm_latency_us: 0.45,
            grid_axis_limit: 65_535,
        }
    }

    /// NVIDIA V100-SXM2-32GB (compute capability 7.0), the previous
    /// generation: fewer SMs, HBM2, higher launch overhead.
    pub fn v100_sxm2_32gb() -> DeviceSpec {
        DeviceSpec {
            name: "V100-SXM2-32GB".into(),
            sms: 80,
            max_blocks_per_sm: 32,
            max_threads_per_sm: 2048,
            max_threads_per_block: 1024,
            smem_kb_per_sm: 96,
            l1_kb_per_sm: 128,
            l2_mb: 6,
            peak_bw_gbs: 900.0,
            launch_us: 5.0,
            hbm_latency_us: 0.6,
            grid_axis_limit: 65_535,
        }
    }

    pub fn by_name(name: &str) -> Option<DeviceSpec> {
        match name {
            "a100-sxm4-80gb" | "a100" => Some(Self::a100_sxm4_80gb()),
            "a30" => Some(Self::a30()),
            "h100-sxm5-80gb" | "h100" => Some(Self::h100_sxm5_80gb()),
            "v100-sxm2-32gb" | "v100" => Some(Self::v100_sxm2_32gb()),
            _ => None,
        }
    }

    /// Every known device, for cross-device sweeps.
    pub fn all() -> Vec<DeviceSpec> {
        vec![Self::v100_sxm2_32gb(), Self::a30(), Self::a100_sxm4_80gb(), Self::h100_sxm5_80gb()]
    }

    /// Resident blocks per SM for a given block shape.
    pub fn blocks_per_sm(&self, threads_per_block: usize, smem_bytes_per_block: usize) -> usize {
        if threads_per_block == 0 {
            return 0;
        }
        let by_threads = self.max_threads_per_sm / threads_per_block.min(self.max_threads_per_block);
        let by_smem = if smem_bytes_per_block == 0 {
            self.max_blocks_per_sm
        } else {
            (self.smem_kb_per_sm * 1024) / smem_bytes_per_block
        };
        by_threads.min(by_smem).min(self.max_blocks_per_sm).max(0)
    }

    /// Device-wide concurrent-block capacity (the §4.2 saturation scale:
    /// 108 x 32 ≈ 3.5K blocks in the best case).
    pub fn concurrency_capacity(&self, threads_per_block: usize, smem_bytes: usize) -> usize {
        (self.blocks_per_sm(threads_per_block, smem_bytes) * self.sms).max(1)
    }

    /// Occupancy in [0,1] for a block shape: resident threads / max.
    pub fn occupancy(&self, threads_per_block: usize, smem_bytes: usize) -> f64 {
        let b = self.blocks_per_sm(threads_per_block, smem_bytes);
        (b * threads_per_block.min(self.max_threads_per_block)) as f64
            / self.max_threads_per_sm as f64
    }

    /// Number of launches needed to cover `blocks` given the per-axis grid
    /// limit (GSPN-2's multi-launch offset indexing, §4.3).
    pub fn launches_for_grid(&self, blocks: usize) -> usize {
        blocks.div_ceil(self.grid_axis_limit).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_constants() {
        let d = DeviceSpec::a100_sxm4_80gb();
        assert_eq!(d.sms, 108);
        assert_eq!(d.max_blocks_per_sm, 32);
        // The paper's ~3.5K concurrent-block figure (108 x 32).
        assert_eq!(d.concurrency_capacity(64, 0), 3456);
    }

    #[test]
    fn occupancy_by_threads() {
        let d = DeviceSpec::a100_sxm4_80gb();
        // 1024-thread blocks: 2 resident per SM.
        assert_eq!(d.blocks_per_sm(1024, 0), 2);
        assert!((d.occupancy(1024, 0) - 1.0).abs() < 1e-9);
        // 512-thread blocks: 4 resident.
        assert_eq!(d.blocks_per_sm(512, 0), 4);
        // Tiny blocks capped by the 32-block limit.
        assert_eq!(d.blocks_per_sm(32, 0), 32);
        assert!(d.occupancy(32, 0) < 0.51);
    }

    #[test]
    fn smem_limits_residency() {
        let d = DeviceSpec::a100_sxm4_80gb();
        // 100 KiB smem per block -> only 1 block per SM.
        assert_eq!(d.blocks_per_sm(256, 100 * 1024), 1);
        assert_eq!(d.blocks_per_sm(256, 40 * 1024), 4);
    }

    #[test]
    fn grid_limit_launches() {
        let d = DeviceSpec::a100_sxm4_80gb();
        assert_eq!(d.launches_for_grid(1000), 1);
        assert_eq!(d.launches_for_grid(65_535), 1);
        assert_eq!(d.launches_for_grid(65_536), 2);
        assert_eq!(d.launches_for_grid(200_000), 4);
    }

    #[test]
    fn device_lookup() {
        assert!(DeviceSpec::by_name("a100").is_some());
        assert!(DeviceSpec::by_name("a30").is_some());
        assert!(DeviceSpec::by_name("h100").is_some());
        assert!(DeviceSpec::by_name("v100").is_some());
        assert!(DeviceSpec::by_name("tpu").is_none());
    }

    #[test]
    fn device_ordering_by_bandwidth() {
        let all = DeviceSpec::all();
        assert_eq!(all.len(), 4);
        for pair in all.windows(2) {
            assert!(pair[0].peak_bw_gbs < pair[1].peak_bw_gbs);
        }
    }

    #[test]
    fn h100_concurrency_exceeds_a100() {
        let a = DeviceSpec::a100_sxm4_80gb();
        let h = DeviceSpec::h100_sxm5_80gb();
        assert!(h.concurrency_capacity(64, 0) > a.concurrency_capacity(64, 0));
    }
}
