//! Cumulative optimisation pipeline: regenerates the step-by-step bars of
//! Fig 3 (main config), Fig S3 (large batch) and Fig S4 (large channels).

use super::device::DeviceSpec;
use super::exec::{simulate, SimResult};
use super::workload::{KernelConfig, OptStage, ScanWorkload};

#[derive(Clone, Debug)]
pub struct StageResult {
    pub stage: OptStage,
    pub name: &'static str,
    pub time_ms: f64,
    /// Speedup relative to the previous stage.
    pub step_speedup: f64,
    /// Cumulative speedup over the GSPN-1 baseline.
    pub cum_speedup: f64,
    pub sim: SimResult,
}

/// Run the full cumulative pipeline. `final_proxy_ratio > 1` additionally
/// applies the compressive proxy dimension at the last stage (the Fig S4
/// configuration uses ratio 8).
pub fn run_pipeline(
    dev: &DeviceSpec,
    wl: &ScanWorkload,
    final_proxy_ratio: usize,
) -> Vec<StageResult> {
    let mut out = Vec::with_capacity(OptStage::ALL.len());
    let mut baseline = 0.0;
    let mut prev = 0.0;
    for stage in OptStage::ALL {
        let mut cfg: KernelConfig = stage.config();
        if stage == OptStage::Compressive && final_proxy_ratio > 1 {
            cfg.proxy_ratio = final_proxy_ratio;
        }
        let sim = simulate(dev, wl, &cfg);
        let t = sim.time_ms;
        if stage == OptStage::Gspn1 {
            baseline = t;
            prev = t;
        }
        out.push(StageResult {
            stage,
            name: stage.name(),
            time_ms: t,
            step_speedup: if prev > 0.0 { prev / t } else { 1.0 },
            cum_speedup: if t > 0.0 { baseline / t } else { 1.0 },
            sim,
        });
        prev = t;
    }
    out
}

/// Paper-reported milestone times for the three pipeline configurations
/// (used by EXPERIMENTS.md's computed-vs-paper tables).
pub struct PaperPipeline {
    pub label: &'static str,
    pub n: usize,
    pub c: usize,
    pub res: usize,
    pub proxy_ratio: usize,
    pub paper_ms: [f64; 6],
}

pub const FIG3: PaperPipeline = PaperPipeline {
    label: "Fig 3 (1024^2, bs16, 8ch)",
    n: 16,
    c: 8,
    res: 1024,
    proxy_ratio: 0,
    paper_ms: [71.4, 57.4, 2.4, 2.2, 2.1, 1.8],
};

pub const FIG_S3: PaperPipeline = PaperPipeline {
    label: "Fig S3 (1024^2, bs256, 1ch)",
    n: 256,
    c: 1,
    res: 1024,
    proxy_ratio: 0,
    paper_ms: [143.7, 139.2, 4.1, 4.5, 4.4, 3.9],
};

pub const FIG_S4: PaperPipeline = PaperPipeline {
    label: "Fig S4 (1024^2, bs1, 1152ch)",
    n: 1,
    c: 1152,
    res: 1024,
    proxy_ratio: 8,
    // The appendix reports baseline 863.2, pre-compressive 49.8,
    // compressive 6.4, final 5.7; intermediate bars read from the figure.
    paper_ms: [863.2, 757.6, 55.0, 51.0, 49.8, 5.7],
};

impl PaperPipeline {
    pub fn workload(&self) -> ScanWorkload {
        ScanWorkload::fwd(self.n, self.c, self.res, self.res)
    }

    pub fn run(&self, dev: &DeviceSpec) -> Vec<StageResult> {
        run_pipeline(dev, &self.workload(), self.proxy_ratio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> DeviceSpec {
        DeviceSpec::a100_sxm4_80gb()
    }

    #[test]
    fn fig3_pipeline_shape() {
        let r = FIG3.run(&a100());
        assert_eq!(r.len(), 6);
        // Coalescing is the dominant single win (paper: 23.9x).
        let coalesce_gain = r[2].step_speedup;
        for (i, s) in r.iter().enumerate() {
            if i != 2 && i != 0 {
                assert!(coalesce_gain > s.step_speedup, "stage {i} beat coalescing");
            }
        }
        assert!(coalesce_gain > 10.0, "coalescing only {coalesce_gain}x");
        // Final cumulative speedup in the paper's claimed band (40-52x).
        let total = r.last().unwrap().cum_speedup;
        assert!((30.0..60.0).contains(&total), "total {total}x");
    }

    #[test]
    fn fig3_within_factor_two_of_paper() {
        let r = FIG3.run(&a100());
        for (got, want) in r.iter().zip(FIG3.paper_ms) {
            let ratio = got.time_ms / want;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: {:.2} ms vs paper {want} ms",
                got.name,
                got.time_ms
            );
        }
    }

    #[test]
    fn figs3_sram_slowdown_reproduced() {
        let r = FIG_S3.run(&a100());
        // Stage 3 (SRAM) is a slowdown: step_speedup < 1 (paper 0.9x).
        assert!(r[3].step_speedup < 1.0, "SRAM step {}x", r[3].step_speedup);
        // Unified-kernel gain is small (paper 1.03x) — far below the
        // coalescing gain, and smaller than Fig 3's 1.2x would suggest.
        assert!(r[1].step_speedup < 1.3, "fused step {}x", r[1].step_speedup);
        let total = r.last().unwrap().cum_speedup;
        assert!((25.0..50.0).contains(&total), "total {total}x (paper 36.8x)");
    }

    #[test]
    fn figs4_compressive_dominates() {
        let r = FIG_S4.run(&a100());
        let comp = r[5].step_speedup;
        assert!(comp > 3.0, "compressive step only {comp}x (paper 7.8x)");
        let total = r.last().unwrap().cum_speedup;
        assert!(total > 80.0, "total {total}x (paper 151.4x)");
    }

    #[test]
    fn all_pipelines_within_factor_two_at_endpoints() {
        for p in [&FIG3, &FIG_S3, &FIG_S4] {
            let r = p.run(&a100());
            for idx in [0usize, 5] {
                let ratio = r[idx].time_ms / p.paper_ms[idx];
                assert!(
                    (0.4..2.5).contains(&ratio),
                    "{} stage {idx}: {:.2} vs {:.2}",
                    p.label,
                    r[idx].time_ms,
                    p.paper_ms[idx]
                );
            }
        }
    }
}
