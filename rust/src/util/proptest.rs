//! Property-based testing mini-framework (no proptest crate vendored).
//!
//! A property is a closure from a seeded `Gen` to `Result<(), String>`;
//! the runner executes it across many deterministic seeds and, on failure,
//! reports the failing seed so the case replays exactly. Shrinking is
//! intentionally simple: we re-run with "smaller" size hints first, which
//! in practice finds near-minimal cases for the tensor/scan/batcher
//! invariants this repo checks.

use super::rng::Rng;

/// Value generator handed to each property execution.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [0, 1]; properties scale their dimensions by it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Rng::new(seed), size }
    }

    /// Integer in [lo, hi] scaled toward lo for small sizes.
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n, 1.0)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0x5EED }
    }
}

/// Run a property across `cfg.cases` seeds; panics with the failing seed.
pub fn check_with<F>(cfg: Config, name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    // Small sizes first: failures surface with near-minimal inputs.
    for case in 0..cfg.cases {
        let size = 0.15 + 0.85 * (case as f64 / cfg.cases.max(1) as f64);
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed={seed:#x}, size={size:.2}): {msg}"
            );
        }
    }
}

/// Run with default config.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    check_with(Config::default(), name, prop);
}

/// Assertion helpers that return Err instead of panicking.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let denom = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() / denom <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

pub fn ensure_all_close(a: &[f32], b: &[f32], tol: f64, what: &str) -> Result<(), String> {
    ensure(a.len() == b.len(), format!("{what}: length {} vs {}", a.len(), b.len()))?;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = 1.0f64.max((*x as f64).abs()).max((*y as f64).abs());
        if ((*x as f64) - (*y as f64)).abs() / denom > tol {
            return Err(format!("{what}: index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("addition commutes", |g| {
            let a = g.f32_in(-100.0, 100.0);
            let b = g.f32_in(-100.0, 100.0);
            ensure_close((a + b) as f64, (b + a) as f64, 1e-9, "a+b")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn int_in_respects_bounds() {
        check("int_in bounds", |g| {
            let lo = g.int_in(0, 10);
            let hi = lo + g.int_in(0, 10);
            let x = g.int_in(lo, hi);
            ensure(x >= lo && x <= hi, format!("{x} not in [{lo},{hi}]"))
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut g1 = Gen::new(123, 0.5);
        let mut g2 = Gen::new(123, 0.5);
        for _ in 0..16 {
            assert_eq!(g1.int_in(0, 1000), g2.int_in(0, 1000));
        }
    }

    #[test]
    fn ensure_all_close_reports_index() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 9.0, 3.0];
        let err = ensure_all_close(&a, &b, 1e-6, "vecs").unwrap_err();
        assert!(err.contains("index 1"), "{err}");
    }
}
