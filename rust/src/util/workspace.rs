//! Size-classed workspace pool: leased f32 scratch buffers with RAII return.
//!
//! The scan engine's hot path used to build every slab, retained panel, and
//! correction column from a fresh `vec!`; under steady-state serving that is
//! pure allocator tax on every request. [`BufferPool`] keeps freed buffers in
//! power-of-two size classes and hands them back out as [`Lease`]s whose
//! `Drop` returns the buffer to the pool — including during unwinding, so the
//! pool composes with the engine's panic-containment paths (a panicking batch
//! member cannot leak its scratch).
//!
//! Zeroing discipline (bit-exactness): [`BufferPool::acquire`] returns a
//! buffer with arbitrary contents and is only used where the engine fully
//! overwrites before reading (pack slabs, staged-tap panels, staging
//! columns). [`BufferPool::acquire_zeroed`] zero-resets the visible prefix
//! and is used exactly where the old fresh-`vec!` code relied on zero
//! initialization (carry columns, `zeros` reset columns, correction
//! buffers, retained phase-1 panels).
//!
//! Counters ([`BufferPool::stats`]) make the allocation-free serving
//! invariant testable: after one warm-up call per bucket, a repeated
//! identical request must record zero pool misses.

use std::collections::BTreeMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::lock_unpoisoned;

/// Smallest size class, in elements. Tiny requests share one class so the
/// free lists stay short.
const MIN_CLASS: usize = 64;

/// Default retention cap for the process-global pool: 512 MiB of f32s.
const DEFAULT_CAP_BYTES: usize = 512 << 20;

/// The size class a request for `len` elements lands in. Crate-visible
/// so the scan planner's workspace-footprint model aggregates demand by
/// the pool's real classes instead of re-deriving the rounding rule.
pub(crate) fn size_class(len: usize) -> usize {
    len.max(MIN_CLASS).next_power_of_two()
}

/// Snapshot of pool counters. `hits`/`misses` are cumulative acquire
/// outcomes; `bytes_pooled` / `bytes_leased` are current gauges;
/// `peak_leased` is the high-water mark of bytes out on lease.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
    pub bytes_pooled: u64,
    pub bytes_leased: u64,
    pub peak_leased: u64,
}

impl PoolStats {
    /// Fraction of acquires served from the pool (1.0 when no traffic yet
    /// would be misleading, so an idle pool reports 0.0).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A pool of reusable f32 buffers, keyed by power-of-two size class.
///
/// Thread-safe: acquire/release take a short mutex over the free lists;
/// counters are atomics. Buffers released while the retained total would
/// exceed `cap_bytes` are dropped instead of pooled, bounding memory.
pub struct BufferPool {
    classes: Mutex<BTreeMap<usize, Vec<Vec<f32>>>>,
    cap_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes_pooled: AtomicU64,
    bytes_leased: AtomicU64,
    peak_leased: AtomicU64,
}

impl BufferPool {
    pub fn new(cap_bytes: usize) -> Self {
        Self {
            classes: Mutex::new(BTreeMap::new()),
            cap_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            bytes_pooled: AtomicU64::new(0),
            bytes_leased: AtomicU64::new(0),
            peak_leased: AtomicU64::new(0),
        }
    }

    /// Process-global pool used by the public scan entry points that do not
    /// take an explicit workspace.
    pub fn global() -> &'static BufferPool {
        static POOL: OnceLock<BufferPool> = OnceLock::new();
        POOL.get_or_init(|| BufferPool::new(DEFAULT_CAP_BYTES))
    }

    pub fn cap_bytes(&self) -> usize {
        self.cap_bytes
    }

    /// Lease a buffer of at least `len` elements with ARBITRARY contents.
    /// Callers must fully overwrite before reading.
    pub fn acquire(&self, len: usize) -> Lease<'_> {
        self.acquire_inner(len, false)
    }

    /// Lease a buffer whose visible `len` prefix is zeroed — the drop-in
    /// replacement for `vec![0.0f32; len]`.
    pub fn acquire_zeroed(&self, len: usize) -> Lease<'_> {
        self.acquire_inner(len, true)
    }

    fn acquire_inner(&self, len: usize, zero: bool) -> Lease<'_> {
        let class = size_class(len);
        let reused = {
            let mut map = lock_unpoisoned(&self.classes);
            map.get_mut(&class).and_then(|v| v.pop())
        };
        let buf = match reused {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.bytes_pooled.fetch_sub((class * 4) as u64, Ordering::Relaxed);
                if zero {
                    b[..len].fill(0.0);
                }
                b
            }
            // A fresh vec is already zeroed; no extra fill needed.
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; class]
            }
        };
        let leased =
            self.bytes_leased.fetch_add((class * 4) as u64, Ordering::Relaxed) + (class * 4) as u64;
        self.peak_leased.fetch_max(leased, Ordering::Relaxed);
        Lease { buf, len, pool: self }
    }

    /// Take a zeroed buffer of exactly `len` elements *out* of the pool:
    /// ownership transfers to the caller, nothing is counted as on
    /// lease. This is the escape hatch for buffers that leave the engine
    /// entirely — the coordinator's reply tensors — where a borrowed
    /// [`Lease`] cannot follow. The vec keeps its full size-class
    /// capacity (only its visible length is `len`), so a later
    /// [`BufferPool::donate`] can put it back on the same free list.
    /// Counts a hit or miss exactly like `acquire`, which is what lets
    /// the warm-bucket zero-miss tests cover the reply path too.
    pub fn take_zeroed(&self, len: usize) -> Vec<f32> {
        let class = size_class(len);
        let reused = {
            let mut map = lock_unpoisoned(&self.classes);
            map.get_mut(&class).and_then(|v| v.pop())
        };
        match reused {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.bytes_pooled.fetch_sub((class * 4) as u64, Ordering::Relaxed);
                b[..len].fill(0.0);
                b.truncate(len);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let mut b = Vec::with_capacity(class);
                b.resize(len, 0.0);
                b
            }
        }
    }

    /// Give an owned buffer back to the pool — the return half of
    /// [`BufferPool::take_zeroed`]. Accepts any vec whose *capacity* is
    /// exactly one of the pool's size classes (every taken buffer keeps
    /// its class capacity through `truncate`); a foreign-capacity vec,
    /// or one that would push retention past the cap, is simply dropped.
    /// Never touches the lease gauges: donated buffers were never on
    /// lease.
    pub fn donate(&self, mut buf: Vec<f32>) {
        let class = buf.capacity();
        if class < MIN_CLASS || !class.is_power_of_two() {
            return;
        }
        if self.bytes_pooled.load(Ordering::Relaxed) as usize + class * 4 > self.cap_bytes {
            return;
        }
        // Restore the len == class invariant of pooled buffers. The
        // tail fill never reallocates (len grows only to capacity);
        // contents stay arbitrary per the `acquire` contract.
        buf.resize(class, 0.0);
        self.bytes_pooled.fetch_add((class * 4) as u64, Ordering::Relaxed);
        lock_unpoisoned(&self.classes).entry(class).or_default().push(buf);
    }

    /// Ensure at least `count` free buffers of `len`'s size class exist,
    /// respecting the retention cap. Counts neither as hit nor miss.
    pub fn prewarm(&self, len: usize, count: usize) {
        let class = size_class(len);
        let mut map = lock_unpoisoned(&self.classes);
        let have = map.get(&class).map_or(0, |v| v.len());
        for _ in have..count {
            if self.bytes_pooled.load(Ordering::Relaxed) as usize + class * 4 > self.cap_bytes {
                break;
            }
            self.bytes_pooled.fetch_add((class * 4) as u64, Ordering::Relaxed);
            map.entry(class).or_default().push(vec![0.0f32; class]);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            bytes_pooled: self.bytes_pooled.load(Ordering::Relaxed),
            bytes_leased: self.bytes_leased.load(Ordering::Relaxed),
            peak_leased: self.peak_leased.load(Ordering::Relaxed),
        }
    }

    /// Reset the lease high-water mark to the *current* outstanding
    /// gauge and return the peak observed since the previous rebase —
    /// the per-request peak-workspace accounting hook for the serving
    /// coordinator (bracket an execution with two calls; the second
    /// returns that execution's peak). With several concurrent users of
    /// one pool the measurement windows overlap, so per-window peaks
    /// attribute shared demand rather than isolating it; callers that
    /// need the lifetime high-water mark fold each return value into
    /// their own running max (the serving metrics do).
    pub fn rebase_peak(&self) -> u64 {
        let now = self.bytes_leased.load(Ordering::Relaxed);
        self.peak_leased.swap(now, Ordering::Relaxed).max(now)
    }

    fn release(&self, buf: Vec<f32>) {
        // Leases never resize the vec, so its length IS the size class.
        let class = buf.len();
        self.bytes_leased.fetch_sub((class * 4) as u64, Ordering::Relaxed);
        if self.bytes_pooled.load(Ordering::Relaxed) as usize + class * 4 > self.cap_bytes {
            return; // over cap: drop instead of retaining
        }
        self.bytes_pooled.fetch_add((class * 4) as u64, Ordering::Relaxed);
        lock_unpoisoned(&self.classes).entry(class).or_default().push(buf);
    }
}

/// RAII lease over a pooled buffer. Derefs to exactly the requested length
/// (the size-class tail stays hidden); `Drop` returns the buffer to the
/// pool, including when dropped during unwinding.
pub struct Lease<'p> {
    buf: Vec<f32>,
    len: usize,
    pool: &'p BufferPool,
}

impl Lease<'_> {
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// View the leased storage as `u16` words — twice as many elements as
    /// the f32 view. This is how the scan engine's reduced-precision mode
    /// packs two bf16 values into each pooled f32 slot without growing the
    /// pool beyond its single element type: a lease of
    /// `bf16_len(n) = ceil(n/2)` f32s holds `n` bf16 words.
    ///
    /// Sound because `align_of::<u16>() <= align_of::<f32>()` and every bit
    /// pattern is a valid `u16`. The word order within an f32 slot is
    /// endianness-dependent but irrelevant: the pack and unpack sides share
    /// this view, and `acquire` contents are arbitrary by contract anyway.
    pub fn as_u16(&self) -> &[u16] {
        let s: &[f32] = self;
        // SAFETY: same allocation, halved element size, compatible
        // alignment; lifetime tied to &self.
        unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u16, s.len() * 2) }
    }

    /// Mutable twin of [`Lease::as_u16`].
    pub fn as_u16_mut(&mut self) -> &mut [u16] {
        let s: &mut [f32] = self;
        let (ptr, n) = (s.as_mut_ptr(), s.len());
        // SAFETY: as in `as_u16`; the &mut self borrow gives exclusivity.
        unsafe { std::slice::from_raw_parts_mut(ptr as *mut u16, n * 2) }
    }
}

impl Deref for Lease<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.buf[..self.len]
    }
}

impl DerefMut for Lease<'_> {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf[..self.len]
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        if !buf.is_empty() {
            self.pool.release(buf);
        }
    }
}

// ---------------------------------------------------------------------
// BlockBoard: the chained scan's decoupled look-back publication board
// ---------------------------------------------------------------------

/// Block states of a [`BlockBoard`] slot, in publication order. A block
/// moves `EMPTY -> AGG -> PREFIX` (its owner is the only writer), or to
/// `POISONED` from any state when the owning job panics so waiters can
/// unwind instead of spinning forever.
pub const BLOCK_EMPTY: u32 = 0;
pub const BLOCK_AGG: u32 = 1;
pub const BLOCK_PREFIX: u32 = 2;
pub const BLOCK_POISONED: u32 = 3;

/// The decoupled look-back publication board of the chained scan
/// (`multi_chained.rs`-style `BlockInfo { state, aggregate, prefix }`,
/// with the u64-packed payload widened to two f32 columns): per block,
/// an atomic state plus a payload slot holding the block's *aggregate*
/// (its zero-carry final column) and *prefix* (its corrected final
/// column — the true carry into the next block).
///
/// The payload lives in ONE caller-held pooled buffer (`2 * hmax`
/// floats per block: `[aggregate | prefix]`), so the whole board is a
/// single [`BufferPool`] lease — allocation-free in steady state and
/// returned to the pool even when a job unwinds. Publication protocol:
/// the owner locks the slot, copies its column in, then Release-stores
/// the new state; readers Acquire-load the state first and only then
/// lock + copy out, so the column bytes are always ordered-after the
/// state that advertises them. The per-slot mutex is uncontended in
/// steady state (the owner writes once, successors copy once each) and
/// exists to keep the aliasing safe in the racing case — a successor
/// copying the aggregate while the owner publishes its prefix into the
/// same slot.
pub struct BlockBoard<'a> {
    states: Vec<AtomicU32>,
    slots: Vec<Mutex<&'a mut [f32]>>,
    hmax: usize,
}

impl<'a> BlockBoard<'a> {
    /// Split `payload` (at least `2 * hmax * nblocks` floats, typically
    /// a pooled lease held by the caller) into per-block slots.
    pub fn new(payload: &'a mut [f32], nblocks: usize, hmax: usize) -> BlockBoard<'a> {
        let hmax = hmax.max(1);
        assert!(payload.len() >= 2 * hmax * nblocks, "BlockBoard payload too small");
        let slots = payload[..2 * hmax * nblocks].chunks_mut(2 * hmax).map(Mutex::new).collect();
        BlockBoard { states: (0..nblocks).map(|_| AtomicU32::new(BLOCK_EMPTY)).collect(), slots, hmax }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// Current state of block `i` (Acquire: a state `>= BLOCK_AGG`
    /// guarantees the matching column reads back the published bytes).
    pub fn state(&self, i: usize) -> u32 {
        self.states[i].load(Ordering::Acquire)
    }

    /// Publish block `i`'s aggregate (owner only).
    pub fn publish_agg(&self, i: usize, col: &[f32]) {
        debug_assert!(col.len() <= self.hmax);
        lock_unpoisoned(&self.slots[i])[..col.len()].copy_from_slice(col);
        self.states[i].store(BLOCK_AGG, Ordering::Release);
    }

    /// Publish block `i`'s prefix (owner only, after its aggregate).
    pub fn publish_prefix(&self, i: usize, col: &[f32]) {
        debug_assert!(col.len() <= self.hmax);
        let h = self.hmax;
        lock_unpoisoned(&self.slots[i])[h..h + col.len()].copy_from_slice(col);
        self.states[i].store(BLOCK_PREFIX, Ordering::Release);
    }

    /// Copy out block `i`'s aggregate. Caller must have observed
    /// `state(i) >= BLOCK_AGG`.
    pub fn read_agg(&self, i: usize, out: &mut [f32]) {
        debug_assert!(self.state(i) >= BLOCK_AGG && self.state(i) != BLOCK_POISONED);
        out.copy_from_slice(&lock_unpoisoned(&self.slots[i])[..out.len()]);
    }

    /// Copy out block `i`'s prefix. Caller must have observed
    /// `state(i) == BLOCK_PREFIX`.
    pub fn read_prefix(&self, i: usize, out: &mut [f32]) {
        debug_assert!(self.state(i) == BLOCK_PREFIX);
        let h = self.hmax;
        out.copy_from_slice(&lock_unpoisoned(&self.slots[i])[h..h + out.len()]);
    }

    /// Mark block `i` dead because its owning job is unwinding; any
    /// waiter observing this must panic rather than keep spinning.
    pub fn poison(&self, i: usize) {
        self.states[i].store(BLOCK_POISONED, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<Lease<'static>>();
        fn assert_sync<T: Sync>() {}
        assert_sync::<BufferPool>();
    }

    #[test]
    fn reuse_hits_same_class() {
        let p = BufferPool::new(usize::MAX);
        {
            let l = p.acquire(100);
            assert_eq!(l.len(), 100);
        }
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (0, 1));
        assert_eq!(s.bytes_pooled, 128 * 4); // class of 100 is 128
        {
            let _l = p.acquire(97); // same class -> hit
        }
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_leased, 0);
        assert!(s.peak_leased >= 128 * 4);
    }

    #[test]
    fn rebase_peak_windows_the_high_water_mark() {
        let p = BufferPool::new(usize::MAX);
        {
            let _a = p.acquire(100); // class 128
            let _b = p.acquire(100);
        }
        // First window saw both leases outstanding at once.
        assert_eq!(p.rebase_peak(), 2 * 128 * 4);
        // A fresh window with one smaller lease reports only its own peak.
        {
            let _a = p.acquire(40); // class 64
        }
        assert_eq!(p.rebase_peak(), 64 * 4);
        // An idle window reports zero; outstanding leases floor the reset.
        assert_eq!(p.rebase_peak(), 0);
        let held = p.acquire(100);
        assert_eq!(p.rebase_peak(), 128 * 4);
        // Rebase while a lease is live: the next window starts at the
        // outstanding gauge, not zero.
        assert_eq!(p.rebase_peak(), 128 * 4);
        drop(held);
    }

    #[test]
    fn u16_view_roundtrips_and_tracks_len() {
        let p = BufferPool::new(usize::MAX);
        let mut l = p.acquire(100);
        assert_eq!(l.as_u16().len(), 200);
        let w = l.as_u16_mut();
        for (i, v) in w.iter_mut().enumerate() {
            *v = i as u16;
        }
        assert_eq!(l.as_u16()[199], 199);
        // The u16 words live in the same storage as the f32 view: the pair
        // (198, 199) occupies f32 slot 99, whichever endianness orders it.
        let hi = l[99].to_bits();
        let (a, b) = ((hi & 0xffff) as u16, (hi >> 16) as u16);
        assert!((a == 198 && b == 199) || (a == 199 && b == 198));
    }

    #[test]
    fn acquire_zeroed_resets_reused_buffer() {
        let p = BufferPool::new(usize::MAX);
        {
            let mut l = p.acquire(64);
            l.iter_mut().for_each(|v| *v = 7.0);
        }
        let l = p.acquire_zeroed(64);
        assert_eq!(p.stats().hits, 1);
        assert!(l.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn plain_acquire_does_not_rezero() {
        let p = BufferPool::new(usize::MAX);
        {
            let mut l = p.acquire(64);
            l[0] = 3.5;
        }
        let l = p.acquire(64);
        assert_eq!(l[0], 3.5); // pooled contents are arbitrary by contract
    }

    #[test]
    fn lease_returns_on_unwind() {
        let p = BufferPool::new(usize::MAX);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _l = p.acquire(256);
            panic!("boom");
        }));
        assert!(r.is_err());
        let s = p.stats();
        assert_eq!(s.bytes_leased, 0);
        assert_eq!(s.bytes_pooled, 256 * 4);
        let _l = p.acquire(256);
        assert_eq!(p.stats().hits, 1);
    }

    #[test]
    fn cap_drops_excess_buffers() {
        let p = BufferPool::new(256); // 64 f32s
        {
            let _a = p.acquire(64);
            let _b = p.acquire(64);
        }
        let s = p.stats();
        assert_eq!(s.bytes_pooled, 256); // only one buffer retained
        {
            let _a = p.acquire(64); // hit
            let _b = p.acquire(64); // miss (second was dropped)
        }
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (1, 3));
    }

    #[test]
    fn take_donate_roundtrip_hits_same_class() {
        let p = BufferPool::new(usize::MAX);
        let buf = p.take_zeroed(100);
        assert_eq!(buf.len(), 100);
        assert_eq!(buf.capacity(), 128); // class capacity survives truncate
        assert!(buf.iter().all(|&v| v == 0.0));
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (0, 1));
        assert_eq!(s.bytes_leased, 0, "taken buffers are owned, not leased");
        p.donate(buf);
        assert_eq!(p.stats().bytes_pooled, 128 * 4);
        // Same class back out: a hit, zeroed again.
        let mut buf = p.take_zeroed(97);
        assert_eq!((p.stats().hits, p.stats().misses), (1, 1));
        assert!(buf.iter().all(|&v| v == 0.0));
        buf[0] = 5.0;
        p.donate(buf);
        // donate/acquire interoperate: a Lease can reuse a donated vec.
        let l = p.acquire(120);
        assert_eq!(p.stats().hits, 2);
        drop(l);
    }

    #[test]
    fn donate_rejects_foreign_capacity_and_respects_cap() {
        let p = BufferPool::new(256); // one 64-f32 class buffer
        p.donate(vec![0.0f32; 100]); // capacity 100: not a size class
        assert_eq!(p.stats().bytes_pooled, 0);
        let a = p.take_zeroed(64);
        let b = p.take_zeroed(64);
        p.donate(a);
        assert_eq!(p.stats().bytes_pooled, 256);
        p.donate(b); // over cap: dropped
        assert_eq!(p.stats().bytes_pooled, 256);
    }

    #[test]
    fn prewarm_avoids_misses() {
        let p = BufferPool::new(usize::MAX);
        p.prewarm(1000, 3);
        let s = p.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        assert_eq!(s.bytes_pooled, 3 * 1024 * 4);
        let _a = p.acquire(1000);
        let _b = p.acquire(1024);
        let _c = p.acquire(513);
        assert_eq!(p.stats().misses, 0);
        assert_eq!(p.stats().hits, 3);
    }

    #[test]
    fn hit_rate_reporting() {
        let p = BufferPool::new(usize::MAX);
        assert_eq!(p.stats().hit_rate(), 0.0);
        {
            let _l = p.acquire(64);
        }
        let _l = p.acquire(64);
        assert!((p.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn block_board_publication_roundtrip() {
        let p = BufferPool::new(usize::MAX);
        let mut payload = p.acquire(2 * 4 * 3);
        let board = BlockBoard::new(&mut payload, 3, 4);
        assert_eq!(board.len(), 3);
        assert_eq!(board.state(0), BLOCK_EMPTY);
        board.publish_agg(1, &[1.0, 2.0, 3.0]);
        assert_eq!(board.state(1), BLOCK_AGG);
        assert_eq!(board.state(0), BLOCK_EMPTY);
        let mut out = [0.0f32; 3];
        board.read_agg(1, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
        board.publish_prefix(1, &[4.0, 5.0, 6.0]);
        assert_eq!(board.state(1), BLOCK_PREFIX);
        board.read_prefix(1, &mut out);
        assert_eq!(out, [4.0, 5.0, 6.0]);
        // The aggregate survives the prefix publication (disjoint halves
        // of the slot) — look-back reads both.
        board.read_agg(1, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
        board.poison(2);
        assert_eq!(board.state(2), BLOCK_POISONED);
    }

    #[test]
    fn block_board_cross_thread_visibility() {
        // Publisher thread writes agg then prefix; a spinning reader that
        // observes the state must read exactly the published bytes.
        let p = BufferPool::new(usize::MAX);
        let mut payload = p.acquire(2 * 8);
        let board = BlockBoard::new(&mut payload, 1, 8);
        std::thread::scope(|s| {
            let b = &board;
            s.spawn(move || {
                b.publish_agg(0, &[7.0; 8]);
                b.publish_prefix(0, &[9.0; 8]);
            });
            s.spawn(move || {
                while b.state(0) < BLOCK_AGG {
                    std::hint::spin_loop();
                }
                let mut out = [0.0f32; 8];
                b.read_agg(0, &mut out);
                assert_eq!(out, [7.0; 8]);
                while b.state(0) < BLOCK_PREFIX {
                    std::hint::spin_loop();
                }
                b.read_prefix(0, &mut out);
                assert_eq!(out, [9.0; 8]);
            });
        });
    }
}
