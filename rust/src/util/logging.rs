//! Leveled stderr logger with per-module tags and a global level filter.
//!
//! Small on purpose: the binary is a CLI tool, so structured stderr lines
//! (`LEVEL tag: message`) are enough. The level comes from `GSPN2_LOG`
//! (error|warn|info|debug|trace) or defaults to `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != 255 {
        return match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        };
    }
    let lv = std::env::var("GSPN2_LOG").map(|s| Level::parse(&s)).unwrap_or(Level::Info);
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv
}

pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn log(lv: Level, tag: &str, msg: &str) {
    if lv > level() {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let dt = t0.elapsed().as_secs_f64();
    eprintln!("[{dt:9.3}s] {} {tag}: {msg}", lv.tag());
}

pub fn error(tag: &str, msg: &str) {
    log(Level::Error, tag, msg);
}
pub fn warn(tag: &str, msg: &str) {
    log(Level::Warn, tag, msg);
}
pub fn info(tag: &str, msg: &str) {
    log(Level::Info, tag, msg);
}
pub fn debug(tag: &str, msg: &str) {
    log(Level::Debug, tag, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("ERROR"), Level::Error);
        assert_eq!(Level::parse("debug"), Level::Debug);
        assert_eq!(Level::parse("bogus"), Level::Info);
    }

    #[test]
    fn set_level_silences() {
        set_level(Level::Error);
        // Nothing to assert on stderr; just exercise the filtered path.
        info("test", "should be filtered");
        error("test", "visible");
        set_level(Level::Info);
    }
}
