//! Mini-TOML parser for config files (`configs/*.toml`).
//!
//! Supports the subset the launcher needs: `[section]` / `[a.b]` tables,
//! `key = value` with string / integer / float / bool / array values, and
//! `#` comments. Values land in a flat `section.key -> Value` map, which
//! the typed config structs in `crate::config` consume.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_usize_list(&self) -> Option<Vec<usize>> {
        match self {
            Value::Arr(items) => items.iter().map(|v| v.as_i64().map(|x| x as usize)).collect(),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct Toml {
    pub map: BTreeMap<String, Value>,
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            map.insert(key, value);
        }
        Ok(Toml { map })
    }

    pub fn load(path: &str) -> Result<Toml, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Toml::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|x| x as usize).unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(body) = s.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let inner = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_value(p)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_sections() {
        let t = Toml::parse(
            "top = 1\n[server]\nport = 8080\nhost = \"local\"\n[a.b]\nx = 2.5\n",
        )
        .unwrap();
        assert_eq!(t.get("top").unwrap().as_i64(), Some(1));
        assert_eq!(t.usize_or("server.port", 0), 8080);
        assert_eq!(t.str_or("server.host", ""), "local");
        assert_eq!(t.f64_or("a.b.x", 0.0), 2.5);
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = Toml::parse("# header\nx = 3 # trailing\n\ny = \"a # not comment\"\n").unwrap();
        assert_eq!(t.get("x").unwrap().as_i64(), Some(3));
        assert_eq!(t.str_or("y", ""), "a # not comment");
    }

    #[test]
    fn arrays() {
        let t = Toml::parse("dims = [64, 128, 320, 512]\nmix = [1, 2.5]\n").unwrap();
        assert_eq!(
            t.get("dims").unwrap().as_usize_list().unwrap(),
            vec![64, 128, 320, 512]
        );
    }

    #[test]
    fn bools_and_underscored_numbers() {
        let t = Toml::parse("on = true\noff = false\nbig = 1_000_000\n").unwrap();
        assert_eq!(t.bool_or("on", false), true);
        assert_eq!(t.bool_or("off", true), false);
        assert_eq!(t.get("big").unwrap().as_i64(), Some(1_000_000));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let err = Toml::parse("x 3\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        assert!(Toml::parse("[open\n").is_err());
        assert!(Toml::parse("k = \"unterminated\n").is_err());
    }
}
