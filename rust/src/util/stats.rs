//! Summary statistics, percentile estimation, and latency histograms.
//!
//! Used by the coordinator's metrics, the bench harness, and the repro
//! drivers that print paper tables.

/// Running summary over f64 samples (Welford's online algorithm).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile over a stored sample set (fine for bench-scale data).
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=100.0).contains(&p));
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (samples.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        samples[lo]
    } else {
        let frac = rank - lo as f64;
        samples[lo] * (1.0 - frac) + samples[hi] * frac
    }
}

/// Log-bucketed latency histogram (HdrHistogram-lite): buckets grow by
/// ~4.6% per step, covering 1 ns .. ~17 min with 512 buckets.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

const HIST_BUCKETS: usize = 512;
const HIST_GROWTH: f64 = 1.046;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; HIST_BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns <= 1 {
            return 0;
        }
        let b = (ns as f64).ln() / HIST_GROWTH.ln();
        (b as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_upper(i: usize) -> f64 {
        HIST_GROWTH.powi(i as i32 + 1)
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// The exact maximum recorded sample, in nanoseconds. Unlike
    /// `percentile_ns(100.0)` — which reads a log-bucket upper bound and
    /// is only "max-ish" — this is tracked per sample and carries no
    /// bucketing error.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Percentile in nanoseconds (upper bucket bound; <= 4.6% error).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_upper(i).min(self.max_ns as f64);
            }
        }
        self.max_ns as f64
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Pretty-print engineering time: ns/us/ms/s.
pub fn fmt_time_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut whole = Summary::new();
        for i in 0..100 {
            let x = (i as f64).sin() * 10.0;
            whole.add(x);
            if i % 2 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.var() - whole.var()).abs() < 1e-9);
    }

    #[test]
    fn percentile_exact() {
        let mut xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&mut xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 100.0);
    }

    #[test]
    fn histogram_percentiles_close() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 1000); // 1µs..10ms uniform
        }
        let p50 = h.percentile_ns(50.0);
        assert!((p50 / 5_000_000.0 - 1.0).abs() < 0.06, "p50={p50}");
        let p99 = h.percentile_ns(99.0);
        assert!((p99 / 9_900_000.0 - 1.0).abs() < 0.06, "p99={p99}");
        assert!(h.percentile_ns(100.0) <= 10_000_000.0);
        let p999 = h.percentile_ns(99.9);
        assert!((p999 / 9_990_000.0 - 1.0).abs() < 0.06, "p999={p999}");
        // The max is exact, not bucket-rounded.
        assert_eq!(h.max_ns(), 10_000_000);
    }

    #[test]
    fn histogram_max_is_exact_and_merges() {
        let mut a = LatencyHistogram::new();
        a.record_ns(1_234_567);
        assert_eq!(a.max_ns(), 1_234_567);
        let mut b = LatencyHistogram::new();
        b.record_ns(7_654_321);
        a.merge(&b);
        assert_eq!(a.max_ns(), 7_654_321);
        assert_eq!(LatencyHistogram::new().max_ns(), 0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 0..1000u64 {
            a.record_ns(1000 + i);
            b.record_ns(5000 + i);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        assert!(a.mean_ns() > 3000.0);
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time_ns(500.0), "500 ns");
        assert!(fmt_time_ns(1500.0).contains("µs"));
        assert!(fmt_time_ns(2.5e6).contains("ms"));
        assert!(fmt_time_ns(3.2e9).contains(" s"));
    }
}
