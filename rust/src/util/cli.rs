//! Tiny declarative CLI argument parser (no clap vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! The binary's subcommands each build an `Args` from `std::env::args()`
//! leftovers and query typed getters with defaults.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--sizes 64,128,256`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["repro", "fig3", "--verbose"]);
        assert_eq!(a.positional, vec!["repro", "fig3"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn options_space_and_equals() {
        let a = parse(&["--batch", "16", "--res=1024"]);
        assert_eq!(a.usize_or("batch", 0), 16);
        assert_eq!(a.usize_or("res", 0), 1024);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--offset", "-3"]);
        // "-3" does not start with -- so it is consumed as a value.
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn lists() {
        let a = parse(&["--sizes", "64,128,256"]);
        assert_eq!(a.usize_list_or("sizes", &[]), vec![64, 128, 256]);
        assert_eq!(a.usize_list_or("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn floats() {
        let a = parse(&["--rate", "123.5"]);
        assert_eq!(a.f64_or("rate", 0.0), 123.5);
    }
}
