//! A small fixed-size thread pool with a shared FIFO queue.
//!
//! The coordinator's worker pool and the benchmark drivers use this; no
//! tokio/rayon is vendored, so it is built directly on `std::thread` +
//! `Mutex`/`Condvar`. Supports fire-and-forget `execute`, fork-join
//! `scope`-style `map`, and graceful shutdown on drop.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gspn2-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the driver).
    pub fn for_host() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Fork-join map: applies `f` to each item in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let f = Arc::new(f);
        for (i, item) in items.into_iter().enumerate() {
            let res = Arc::clone(&results);
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                res.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("pool still holds results"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job did not run"))
            .collect()
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        // A panicking job must not wedge wait_idle: decrement via guard.
        struct Dec<'a>(&'a Shared);
        impl Drop for Dec<'_> {
            fn drop(&mut self) {
                if self.0.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = self.0.done_lock.lock().unwrap();
                    self.0.done.notify_all();
                }
            }
        }
        let _dec = Dec(&sh);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if result.is_err() {
            crate::util::logging::warn("threadpool", "worker job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_actually_parallel() {
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        pool.map(vec![(); 8], |_| std::thread::sleep(std::time::Duration::from_millis(40)));
        // 8 x 40ms on 4 threads ~ 80ms; serial would be 320ms.
        assert!(t0.elapsed() < std::time::Duration::from_millis(250));
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let ok = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&ok);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }
}
