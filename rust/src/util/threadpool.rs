//! The process-wide parallelism substrate: a fixed-size thread pool with
//! a shared FIFO queue, safe concurrent fork-join, and work-helping.
//!
//! Everything in the crate that wants CPU parallelism — the scan plane
//! loops ([`crate::scan`]), the segment-parallel decomposition, the
//! coordinator's intra-batch tensor assembly, and the bench drivers —
//! submits to one shared pool ([`ThreadPool::global`]) instead of
//! spawning scoped OS threads per call. No tokio/rayon is vendored, so
//! it is built directly on `std::thread` + `Mutex`/`Condvar`.
//!
//! Design notes:
//!
//! * **Per-call completion latch.** Each `map`/`try_map` call owns a
//!   latch (count + condvar, the `BlockInfo`-style state machine of the
//!   multi-dimensional-parallel-scan reference) that only its own jobs
//!   decrement. Two `map` calls racing from different threads, or a
//!   `map` overlapping fire-and-forget `execute` jobs, can no longer
//!   observe each other's completion (the old implementation waited on
//!   the pool-global `in_flight` counter and could return early or trip
//!   `expect("job did not run")`).
//! * **Scoped borrows.** `map` jobs may borrow non-`'static` data from
//!   the caller's frame: the call does not return until its latch
//!   confirms every job has finished, so the borrows cannot dangle
//!   (the queue erases the lifetime internally, `rayon::scope`-style).
//! * **Work-helping (own-call only, O(1)).** While its latch is closed,
//!   the calling thread pulls *its own call's* jobs and runs them
//!   instead of sleeping. A job may therefore submit a nested `map` to
//!   the same pool without deadlocking, even on a 1-thread pool: every
//!   caller can always drive its own jobs to completion by itself.
//!   Helping never executes another call's work, so a latency-sensitive
//!   caller (e.g. a serving executor fanning out a batch assembly)
//!   cannot be held hostage by a stranger's long-running job. Each call
//!   keeps its jobs in its own list ([`CallJobs`]) and the global queue
//!   holds one *ticket* per job pointing at that list, so both an
//!   own-job pop (helper) and a next-job pop (worker) are O(1) — no
//!   O(queue-length) tag scan under the queue mutex, however deep the
//!   fan-out. A ticket whose call was fully helped is a no-op.
//! * **Panic propagation.** A panicking `map` job no longer poisons the
//!   pool or wedges the caller: `try_map` collects the first payload and
//!   returns it as a [`MapError`]; `map` rethrows the payload in the
//!   calling thread via `resume_unwind`. `execute` jobs keep the old
//!   log-and-continue behaviour.
//! * **Dependency-aware submission.** [`ThreadPool::run_graph`] executes
//!   a small task graph built with [`GraphBuilder::submit`] /
//!   [`GraphBuilder::submit_after`]: continuations run the moment their
//!   prerequisite jobs complete, with no global barrier in between —
//!   what the fused scan engine uses to hide one plane's carry
//!   correction behind other planes' phase-1 scans (wavefront
//!   scheduling). The graph reuses the per-call machinery above: a
//!   per-call ready list, stale-ticket no-ops, and the same helping
//!   wait (the caller drives its own ready nodes, so graphs complete
//!   even on a fully busy pool and nested submission stays
//!   deadlock-free).
//!
//! Sharing model: [`ThreadPool::global`] lazily builds one host-sized
//! pool for the lifetime of the process; `ThreadPool::new` remains for
//! tests and callers that need an isolated pool. The pool is `Sync` —
//! submit from as many threads as you like.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// The job list of one `map`/`try_map` call. The submitting caller pops
/// from here directly while it waits (an O(1) own-job pop); workers
/// reach it through [`Work::Call`] tickets in the global queue.
struct CallJobs {
    jobs: Mutex<VecDeque<Job>>,
}

/// One entry of the global queue: a fire-and-forget job, a ticket for
/// one job of a `map` call, or a ticket for one ready node of a
/// `run_graph` call (either ticket is a no-op if the caller already
/// helped that job to completion).
enum Work {
    Exec(Job),
    Call(Arc<CallJobs>),
    Graph(Arc<GraphCall>),
}

struct Shared {
    queue: Mutex<VecDeque<Work>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
}

/// Per-`map`-call completion latch: counts its own jobs down to zero and
/// records panic payloads, independent of anything else in the pool.
struct Latch {
    state: Mutex<LatchState>,
    open: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: usize,
    payload: Option<Box<dyn Any + Send + 'static>>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: n, panicked: 0, payload: None }),
            open: Condvar::new(),
        }
    }

    /// One job finished (`payload` set if it panicked).
    fn complete(&self, payload: Option<Box<dyn Any + Send + 'static>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if let Some(p) = payload {
            st.panicked += 1;
            if st.payload.is_none() {
                st.payload = Some(p);
            }
        }
        if st.remaining == 0 {
            self.open.notify_all();
        }
    }
}

/// The shared state of one `run_graph` call: the dependency-aware twin
/// of [`CallJobs`]. All bookkeeping (pending jobs, per-node dependency
/// counts, the ready list, and the completion count) lives under one
/// mutex so enabling a node and waiting for progress can never miss
/// each other; `progress` is notified whenever nodes become ready or
/// the graph completes, which is what lets the submitting caller help
/// newly-enabled continuations instead of sleeping through them.
struct GraphCall {
    state: Mutex<GraphState>,
    progress: Condvar,
}

struct GraphState {
    /// Node jobs, taken (`None`) once claimed by a runner.
    jobs: Vec<Option<Job>>,
    /// Unfinished-prerequisite count per node.
    waiting: Vec<usize>,
    /// Nodes unblocked by each node's completion.
    dependents: Vec<Vec<usize>>,
    /// Nodes whose prerequisites have all completed, not yet claimed.
    ready: VecDeque<usize>,
    /// Nodes not yet completed (runnable, running, or still blocked).
    remaining: usize,
    panicked: usize,
    payload: Option<Box<dyn Any + Send + 'static>>,
}

/// Handle to a node added to a [`GraphBuilder`]; pass it to
/// [`GraphBuilder::submit_after`] to order later nodes after it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(usize);

struct GraphNodeSpec<'env> {
    job: Box<dyn FnOnce() + Send + 'env>,
    deps: Vec<usize>,
}

/// Builder for a small dependency graph of jobs, executed by
/// [`ThreadPool::run_graph`]. Nodes may only depend on previously added
/// nodes, so the graph is acyclic by construction. Jobs may borrow from
/// the caller's frame (no `'static` bound), exactly like
/// [`ThreadPool::map`] jobs.
pub struct GraphBuilder<'env> {
    nodes: Vec<GraphNodeSpec<'env>>,
}

impl<'env> GraphBuilder<'env> {
    pub fn new() -> GraphBuilder<'env> {
        GraphBuilder { nodes: Vec::new() }
    }

    /// [`GraphBuilder::new`] with room for `n` nodes — callers like the
    /// wavefront scan engine know their node count (pieces +
    /// per-direction continuations per plane) up front.
    pub fn with_capacity(n: usize) -> GraphBuilder<'env> {
        GraphBuilder { nodes: Vec::with_capacity(n) }
    }

    /// Add a root node (no prerequisites); runnable immediately.
    pub fn submit<F: FnOnce() + Send + 'env>(&mut self, job: F) -> NodeId {
        self.submit_after(&[], job)
    }

    /// Add a continuation: `job` runs only after every node in `deps`
    /// has completed. Dependencies must be nodes already added to this
    /// builder (the DAG invariant, checked).
    pub fn submit_after<F: FnOnce() + Send + 'env>(&mut self, deps: &[NodeId], job: F) -> NodeId {
        let id = self.nodes.len();
        for d in deps {
            assert!(d.0 < id, "graph dependency on a node not yet submitted");
        }
        self.nodes.push(GraphNodeSpec {
            job: Box::new(job),
            deps: deps.iter().map(|d| d.0).collect(),
        });
        NodeId(id)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

impl<'env> Default for GraphBuilder<'env> {
    fn default() -> Self {
        GraphBuilder::new()
    }
}

/// Error returned by [`ThreadPool::try_map`] when at least one job
/// panicked. Holds the first panic payload; the remaining jobs still ran
/// to completion before the call returned.
pub struct MapError {
    /// How many of the call's jobs panicked.
    pub panicked: usize,
    payload: Box<dyn Any + Send + 'static>,
}

impl MapError {
    /// Best-effort text of the first panic payload.
    pub fn message(&self) -> String {
        super::panic_message(&*self.payload)
    }

    /// The first panic payload, e.g. for `std::panic::resume_unwind`.
    pub fn into_payload(self) -> Box<dyn Any + Send + 'static> {
        self.payload
    }
}

impl std::fmt::Debug for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MapError {{ panicked: {}, message: {:?} }}", self.panicked, self.message())
    }
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} pool job(s) panicked: {}", self.panicked, self.message())
    }
}

impl std::error::Error for MapError {}

pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gspn2-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the driver).
    pub fn for_host() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    /// The process-wide shared pool: built once, never torn down. All
    /// scan / serving / bench parallelism routes through this handle so
    /// the process runs exactly one persistent worker set.
    pub fn global() -> &'static ThreadPool {
        GLOBAL.get_or_init(ThreadPool::for_host)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Jobs currently queued or running, across all submitters — the
    /// pool-occupancy signal consumers like the serving batcher use to
    /// size release decisions. A snapshot: it can be stale by the time
    /// the caller acts on it, which is fine for scheduling heuristics.
    pub fn load(&self) -> usize {
        self.shared.in_flight.load(Ordering::SeqCst)
    }

    /// Whether the pool already holds at least as much queued/running
    /// work as it has workers (no idle capacity right now). A coarse
    /// introspection helper: the serving batcher no longer consumes
    /// this bool — release sizing goes through the scan planner's
    /// graded `eager_release_min`, which reads [`ThreadPool::load`]
    /// directly.
    pub fn saturated(&self) -> bool {
        self.load() >= self.threads()
    }

    /// Non-blocking work-assist: pop ONE entry off the global queue and
    /// run it on the calling thread, dispatching exactly as a worker
    /// would (`Exec` jobs run directly; `Call`/`Graph` tickets claim one
    /// job of their call, and a stale ticket — the submitting caller
    /// already helped its jobs to completion — is a no-op). Returns
    /// `false` when the queue was empty.
    ///
    /// This is what lets a thread that must *wait on a condition another
    /// pool job will establish* (e.g. a chained-scan chunk spinning on
    /// its predecessor's published prefix) drain the queue instead of
    /// burning a core: `while !done { if !pool.try_assist() { spin } }`.
    /// Unlike the own-call helping inside [`ThreadPool::try_map`], this
    /// runs *any* submitter's work, so only call it from code prepared
    /// to execute a stranger's job (workers' own loop semantics).
    pub fn try_assist(&self) -> bool {
        let work = self.shared.queue.lock().unwrap().pop_front();
        match work {
            None => false,
            Some(Work::Exec(job)) => {
                run_one(&self.shared, job);
                true
            }
            Some(Work::Call(call)) => {
                let job = call.jobs.lock().unwrap().pop_front();
                if let Some(job) = job {
                    run_one(&self.shared, job);
                }
                true
            }
            Some(Work::Graph(call)) => {
                let _ = run_graph_node(&self.shared, &call);
                true
            }
        }
    }

    /// Fire-and-forget. A panic in `job` is caught and logged; use
    /// [`ThreadPool::try_map`] when the caller needs the outcome.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push_back(Work::Exec(Box::new(job)));
        self.shared.available.notify_one();
    }

    /// Block until the queue is fully drained (every job from every
    /// submitter has finished). This is a pool-global rendezvous for
    /// `execute`-style usage; `map`/`try_map` wait on their own per-call
    /// latch instead and are unaffected by other submitters.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Fork-join map: applies `f` to each item in parallel, preserving
    /// order. Items, results, and `f` may borrow from the caller's frame
    /// (no `'static` bound): the call returns only after every job has
    /// run. If any job panics the payload is rethrown in the caller —
    /// use [`ThreadPool::try_map`] to get it as an error instead.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        match self.try_map(items, f) {
            Ok(out) => out,
            Err(e) => std::panic::resume_unwind(e.into_payload()),
        }
    }

    /// Fork-join map returning `Err(MapError)` if any job panicked
    /// (carrying the first payload) instead of unwinding the caller.
    /// All jobs run to completion either way.
    pub fn try_map<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>, MapError>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let latch = Latch::new(n);
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let call = Arc::new(CallJobs { jobs: Mutex::new(VecDeque::with_capacity(n)) });
        {
            let f = &f;
            let slots = &slots;
            let latch = &latch;
            {
                let mut cj = call.jobs.lock().unwrap();
                for (i, item) in items.into_iter().enumerate() {
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        match catch_unwind(AssertUnwindSafe(|| f(item))) {
                            Ok(r) => {
                                *slots[i].lock().unwrap() = Some(r);
                                latch.complete(None);
                            }
                            Err(payload) => latch.complete(Some(payload)),
                        }
                    });
                    // SAFETY: the latch wait below keeps this frame (and
                    // every borrow inside the job) alive until the job
                    // has finished running; nothing drops a job unrun —
                    // the call's job list is drained by exactly this
                    // call's helper and by ticket-holding workers while
                    // `&self` borrows the pool, and any ticket outliving
                    // this call finds the list already empty.
                    cj.push_back(unsafe {
                        std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                    });
                }
            }
            self.shared.in_flight.fetch_add(n, Ordering::SeqCst);
            {
                let mut q = self.shared.queue.lock().unwrap();
                q.extend((0..n).map(|_| Work::Call(Arc::clone(&call))));
            }
            if n == 1 {
                self.shared.available.notify_one();
            } else {
                self.shared.available.notify_all();
            }

            // Work-helping wait: pop THIS call's jobs straight off its
            // own list — O(1) per job, no scan of the global queue — and
            // run them until the latch opens. Helping only our own jobs
            // keeps nested submission deadlock-free (a caller can always
            // drive its own jobs by itself, workers or not) without ever
            // executing a stranger's long-running job on a
            // latency-sensitive caller. Once our list is empty, the
            // remaining jobs are running on other threads, so a plain
            // latch wait cannot stall.
            loop {
                let job = call.jobs.lock().unwrap().pop_front();
                match job {
                    Some(job) => run_one(&self.shared, job),
                    None => {
                        let mut st = latch.state.lock().unwrap();
                        while st.remaining > 0 {
                            st = latch.open.wait(st).unwrap();
                        }
                        break;
                    }
                }
                let st = latch.state.lock().unwrap();
                if st.remaining == 0 {
                    break;
                }
            }
        }

        let st = latch.state.into_inner().unwrap();
        if st.panicked > 0 {
            return Err(MapError {
                panicked: st.panicked,
                payload: st.payload.expect("panicked > 0 implies a stored payload"),
            });
        }
        Ok(slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap()
                    .expect("latch opened with no panics: every slot is filled")
            })
            .collect())
    }

    /// Execute a dependency graph of jobs: every node runs exactly once,
    /// a node only after all its prerequisites, independent nodes in
    /// parallel. Blocks until the whole graph has completed (so, like
    /// [`ThreadPool::map`], node jobs may borrow from the caller's
    /// frame). Dependency-aware submission is what lets a dependent
    /// stage start the moment *its* prerequisites finish instead of
    /// behind a global barrier — wavefront scheduling.
    ///
    /// Execution reuses the `map` machinery: the graph keeps a per-call
    /// ready list, the global queue holds one ticket per ready node
    /// (stale tickets are no-ops), and the submitting caller
    /// work-helps — it drains ready nodes itself, waking whenever a
    /// completion enables new ones, so a graph completes even when every
    /// worker is busy elsewhere (nested submission stays deadlock-free:
    /// a node may itself call `map`/`run_graph` on the same pool).
    ///
    /// If any node panics the first payload is returned as a
    /// [`MapError`]; the remaining nodes (including dependents of the
    /// panicking node) still run to completion first, mirroring
    /// `try_map`.
    pub fn run_graph(&self, builder: GraphBuilder<'_>) -> Result<(), MapError> {
        let n = builder.nodes.len();
        if n == 0 {
            return Ok(());
        }
        let mut jobs: Vec<Option<Job>> = Vec::with_capacity(n);
        let mut waiting = Vec::with_capacity(n);
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut ready = VecDeque::new();
        for (i, node) in builder.nodes.into_iter().enumerate() {
            // SAFETY: the wait loop below keeps this frame (and every
            // borrow inside the job) alive until every node has run;
            // nothing drops a node unrun — ready nodes are drained by
            // exactly this call's helper and by ticket-holding workers
            // while `&self` borrows the pool, and any ticket outliving
            // this call finds the ready list empty.
            jobs.push(Some(unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(node.job)
            }));
            let ndeps = node.deps.len();
            for dep in node.deps {
                dependents[dep].push(i);
            }
            waiting.push(ndeps);
            if ndeps == 0 {
                ready.push_back(i);
            }
        }
        let n_ready = ready.len();
        let call = Arc::new(GraphCall {
            state: Mutex::new(GraphState {
                jobs,
                waiting,
                dependents,
                ready,
                remaining: n,
                panicked: 0,
                payload: None,
            }),
            progress: Condvar::new(),
        });
        self.shared.in_flight.fetch_add(n, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.extend((0..n_ready).map(|_| Work::Graph(Arc::clone(&call))));
        }
        self.shared.available.notify_all();

        // Work-helping wait: claim ready nodes of THIS graph and run
        // them on the calling thread; when none are ready, sleep on the
        // graph's progress condvar, which completions ping both when
        // they enable new nodes and when the last node finishes. The
        // ready check and the wait share one mutex, so a wakeup can
        // never slip between them.
        loop {
            if !run_graph_node(&self.shared, &call) {
                let mut st = call.state.lock().unwrap();
                while st.remaining > 0 && st.ready.is_empty() {
                    st = call.progress.wait(st).unwrap();
                }
                if st.remaining == 0 {
                    break;
                }
                // New ready nodes appeared: loop back and help.
            } else {
                let st = call.state.lock().unwrap();
                if st.remaining == 0 {
                    break;
                }
            }
        }

        let mut st = call.state.lock().unwrap();
        if st.panicked > 0 {
            return Err(MapError {
                panicked: st.panicked,
                payload: st
                    .payload
                    .take()
                    .expect("panicked > 0 implies a stored payload"),
            });
        }
        Ok(())
    }
}

/// Claim and run one ready node of `call` (used by both workers holding
/// tickets and the submitting caller's helping wait). Returns false if
/// no node was ready to claim. Completion bookkeeping — enabling
/// dependents, pushing tickets for them, waking the helping caller —
/// happens here, under the graph mutex.
fn run_graph_node(sh: &Shared, call: &Arc<GraphCall>) -> bool {
    let claimed = {
        let mut st = call.state.lock().unwrap();
        match st.ready.pop_front() {
            Some(i) => st.jobs[i].take().map(|job| (i, job)),
            None => None,
        }
    };
    let Some((i, job)) = claimed else {
        return false;
    };
    let payload = catch_unwind(AssertUnwindSafe(job)).err();
    // Completion: enable dependents under the graph mutex, then mirror
    // run_one's pool-global in-flight bookkeeping.
    let newly_ready = {
        let mut st = call.state.lock().unwrap();
        st.remaining -= 1;
        if let Some(p) = payload {
            st.panicked += 1;
            if st.payload.is_none() {
                st.payload = Some(p);
            }
        }
        let mut enabled = 0usize;
        let deps: Vec<usize> = st.dependents[i].drain(..).collect();
        for d in deps {
            st.waiting[d] -= 1;
            if st.waiting[d] == 0 {
                st.ready.push_back(d);
                enabled += 1;
            }
        }
        if enabled > 0 || st.remaining == 0 {
            call.progress.notify_all();
        }
        enabled
    };
    if newly_ready > 0 {
        {
            let mut q = sh.queue.lock().unwrap();
            q.extend((0..newly_ready).map(|_| Work::Graph(Arc::clone(call))));
        }
        if newly_ready == 1 {
            sh.available.notify_one();
        } else {
            sh.available.notify_all();
        }
    }
    if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
        let _g = sh.done_lock.lock().unwrap();
        sh.done.notify_all();
    }
    true
}

/// Execute one queued job with the in-flight bookkeeping shared by
/// workers and helping callers.
fn run_one(sh: &Shared, job: Job) {
    // A panicking job must not wedge wait_idle: decrement via guard.
    struct Dec<'a>(&'a Shared);
    impl Drop for Dec<'_> {
        fn drop(&mut self) {
            if self.0.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                let _g = self.0.done_lock.lock().unwrap();
                self.0.done.notify_all();
            }
        }
    }
    let _dec = Dec(sh);
    // Map jobs catch their own panics (routing the payload to the
    // call's latch); this outer guard only fires for `execute` jobs.
    if catch_unwind(AssertUnwindSafe(job)).is_err() {
        crate::util::logging::warn("threadpool", "worker job panicked");
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let work = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(work) = q.pop_front() {
                    break work;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        match work {
            Work::Exec(job) => run_one(&sh, job),
            // A map ticket: run one of that call's jobs. An empty list
            // means the submitting caller already helped every job to
            // completion — the stale ticket is a no-op (its jobs were
            // accounted when they actually ran).
            Work::Call(call) => {
                let job = call.jobs.lock().unwrap().pop_front();
                if let Some(job) = job {
                    run_one(&sh, job);
                }
            }
            // A graph ticket: claim one ready node of that graph (a
            // stale ticket — the caller helped the node first — is a
            // no-op, same as map tickets).
            Work::Graph(call) => {
                let _ = run_graph_node(&sh, &call);
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_actually_parallel() {
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        pool.map(vec![(); 8], |_| std::thread::sleep(std::time::Duration::from_millis(40)));
        // 8 x 40ms on 4 threads (+ the helping caller) ~ 80ms; serial
        // would be 320ms.
        assert!(t0.elapsed() < std::time::Duration::from_millis(250));
    }

    #[test]
    fn map_borrows_caller_frame() {
        // No 'static bound: jobs read a stack-local table by reference.
        let pool = ThreadPool::new(2);
        let table: Vec<u64> = (0..32).map(|i| i * 10).collect();
        let out = pool.map((0..32usize).collect::<Vec<_>>(), |i| table[i] + 1);
        assert_eq!(out, (0..32).map(|i| i * 10 + 1).collect::<Vec<u64>>());
    }

    #[test]
    fn survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let ok = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&ok);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn try_map_empty_is_ok() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.try_map(Vec::<u32>::new(), |x| x).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn try_map_surfaces_panic_as_error() {
        let pool = ThreadPool::new(2);
        let err = pool
            .try_map((0..8).collect::<Vec<u32>>(), |x| {
                if x == 3 {
                    panic!("job {x} exploded");
                }
                x * 2
            })
            .unwrap_err();
        assert_eq!(err.panicked, 1);
        assert!(err.message().contains("exploded"), "{}", err.message());
        // The pool is not poisoned: the next call works.
        let ok = pool.try_map(vec![1u32, 2, 3], |x| x + 1).unwrap();
        assert_eq!(ok, vec![2, 3, 4]);
    }

    #[test]
    fn map_rethrows_panic_payload_in_caller() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![0u32, 1, 2], |x| {
                if x == 1 {
                    panic!("rethrown payload");
                }
                x
            })
        }));
        let payload = caught.expect_err("map must propagate the panic");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("rethrown payload"), "{msg}");
        assert_eq!(pool.map(vec![5u32], |x| x), vec![5]);
    }

    #[test]
    fn map_self_helps_when_workers_are_busy() {
        // The single worker is parked on a blocking execute job; map
        // must complete anyway by running its own jobs on the calling
        // thread (selective helping).
        let pool = ThreadPool::new(1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            let _ = rx.recv();
        });
        let out = pool.map(vec![1u32, 2, 3], |x| x * 2);
        assert_eq!(out, vec![2, 4, 6]);
        tx.send(()).unwrap();
        pool.wait_idle();
    }

    #[test]
    fn nested_map_inside_a_job_completes() {
        // A 1-thread pool forces the helping path: the outer job's
        // thread must drain the inner jobs itself.
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![10u64, 20], |base| {
            pool.map(vec![1u64, 2, 3], |d| base + d).iter().sum::<u64>()
        });
        assert_eq!(out, vec![36, 66]);
    }

    #[test]
    fn load_and_saturation_signal() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.load(), 0);
        assert!(!pool.saturated());
        let barrier = Arc::new(std::sync::Barrier::new(3));
        for _ in 0..2 {
            let b = Arc::clone(&barrier);
            pool.execute(move || {
                b.wait();
            });
        }
        assert!(pool.load() >= 2);
        assert!(pool.saturated());
        barrier.wait();
        pool.wait_idle();
        assert_eq!(pool.load(), 0);
        assert!(!pool.saturated());
    }

    /// The per-call job-list regression: park the only worker so the
    /// caller self-helps its whole map — every ticket it left in the
    /// global queue goes stale. The worker must skip them and keep
    /// serving fresh work.
    #[test]
    fn stale_tickets_are_noops() {
        let pool = ThreadPool::new(1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            let _ = rx.recv();
        });
        let out = pool.map((0..64u32).collect::<Vec<_>>(), |x| x + 1);
        assert_eq!(out, (1..=64).collect::<Vec<u32>>());
        tx.send(()).unwrap();
        pool.wait_idle();
        assert_eq!(pool.map(vec![7u32], |x| x * 2), vec![14]);
    }

    #[test]
    fn global_pool_is_one_instance() {
        let a = ThreadPool::global();
        let b = ThreadPool::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.threads() >= 1);
        assert_eq!(a.map(vec![2u32, 3], |x| x * x), vec![4, 9]);
    }

    // -----------------------------------------------------------------
    // Dependency-graph API
    // -----------------------------------------------------------------

    #[test]
    fn graph_empty_is_ok() {
        let pool = ThreadPool::new(2);
        pool.run_graph(GraphBuilder::new()).unwrap();
    }

    #[test]
    fn graph_runs_continuations_after_prerequisites() {
        let pool = ThreadPool::new(4);
        let log = Mutex::new(Vec::<u32>::new());
        let mut g = GraphBuilder::new();
        let a = g.submit(|| log.lock().unwrap().push(1));
        let b = g.submit_after(&[a], || log.lock().unwrap().push(2));
        let _c = g.submit_after(&[b], || log.lock().unwrap().push(3));
        pool.run_graph(g).unwrap();
        assert_eq!(*log.lock().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn graph_diamond_joins_both_branches() {
        // a -> (b, c) -> d: d must observe both branch effects.
        let pool = ThreadPool::new(4);
        let cell = Mutex::new((0u64, 0u64, 0u64));
        let mut g = GraphBuilder::new();
        let a = g.submit(|| cell.lock().unwrap().0 = 5);
        let b = g.submit_after(&[a], || {
            let mut c = cell.lock().unwrap();
            c.1 = c.0 * 2;
        });
        let c = g.submit_after(&[a], || {
            let mut c = cell.lock().unwrap();
            c.2 = c.0 * 3;
        });
        let joined = Mutex::new(0u64);
        g.submit_after(&[b, c], || {
            let c = cell.lock().unwrap();
            *joined.lock().unwrap() = c.1 + c.2;
        });
        pool.run_graph(g).unwrap();
        assert_eq!(*joined.lock().unwrap(), 25);
    }

    #[test]
    fn graph_wide_fan_in_and_out() {
        // 32 roots -> 1 join -> 32 leaves, checking counts and ordering
        // constraints (join sees all roots; every leaf sees the join).
        let pool = ThreadPool::new(4);
        let roots_done = Arc::new(AtomicU64::new(0));
        let join_seen = Arc::new(AtomicU64::new(0));
        let leaves_ok = Arc::new(AtomicU64::new(0));
        let mut g = GraphBuilder::new();
        let roots: Vec<NodeId> = (0..32)
            .map(|_| {
                let r = Arc::clone(&roots_done);
                g.submit(move || {
                    r.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        let join = {
            let (r, j) = (Arc::clone(&roots_done), Arc::clone(&join_seen));
            g.submit_after(&roots, move || {
                j.store(r.load(Ordering::SeqCst), Ordering::SeqCst);
            })
        };
        for _ in 0..32 {
            let (j, l) = (Arc::clone(&join_seen), Arc::clone(&leaves_ok));
            g.submit_after(&[join], move || {
                if j.load(Ordering::SeqCst) == 32 {
                    l.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        pool.run_graph(g).unwrap();
        assert_eq!(join_seen.load(Ordering::SeqCst), 32);
        assert_eq!(leaves_ok.load(Ordering::SeqCst), 32);
        pool.wait_idle();
        assert_eq!(pool.load(), 0);
    }

    /// The nested-continuation deadlock regression (the wavefront
    /// engine's shape): a 1-thread pool whose only worker is parked on a
    /// blocking job, so the submitting caller must self-drive the whole
    /// graph — including continuations enabled mid-run — and a
    /// continuation that itself submits a nested `map` to the same pool.
    #[test]
    fn graph_nested_continuations_complete_on_busy_single_thread_pool() {
        let pool = ThreadPool::new(1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            let _ = rx.recv();
        });
        let sum = Mutex::new(0u64);
        let mut g = GraphBuilder::new();
        let a = g.submit(|| *sum.lock().unwrap() += 1);
        let b = g.submit_after(&[a], || {
            // Nested fork-join from inside a graph continuation.
            let part: u64 = pool.map(vec![10u64, 20, 30], |x| x + 1).iter().sum();
            *sum.lock().unwrap() += part;
        });
        g.submit_after(&[b], || *sum.lock().unwrap() *= 2);
        pool.run_graph(g).unwrap();
        assert_eq!(*sum.lock().unwrap(), (1 + 63) * 2);
        tx.send(()).unwrap();
        pool.wait_idle();
        // Stale graph tickets left in the queue are no-ops.
        assert_eq!(pool.map(vec![4u32], |x| x * 2), vec![8]);
    }

    /// The per-direction wavefront shape (the fused scan engine's
    /// production graph): per "plane", K chained drain continuations,
    /// each depending on its own fan of piece nodes plus the previous
    /// drain. Asserts the ordering contract the engine relies on —
    /// drain k sees all of its own pieces and every earlier drain of
    /// its plane — across planes running concurrently.
    #[test]
    fn graph_per_direction_continuation_chains() {
        let pool = ThreadPool::new(4);
        const PLANES: usize = 3;
        const DIRS: usize = 4;
        const PIECES: usize = 2;
        let pieces_done = Arc::new(AtomicU64::new(0)); // bit per (p, k, s)
        let drain_order: Arc<Mutex<Vec<(usize, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let ok = Arc::new(AtomicU64::new(0));
        let mut g = GraphBuilder::with_capacity(PLANES * DIRS * (PIECES + 1));
        for p in 0..PLANES {
            let mut prev: Option<NodeId> = None;
            for k in 0..DIRS {
                let mut deps = Vec::with_capacity(PIECES + 1);
                for s in 0..PIECES {
                    let done = Arc::clone(&pieces_done);
                    deps.push(g.submit(move || {
                        done.fetch_or(1 << (p * DIRS * PIECES + k * PIECES + s), Ordering::SeqCst);
                    }));
                }
                if let Some(prev) = prev {
                    deps.push(prev);
                }
                let (done, order, okc) = (
                    Arc::clone(&pieces_done),
                    Arc::clone(&drain_order),
                    Arc::clone(&ok),
                );
                prev = Some(g.submit_after(&deps, move || {
                    // Own pieces (and, transitively, all earlier
                    // directions' pieces of this plane) must be done.
                    let want: u64 = ((1 << ((k + 1) * PIECES)) - 1) << (p * DIRS * PIECES);
                    let have = done.load(Ordering::SeqCst);
                    if have & want == want {
                        okc.fetch_add(1, Ordering::SeqCst);
                    }
                    order.lock().unwrap().push((p, k));
                }));
            }
        }
        pool.run_graph(g).unwrap();
        assert_eq!(ok.load(Ordering::SeqCst), (PLANES * DIRS) as u64);
        // Within each plane the drains ran in direction order.
        let order = drain_order.lock().unwrap();
        for p in 0..PLANES {
            let ks: Vec<usize> =
                order.iter().filter(|&&(pp, _)| pp == p).map(|&(_, k)| k).collect();
            assert_eq!(ks, vec![0, 1, 2, 3], "plane {p} drains out of order");
        }
    }

    #[test]
    fn graph_panic_reports_error_and_still_runs_rest() {
        let pool = ThreadPool::new(2);
        let ran = Arc::new(AtomicU64::new(0));
        let mut g = GraphBuilder::new();
        let a = g.submit(|| panic!("graph node exploded"));
        let r = Arc::clone(&ran);
        g.submit_after(&[a], move || {
            r.fetch_add(1, Ordering::SeqCst);
        });
        let r2 = Arc::clone(&ran);
        g.submit(move || {
            r2.fetch_add(1, Ordering::SeqCst);
        });
        let err = pool.run_graph(g).unwrap_err();
        assert_eq!(err.panicked, 1);
        assert!(err.message().contains("exploded"), "{}", err.message());
        assert_eq!(ran.load(Ordering::SeqCst), 2);
        // The pool is not poisoned.
        assert_eq!(pool.map(vec![1u32], |x| x + 1), vec![2]);
    }

    #[test]
    fn concurrent_graphs_with_interleaved_maps() {
        let pool = ThreadPool::new(4);
        for _ in 0..50 {
            std::thread::scope(|s| {
                let p = &pool;
                let h1 = s.spawn(move || {
                    let acc = Mutex::new(0u64);
                    let mut g = GraphBuilder::new();
                    let roots: Vec<NodeId> = (0..8u64)
                        .map(|i| {
                            let acc = &acc;
                            g.submit(move || *acc.lock().unwrap() += i)
                        })
                        .collect();
                    let joined = Mutex::new(0u64);
                    g.submit_after(&roots, || {
                        *joined.lock().unwrap() = *acc.lock().unwrap()
                    });
                    p.run_graph(g).unwrap();
                    assert_eq!(*joined.lock().unwrap(), 28);
                });
                let h2 = s.spawn(move || {
                    let out = p.map((0..32u64).collect::<Vec<_>>(), |x| x * 2);
                    assert_eq!(out, (0..32).map(|x| x * 2).collect::<Vec<u64>>());
                });
                h1.join().unwrap();
                h2.join().unwrap();
            });
        }
        pool.wait_idle();
        assert_eq!(pool.load(), 0);
    }

    /// The regression for the completion race: two threads run `map`
    /// concurrently while `execute` jobs churn the pool-global counter.
    /// The old `wait_idle`-based map returned early/late or hit
    /// `expect("job did not run")` under exactly this interleaving.
    /// 100 consecutive rounds as demanded by the acceptance criteria.
    #[test]
    fn concurrent_maps_with_interleaved_executes() {
        let pool = ThreadPool::new(4);
        let noise = Arc::new(AtomicU64::new(0));
        for round in 0..100u64 {
            std::thread::scope(|s| {
                let p = &pool;
                let items = || (0..64u64).collect::<Vec<_>>();
                let h1 = s.spawn(move || p.map(items(), move |x| x * 2 + round));
                let h2 = s.spawn(move || p.map(items(), move |x| x * 3 + round));
                for _ in 0..16 {
                    let c = Arc::clone(&noise);
                    pool.execute(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
                let r1 = h1.join().expect("map caller 1");
                let r2 = h2.join().expect("map caller 2");
                assert_eq!(r1, (0..64).map(|x| x * 2 + round).collect::<Vec<u64>>());
                assert_eq!(r2, (0..64).map(|x| x * 3 + round).collect::<Vec<u64>>());
            });
        }
        pool.wait_idle();
        assert_eq!(noise.load(Ordering::SeqCst), 100 * 16);
    }

    #[test]
    fn try_assist_on_empty_queue_is_false() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
        assert!(!pool.try_assist());
    }

    /// A non-worker thread drains queued work via `try_assist` while the
    /// only worker is parked — the chained-scan wait loop's contract.
    #[test]
    fn try_assist_drains_queue_from_caller_thread() {
        let pool = ThreadPool::new(1);
        let (entered_tx, entered_rx) = std::sync::mpsc::channel::<()>();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            entered_tx.send(()).unwrap();
            let _ = release_rx.recv();
        });
        // The worker is provably inside the blocking job before we queue
        // more, so every later pop below is ours.
        entered_rx.recv().unwrap();
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        for _ in 0..5 {
            assert!(pool.try_assist());
        }
        assert_eq!(counter.load(Ordering::SeqCst), 5);
        assert!(!pool.try_assist());
        release_tx.send(()).unwrap();
        pool.wait_idle();
    }

    /// `try_assist` dispatches map tickets like a worker: a parked-pool
    /// map submitted from another thread completes when a third thread
    /// assists, and stale tickets (if the submitting caller helped
    /// first) stay harmless no-ops.
    #[test]
    fn try_assist_runs_map_tickets() {
        let pool = ThreadPool::new(1);
        let (entered_tx, entered_rx) = std::sync::mpsc::channel::<()>();
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            entered_tx.send(()).unwrap();
            let _ = release_rx.recv();
        });
        entered_rx.recv().unwrap();
        std::thread::scope(|s| {
            let p = &pool;
            let mapper = s.spawn(move || p.map((0..8u64).collect::<Vec<_>>(), |x| x + 1));
            // Assist until the mapper's jobs are gone; its own helping
            // races us, so both false and stale-ticket pops are fine.
            let out = loop {
                let _ = p.try_assist();
                if mapper.is_finished() {
                    break mapper.join().unwrap();
                }
                std::hint::spin_loop();
            };
            assert_eq!(out, (1..=8).collect::<Vec<u64>>());
        });
        release_tx.send(()).unwrap();
        pool.wait_idle();
    }
}
