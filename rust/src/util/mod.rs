//! Substrate utilities built from scratch for this repo (no general-purpose
//! crates beyond `xla`/`anyhow` are vendored): PRNG, JSON, TOML, logging,
//! CLI parsing, a thread pool, statistics, a property-testing framework,
//! and a criterion-style bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod toml;

pub use json::Json;
pub use rng::Rng;
pub use threadpool::{GraphBuilder, MapError, NodeId, ThreadPool};
