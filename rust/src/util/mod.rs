//! Substrate utilities built from scratch for this repo (no general-purpose
//! crates beyond `xla`/`anyhow` are vendored): PRNG, JSON, TOML, logging,
//! CLI parsing, a thread pool, statistics, a property-testing framework,
//! and a criterion-style bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod toml;
pub mod workspace;

pub use json::Json;
pub use rng::Rng;
pub use threadpool::{GraphBuilder, MapError, NodeId, ThreadPool};
pub use workspace::{BlockBoard, BufferPool, Lease, PoolStats};

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// `Mutex::lock().unwrap()` turns one panicking job into a cascade: the
/// first panic poisons the lock, and every later accessor dies with a
/// `PoisonError` that buries the original payload (a serving worker's
/// metrics mutex, or a scan graph's hand-off slot). Everything in this
/// crate that locks shared state across panic boundaries — pool
/// hand-off slots, coordinator metrics/queues — wants the data anyway:
/// the guarded values are plain counters/buffers whose invariants do
/// not span the panic, so recovering the guard is always safe here.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Best-effort text of a panic payload (`String` or `&'static str`
/// panics; anything else gets a placeholder). The one payload-to-text
/// policy shared by [`MapError::message`] and the serving worker's
/// caught-panic responses, so the two can't drift.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
