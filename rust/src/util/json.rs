//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used to read `artifacts/manifest.json` (the contract with the Python
//! AOT pipeline) and to write benchmark / experiment outputs under
//! `bench_out/`. No serde is vendored, so this is a small, strict,
//! dependency-free implementation: UTF-8 strings, f64 numbers, no comments.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `m.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- writing -----------------------------------------------------------
    pub fn write(&self) -> String {
        let mut s = String::new();
        self.write_into(&mut s, 0, false);
        s
    }

    pub fn write_pretty(&self) -> String {
        let mut s = String::new();
        self.write_into(&mut s, 0, true);
        s.push('\n');
        s
    }

    fn write_into(&self, s: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(true) => s.push_str("true"),
            Json::Bool(false) => s.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    s.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    s.push_str(&format!("{x}"));
                } else {
                    s.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(v) => write_escaped(s, v),
            Json::Arr(items) => {
                s.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    if pretty {
                        s.push('\n');
                        s.push_str(&" ".repeat(indent + 1));
                    }
                    it.write_into(s, indent + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    s.push('\n');
                    s.push_str(&" ".repeat(indent));
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    if pretty {
                        s.push('\n');
                        s.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(s, k);
                    s.push(':');
                    if pretty {
                        s.push(' ');
                    }
                    v.write_into(s, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    s.push('\n');
                    s.push_str(&" ".repeat(indent));
                }
                s.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &mut String, v: &str) {
    s.push('"');
    for ch in v.chars() {
        match ch {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
            c => s.push(c),
        }
    }
    s.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: only BMP needed for our files.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-sync on UTF-8 multibyte: step back and take the char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("c")
        );
        assert!(v.get("d").unwrap().is_null());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"entries":[{"name":"x","shape":[1,8,64,64],"ok":true}],"v":1.5}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.write()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.write_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let out = Json::Str("tab\t\"q\"".into()).write();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("tab\t\"q\""));
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(42.0).write(), "42");
        assert_eq!(Json::Num(0.5).write(), "0.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version":1,"entries":[
            {"name":"scan","file":"scan.hlo.txt","n_params":0,
             "inputs":[{"name":"x","shape":[1,8,64,64],"dtype":"f32"}],
             "outputs":[{"shape":[1,8,64,64],"dtype":"f32"}],
             "params_bin":null,"meta":{"kind":"scan"}}]}"#;
        let v = Json::parse(text).unwrap();
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("scan"));
        assert!(e.get("params_bin").unwrap().is_null());
        assert_eq!(
            e.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect::<Vec<_>>(),
            vec![1, 8, 64, 64]
        );
    }
}
