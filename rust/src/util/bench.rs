//! Criterion-style micro-benchmark harness (criterion is not vendored).
//!
//! Usage from a `[[bench]]` target with `harness = false`:
//!
//! ```ignore
//! let mut b = BenchSuite::new("scan");
//! b.bench("scan_64", || scan_once(&x));
//! b.finish();
//! ```
//!
//! Each benchmark is warmed up, then timed over adaptive batches until a
//! target measurement time is reached; results print mean / p50 / p95 and
//! are appended to `bench_out/<suite>.json` for the repro pipeline.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{fmt_time_ns, percentile};

pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(700),
            min_samples: 10,
            max_samples: 2000,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub samples: usize,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", self.name.as_str().into()),
            ("mean_ns", self.mean_ns.into()),
            ("p50_ns", self.p50_ns.into()),
            ("p95_ns", self.p95_ns.into()),
            ("samples", self.samples.into()),
            ("iters_per_sample", (self.iters_per_sample as usize).into()),
        ])
    }
}

pub struct BenchSuite {
    suite: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    out_dir: String,
    host: Vec<(&'static str, Json)>,
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Logical core count of the host, for the suite's `host` header.
pub fn core_count() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        Self::with_config(suite, BenchConfig::default())
    }

    pub fn with_config(suite: &str, cfg: BenchConfig) -> Self {
        println!("== bench suite: {suite} ==");
        Self {
            suite: suite.to_string(),
            cfg,
            results: Vec::new(),
            out_dir: std::env::var("GSPN2_BENCH_OUT").unwrap_or_else(|_| "bench_out".into()),
            host: vec![
                ("cores", core_count().into()),
                ("arch", std::env::consts::ARCH.into()),
            ],
        }
    }

    /// Stamp an extra `host` header field into the suite JSON (e.g. the
    /// detected SIMD kernel and lane width — injected by the bench
    /// binaries so this module stays independent of the scan crate).
    /// Later stamps of the same key win.
    pub fn stamp_host(&mut self, key: &'static str, value: Json) {
        self.host.retain(|(k, _)| *k != key);
        self.host.push((key, value));
    }

    /// Time `f`, which performs ONE logical operation per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.cfg.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;

        // Choose batch so one sample is ~measure/min_samples but >= 1 iter.
        let sample_target_ns =
            self.cfg.measure.as_nanos() as f64 / self.cfg.min_samples as f64;
        let iters = ((sample_target_ns / per_iter.max(1.0)).round() as u64).clamp(1, 1 << 22);

        let mut samples_ns: Vec<f64> = Vec::new();
        let t_all = Instant::now();
        while t_all.elapsed() < self.cfg.measure && samples_ns.len() < self.cfg.max_samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        while samples_ns.len() < self.cfg.min_samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }

        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let mut s = samples_ns.clone();
        let p50 = percentile(&mut s, 50.0);
        let p95 = percentile(&mut s, 95.0);
        let res = BenchResult {
            name: name.to_string(),
            mean_ns: mean,
            p50_ns: p50,
            p95_ns: p95,
            samples: samples_ns.len(),
            iters_per_sample: iters,
        };
        println!(
            "  {:<44} {:>12}/iter  (p50 {:>12}, p95 {:>12}, {} samples x {} iters)",
            name,
            fmt_time_ns(mean),
            fmt_time_ns(p50),
            fmt_time_ns(p95),
            res.samples,
            iters
        );
        self.results.push(res.clone());
        res
    }

    /// Record an externally measured scalar (e.g. simulated milliseconds).
    pub fn record_value(&mut self, name: &str, value: f64, unit: &str) {
        println!("  {name:<44} {value:>12.4} {unit}");
        self.results.push(BenchResult {
            name: format!("{name} [{unit}]"),
            mean_ns: value,
            p50_ns: value,
            p95_ns: value,
            samples: 1,
            iters_per_sample: 1,
        });
    }

    /// Write `bench_out/<suite>.json` and print a footer.
    pub fn finish(self) {
        let _ = std::fs::create_dir_all(&self.out_dir);
        let arr = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        let doc = Json::from_pairs(vec![
            ("suite", self.suite.as_str().into()),
            ("host", Json::from_pairs(self.host)),
            ("results", arr),
        ]);
        let path = format!("{}/{}.json", self.out_dir, self.suite);
        if let Err(e) = std::fs::write(&path, doc.write_pretty()) {
            eprintln!("bench: could not write {path}: {e}");
        } else {
            println!("== wrote {path} ({} results) ==", self.results.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_samples: 5,
            max_samples: 50,
        }
    }

    #[test]
    fn bench_measures_something() {
        let mut suite = BenchSuite::with_config("selftest", fast_cfg());
        let mut acc = 0u64;
        let r = suite.bench("u64 add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.samples >= 5);
    }

    #[test]
    fn host_header_stamps() {
        assert!(core_count() >= 1);
        let mut suite = BenchSuite::with_config("selftest3", fast_cfg());
        // Defaults are present; re-stamping a key replaces it.
        assert!(suite.host.iter().any(|(k, _)| *k == "cores"));
        assert!(suite.host.iter().any(|(k, _)| *k == "arch"));
        suite.stamp_host("simd", "avx2".into());
        suite.stamp_host("simd", "scalar".into());
        let simd: Vec<_> = suite.host.iter().filter(|(k, _)| *k == "simd").collect();
        assert_eq!(simd.len(), 1);
        assert_eq!(simd[0].1, Json::from("scalar"));
    }

    #[test]
    fn bench_orders_costs() {
        let mut suite = BenchSuite::with_config("selftest2", fast_cfg());
        let cheap = suite.bench("cheap", || {
            black_box(1 + 1);
        });
        let costly = suite.bench("costly", || {
            let mut s = 0u64;
            for i in 0..2000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(costly.mean_ns > cheap.mean_ns * 3.0);
    }
}
