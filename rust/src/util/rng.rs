//! Deterministic PRNGs: SplitMix64 (seeding) and xoshiro256** (streams).
//!
//! The coordinator, the synthetic-workload generators and the trainer all
//! need reproducible randomness; no `rand` crate is vendored, so this is a
//! from-scratch implementation of the standard public-domain algorithms
//! (Blackman & Vigna). Normal deviates come from the Box-Muller transform
//! with a cached spare.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot produce 4 zeros from
        // any seed, but keep the guard for clarity.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s, spare_normal: None }
    }

    /// Derive an independent stream (used to hand one RNG per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal deviate (Box-Muller with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Marsaglia polar method: exact N(0,1) like Box-Muller but with
        // one ln+sqrt per accepted pair and no sin/cos (the trig pair
        // dominated the profile of trace/test-data generation — see
        // EXPERIMENTS.md §Perf). Acceptance ratio is pi/4 ~ 78.5%.
        loop {
            let v1 = 2.0 * self.uniform() - 1.0;
            let v2 = 2.0 * self.uniform() - 1.0;
            let s = v1 * v1 + v2 * v2;
            if s >= 1.0 || s <= f64::MIN_POSITIVE {
                continue;
            }
            let k = (-2.0 * s.ln() / s).sqrt();
            self.spare_normal = Some(v2 * k);
            return v1 * k;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, std) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }

    /// Vector of N(0, std) samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal(&mut v, std);
        v
    }

    /// Exponential deviate with the given rate (inter-arrival times).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick an index according to non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total weight");
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(19);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(23);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
