//! Dense expansion of the recurrence into the block lower-triangular
//! matrix G of Eq. 4 — the linear-attention view of GSPN.
//!
//! For a single (batch, channel), `vec(h) = G vec(x)` where `vec` stacks
//! columns and block (i, j) equals `(prod_{k=j+1}^{i} w_k) Diag(lam_j)`.
//! This module exists to *validate* that view (tests assert the identity
//! against the O(HW) scan) and to expose attention-map introspection for
//! the examples.

use super::taps::{Taps, TAP_CENTER, TAP_DOWN, TAP_UP};
use crate::tensor::Tensor;

/// Dense H x H tridiagonal matrix for column `i` of (n, cw).
pub fn tridiag(taps: &Taps, n: usize, cw: usize, i: usize) -> Vec<Vec<f32>> {
    let h = taps.h;
    let mut m = vec![vec![0.0f32; h]; h];
    for r in 0..h {
        if r > 0 {
            m[r][r - 1] = taps.at(n, cw, TAP_UP, r, i);
        }
        m[r][r] = taps.at(n, cw, TAP_CENTER, r, i);
        if r + 1 < h {
            m[r][r + 1] = taps.at(n, cw, TAP_DOWN, r, i);
        }
    }
    m
}

fn matmul(a: &[Vec<f32>], b: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = a.len();
    let m = b[0].len();
    let k = b.len();
    let mut out = vec![vec![0.0f32; m]; n];
    for i in 0..n {
        for kk in 0..k {
            let aik = a[i][kk];
            if aik == 0.0 {
                continue;
            }
            for j in 0..m {
                out[i][j] += aik * b[kk][j];
            }
        }
    }
    out
}

/// Full G (W*H x W*H) for one (n, c): the affinity matrix of the
/// attention analogy. O(W^2 H^3) — validation/introspection only.
pub fn expand_g(taps: &Taps, lam: &Tensor, n: usize, c: usize) -> Vec<Vec<f32>> {
    let (h, w) = (taps.h, taps.w);
    let cw = if taps.cw == 1 { 0 } else { c };
    let ws: Vec<Vec<Vec<f32>>> = (0..w).map(|i| tridiag(taps, n, cw, i)).collect();
    let mut g = vec![vec![0.0f32; w * h]; w * h];
    for j in 0..w {
        // Lam_j as a diagonal block.
        let mut block: Vec<Vec<f32>> = (0..h)
            .map(|r| {
                let mut row = vec![0.0f32; h];
                row[r] = lam.at(&[n, c, r, j]);
                row
            })
            .collect();
        // Walk i = j, j+1, ... multiplying in w_{i} progressively.
        for i in j..w {
            if i > j {
                block = matmul(&ws[i], &block);
            }
            for r in 0..h {
                for q in 0..h {
                    g[i * h + r][j * h + q] = block[r][q];
                }
            }
        }
    }
    g
}

/// Effective receptive field: |G| row for the output pixel (r, i),
/// reshaped to (H, W). This is the "attention map" of pixel (r, i).
pub fn attention_map(taps: &Taps, lam: &Tensor, n: usize, c: usize, r: usize, i: usize) -> Tensor {
    let g = expand_g(taps, lam, n, c);
    let (h, w) = (taps.h, taps.w);
    let row = &g[i * h + r];
    let mut out = Tensor::zeros(&[h, w]);
    for j in 0..w {
        for q in 0..h {
            *out.at_mut(&[q, j]) = row[j * h + q].abs();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::core::scan_l2r;
    use crate::util::Rng;

    fn case(seed: u64, n: usize, c: usize, h: usize, w: usize, cw: usize) -> (Tensor, Taps, Tensor) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let raw = Tensor::randn(&[n, cw, 3, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        (x, Taps::normalize(&raw), lam)
    }

    #[test]
    fn eq4_identity_g_times_x_equals_scan() {
        let (x, taps, lam) = case(0, 1, 2, 4, 5, 1);
        let want = scan_l2r(&x, &taps, &lam, 0);
        for c in 0..2 {
            let g = expand_g(&taps, &lam, 0, c);
            // vec(x): columns stacked.
            let (h, w) = (4, 5);
            let xv: Vec<f32> = (0..w)
                .flat_map(|i| (0..h).map(move |r| (i, r)))
                .map(|(i, r)| x.at(&[0, c, r, i]))
                .collect();
            for i in 0..w {
                for r in 0..h {
                    let hv: f32 = g[i * h + r]
                        .iter()
                        .zip(&xv)
                        .map(|(a, b)| a * b)
                        .sum();
                    let got = want.at(&[0, c, r, i]);
                    assert!(
                        (hv - got).abs() < 1e-4,
                        "mismatch at ({r},{i}): {hv} vs {got}"
                    );
                }
            }
        }
    }

    #[test]
    fn g_is_block_lower_triangular() {
        let (_, taps, lam) = case(1, 1, 1, 3, 4, 1);
        let g = expand_g(&taps, &lam, 0, 0);
        let h = 3;
        for i in 0..4 {
            for j in (i + 1)..4 {
                for r in 0..h {
                    for q in 0..h {
                        assert_eq!(g[i * h + r][j * h + q], 0.0, "upper block nonzero");
                    }
                }
            }
        }
    }

    #[test]
    fn diagonal_blocks_are_lam() {
        let (_, taps, lam) = case(2, 1, 1, 3, 4, 1);
        let g = expand_g(&taps, &lam, 0, 0);
        for i in 0..4 {
            for r in 0..3 {
                assert!((g[i * 3 + r][i * 3 + r] - lam.at(&[0, 0, r, i])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn attention_mass_conserved_but_diffuses_with_distance() {
        // Row-stochastic taps conserve total mass exactly: each column
        // block of the query row sums to 1 regardless of distance (the
        // Stability-Context Condition). What distance changes is the
        // *concentration*: the near block is a delta (Diag(lam)), while
        // far blocks are smeared across rows by repeated tridiagonal
        // mixing — so the max entry decays even though the sum does not.
        let mut rng = Rng::new(3);
        let raw = Tensor::randn(&[1, 1, 3, 4, 8], &mut rng, 0.5);
        let taps = Taps::normalize(&raw);
        let lam = Tensor::full(&[1, 1, 4, 8], 1.0);
        let amap = attention_map(&taps, &lam, 0, 0, 2, 7);
        let near_sum: f32 = (0..4).map(|r| amap.at(&[r, 7])).sum();
        let far_sum: f32 = (0..4).map(|r| amap.at(&[r, 0])).sum();
        assert!((near_sum - 1.0).abs() < 1e-4, "near mass {near_sum}");
        assert!((far_sum - 1.0).abs() < 1e-4, "far mass {far_sum}");
        let near_max = (0..4).map(|r| amap.at(&[r, 7])).fold(0.0f32, f32::max);
        let far_max = (0..4).map(|r| amap.at(&[r, 0])).fold(0.0f32, f32::max);
        assert!(
            near_max > far_max + 0.05,
            "no diffusion: near max {near_max}, far max {far_max}"
        );
    }

    #[test]
    fn tridiag_row_stochastic() {
        let (_, taps, _) = case(4, 1, 1, 5, 3, 1);
        for i in 0..3 {
            let m = tridiag(&taps, 0, 0, i);
            for row in m {
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }
}
