//! The four directional passes (§3.2): top-to-bottom, bottom-to-top,
//! left-to-right, right-to-left, all expressed by reorienting the tensor
//! around the canonical left-to-right scan — exactly mirroring
//! `python/compile/kernels/ref.py`'s `to_canonical`/`from_canonical`.
//!
//! Combining the 3-neighbour kernel with the four passes yields dense
//! pairwise connectivity across the grid (the paper's full-context claim);
//! `merged_4dir` applies a learned convex combination over directions.

use super::core::scan_l2r;
use super::fused::{fused_merged_4dir, fused_merged_4dir_pool};
use super::taps::Taps;
use crate::tensor::Tensor;
use crate::util::ThreadPool;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    L2R,
    R2L,
    T2B,
    B2T,
}

pub const DIRECTIONS: [Direction; 4] =
    [Direction::L2R, Direction::R2L, Direction::T2B, Direction::B2T];

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::L2R => "l2r",
            Direction::R2L => "r2l",
            Direction::T2B => "t2b",
            Direction::B2T => "b2t",
        }
    }

    pub fn parse(s: &str) -> Option<Direction> {
        Some(match s {
            "l2r" => Direction::L2R,
            "r2l" => Direction::R2L,
            "t2b" => Direction::T2B,
            "b2t" => Direction::B2T,
            _ => return None,
        })
    }
}

/// Reorient (..., H, W) so the requested direction becomes l2r.
pub fn to_canonical(t: &Tensor, d: Direction) -> Tensor {
    match d {
        Direction::L2R => t.clone(),
        Direction::R2L => t.flip_last(),
        Direction::T2B => t.swap_last2(),
        Direction::B2T => t.swap_last2().flip_last(),
    }
}

/// Inverse of `to_canonical`.
pub fn from_canonical(t: &Tensor, d: Direction) -> Tensor {
    match d {
        Direction::L2R => t.clone(),
        Direction::R2L => t.flip_last(),
        Direction::T2B => t.swap_last2(),
        Direction::B2T => t.flip_last().swap_last2(),
    }
}

/// Directional scan; `taps` are given in canonical orientation (computed
/// from the reoriented feature map, as the model does).
pub fn scan_dir(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
) -> Tensor {
    let xc = to_canonical(x, d);
    let lamc = to_canonical(lam, d);
    let h = scan_l2r(&xc, taps, &lamc, kchunk);
    from_canonical(&h, d)
}

/// Softmax of the merge logits (shared by the serial path, the pooled
/// path, and [`super::compact`] so every merge stays bit-identical).
pub(crate) fn merge_weights(merge_logits: &[f32; 4]) -> [f32; 4] {
    let mx = merge_logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: [f32; 4] = std::array::from_fn(|k| (merge_logits[k] - mx).exp());
    let z: f32 = exps.iter().sum();
    std::array::from_fn(|k| exps[k] / z)
}

/// The serial reference composition of the four-direction merge: one
/// `scan_dir` per direction (with its `to_canonical`/`from_canonical`
/// materializations) and a separate weighted accumulation pass. Kept as
/// the bit-exact ground truth the fused engine ([`super::fused`]) is
/// pinned against; production callers go through [`merged_4dir`], which
/// routes to the fused path.
pub fn merged_4dir_ref(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    let wts = merge_weights(merge_logits);
    let mut out = Tensor::zeros(&x.shape);
    for (k, d) in DIRECTIONS.iter().enumerate() {
        let y = scan_dir(x, taps[k], lam, *d, kchunk);
        for (o, v) in out.data.iter_mut().zip(&y.data) {
            *o += wts[k] * v;
        }
    }
    out
}

/// Four directional scans merged by convex weights (softmaxed logits).
/// Routed through the column-staged fused engine — bit-identical to
/// [`merged_4dir_ref`] (pinned by property tests) with zero canonical /
/// directional intermediates.
pub fn merged_4dir(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    fused_merged_4dir(x, taps, lam, merge_logits, kchunk)
}

/// [`merged_4dir`] with the fused engine's plane loop submitted to a
/// shared pool in block-granular jobs (one job per block of planes,
/// sized off the pool width — not one per plane, and not one per
/// direction: directions merge in-pass inside each plane job, which is
/// what keeps the accumulation order, and therefore every bit, identical
/// to the serial path). In the low-occupancy regime (fewer planes than
/// pool workers, ≥ 256 canonical columns) the engine's scheduler
/// switches to the segment-parallel decomposition, whose arithmetic
/// follows the `scan_l2r_split` reference instead (same merge order,
/// segment-reassociated scans).
pub fn merged_4dir_pool(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_merged_4dir_pool(x, taps, lam, merge_logits, kchunk, pool)
}

/// [`merged_4dir`] over the process-wide shared pool.
pub fn merged_4dir_par(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    merged_4dir_pool(x, taps, lam, merge_logits, kchunk, ThreadPool::global())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};
    use crate::util::Rng;

    #[test]
    fn canonical_roundtrip_all_directions() {
        check("to/from canonical roundtrip", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 3);
            let h = g.int_in(1, 6);
            let w = g.int_in(1, 6);
            let t = Tensor::from_vec(&[n, c, h, w], g.normal_vec(n * c * h * w));
            for d in DIRECTIONS {
                let rt = from_canonical(&to_canonical(&t, d), d);
                ensure(rt == t, format!("roundtrip failed for {:?}", d))?;
            }
            Ok(())
        });
    }

    #[test]
    fn canonical_shapes() {
        let t = Tensor::zeros(&[1, 2, 3, 5]);
        assert_eq!(to_canonical(&t, Direction::L2R).shape, vec![1, 2, 3, 5]);
        assert_eq!(to_canonical(&t, Direction::T2B).shape, vec![1, 2, 5, 3]);
        assert_eq!(to_canonical(&t, Direction::B2T).shape, vec![1, 2, 5, 3]);
    }

    #[test]
    fn r2l_equals_flipped_l2r() {
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&[1, 1, 4, 6], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 1, 4, 6], &mut rng, 1.0);
        let raw = Tensor::randn(&[1, 1, 3, 4, 6], &mut rng, 1.0);
        let taps = Taps::normalize(&raw);
        let l2r = scan_dir(&x.flip_last(), &taps, &lam.flip_last(), Direction::L2R, 0);
        let r2l = scan_dir(&x, &taps, &lam, Direction::R2L, 0);
        assert!(l2r.flip_last().allclose(&r2l, 1e-6, 1e-6));
    }

    #[test]
    fn t2b_propagates_downward() {
        // Impulse at top row; t2b must move it to lower rows, spreading
        // laterally by at most one column per step (tridiagonal cone).
        let h = 6;
        let w = 6;
        let mut x = Tensor::zeros(&[1, 1, h, w]);
        *x.at_mut(&[0, 0, 0, 3]) = 1.0;
        let lam = Tensor::full(&[1, 1, h, w], 1.0);
        let raw = Tensor::zeros(&[1, 1, 3, w, h]); // canonical geometry of t2b
        let taps = Taps::normalize(&raw);
        let y = scan_dir(&x, &taps, &lam, Direction::T2B, 0);
        let lower_mass: f32 = (1..h).map(|r| y.at(&[0, 0, r, 3]).abs()).sum();
        assert!(lower_mass > 0.1, "t2b did not propagate down: {lower_mass}");
        // Row r can only be reached within |col - 3| <= r (3-neighbour cone).
        for r in 0..h {
            for c in 0..w {
                if (c as i64 - 3).unsigned_abs() as usize > r {
                    assert_eq!(y.at(&[0, 0, r, c]), 0.0, "cone violated at ({r},{c})");
                }
            }
        }
        // Upward direction never receives mass (strictly top-to-bottom):
        // nothing above the impulse row.
        for c in 0..w {
            if c != 3 {
                assert_eq!(y.at(&[0, 0, 0, c]), 0.0);
            }
        }
    }

    #[test]
    fn merge_weights_convex() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[1, 2, 4, 4], &mut rng, 1.0);
        let lam = Tensor::full(&[1, 2, 4, 4], 0.5);
        let raws: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[1, 1, 3, 4, 4], &mut rng, 1.0)).collect();
        let taps: Vec<Taps> = raws.iter().map(Taps::normalize).collect();
        let tr = [&taps[0], &taps[1], &taps[2], &taps[3]];
        // One-hot logits ~ selecting a single direction.
        let hot = merged_4dir(&x, tr, &lam, &[50.0, 0.0, 0.0, 0.0], 0);
        let solo = scan_dir(&x, &taps[0], &lam, Direction::L2R, 0);
        assert!(hot.allclose(&solo, 1e-4, 1e-4));
        // Uniform logits = average of the four.
        let uni = merged_4dir(&x, tr, &lam, &[0.0; 4], 0);
        let mut avg = Tensor::zeros(&x.shape);
        for (k, d) in DIRECTIONS.iter().enumerate() {
            let y = scan_dir(&x, tr[k], &lam, *d, 0);
            avg = avg.add(&y.scale(0.25));
        }
        assert!(uni.allclose(&avg, 1e-5, 1e-5));
    }

    #[test]
    fn four_directions_reach_everywhere() {
        // With all four passes, an impulse at any position influences all
        // four corners (dense pairwise connectivity claim).
        let h = 5;
        let w = 5;
        let mut x = Tensor::zeros(&[1, 1, h, w]);
        *x.at_mut(&[0, 0, 2, 2]) = 1.0;
        let lam = Tensor::full(&[1, 1, h, w], 1.0);
        let mk = |hh, ww| Taps::normalize(&Tensor::zeros(&[1, 1, 3, hh, ww]));
        let t_lr = mk(h, w);
        let t_tb = mk(w, h);
        let y = merged_4dir(&x, [&t_lr, &t_lr, &t_tb, &t_tb], &lam, &[0.0; 4], 0);
        for (r, c) in [(0, 0), (0, w - 1), (h - 1, 0), (h - 1, w - 1)] {
            assert!(
                y.at(&[0, 0, r, c]).abs() > 1e-5,
                "corner ({r},{c}) unreached"
            );
        }
    }

    #[test]
    fn merged_pool_is_bit_identical_to_serial() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(17);
        let x = Tensor::randn(&[2, 3, 6, 7], &mut rng, 1.0);
        let lam = Tensor::randn(&[2, 3, 6, 7], &mut rng, 1.0);
        let raw_lr = Tensor::randn(&[2, 1, 3, 6, 7], &mut rng, 1.0);
        let raw_tb = Tensor::randn(&[2, 1, 3, 7, 6], &mut rng, 1.0);
        let t_lr = Taps::normalize(&raw_lr);
        let t_tb = Taps::normalize(&raw_tb);
        let tr = [&t_lr, &t_lr, &t_tb, &t_tb];
        let logits = [0.4f32, -0.2, 1.1, 0.0];
        let serial = merged_4dir(&x, tr, &lam, &logits, 0);
        let pooled = merged_4dir_pool(&x, tr, &lam, &logits, 0, &pool);
        assert_eq!(serial.data, pooled.data);
        // And through the global pool (the serving/model path).
        let global = merged_4dir_par(&x, tr, &lam, &logits, 0);
        assert_eq!(serial.data, global.data);
        // All of the above route through the fused engine; the serial
        // reference composition must agree bit for bit.
        let reference = merged_4dir_ref(&x, tr, &lam, &logits, 0);
        assert_eq!(reference.data, serial.data);
    }

    #[test]
    fn direction_parse_roundtrip() {
        for d in DIRECTIONS {
            assert_eq!(Direction::parse(d.name()), Some(d));
        }
        assert_eq!(Direction::parse("nope"), None);
    }
}
