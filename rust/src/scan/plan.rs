//! The scan execution planner: *what* to launch, decided before anything
//! is launched.
//!
//! GSPN-2's system claim is that propagation should be scheduled as one
//! coherent launch rather than a sequence of synchronized micro-steps;
//! FlashAttention-2 made the same point for attention by promoting work
//! *partitioning* to its own design layer. This module is that layer for
//! the CPU engine: every pooled scan entry point asks [`plan_scan`] for a
//! [`ScanPlan`] — a strategy, a wavefront flag, and a cost estimate —
//! instead of burying the decision inside the engine
//! (`fused::auto_segments`, now absorbed here).
//!
//! ## Strategies
//!
//! * [`ScanStrategy::PlanePar`] — one job per block of (N·C) planes.
//!   Bit-identical to the serial reference with zero decomposition
//!   overhead; the only strategy whose arithmetic is `==` `scan_l2r`.
//! * [`ScanStrategy::Segmented`] — the §5.1 two-phase carry-correction
//!   decomposition: phase 1 scans `s` zero-carry column segments per
//!   (plane, direction) in parallel, phase 2 chains the true carries as a
//!   linear correction. Exact `==` with `scan_l2r_split` at the same
//!   count; pays ~[`CORR_FLOPS_PER_PX`]/[`SCAN_FLOPS_PER_PX`] extra flops
//!   over (s-1)/s of the columns.
//! * [`ScanStrategy::DirFan`] — per-direction phase-1 fan-out for
//!   multi-direction passes: each (plane, direction) scans its full
//!   canonical width from the true zero carry (no correction — the scan
//!   is already exact) into a retained panel, and a fixed-order merge
//!   drain replays the k = 0..4 epilogue per plane. Bit-identical to
//!   `PlanePar` (same arithmetic, different schedule), ×`ndirs` the
//!   parallel width — the mid-occupancy fix for geometries too narrow to
//!   segment.
//! * [`ScanStrategy::Chained`] — the single-pass chained decomposition
//!   (`fused::run_engine_chained`): the same column chunks as
//!   `Segmented`, but each chunk is ONE job that scans from a zero
//!   carry, publishes its aggregate, resolves its true carry by
//!   decoupled look-back over predecessors' published prefixes, folds
//!   the correction into its still-cache-hot panel, and drains. Exact
//!   `==` with `Segmented` (and `scan_l2r_split`) at the same count —
//!   same arithmetic, no phase barrier, no retained-panel array, no
//!   second panel read. The production low-occupancy strategy; the
//!   two-phase `Segmented` engine is kept as the bit/bench reference.
//!
//! The `wavefront` flag asks the engine to run each plane's dependent
//! stage (the fused correction + epilogue drain) as *per-direction
//! continuations* of that plane's phase-1 jobs on the pool's task-graph
//! API ([`crate::util::ThreadPool::run_graph`]) instead of behind a
//! global barrier: direction k's drain starts the moment direction k's
//! own pieces finish (chained after drain k-1 to keep the merge order),
//! so it overlaps both other planes' phase 1 and the same plane's later
//! directions (LASP-2-style compute/dependency overlap).
//!
//! ## Decision rule (the planner, in order)
//!
//! 1. An override (`scan.plan` config / `GSPN2_SCAN_PLAN` env:
//!    `plane|segment|dirfan|chained`) short-circuits the auto rule —
//!    `segment`, `dirfan`, and `chained` still respect validity fences
//!    (a too-narrow geometry cannot be segmented or chained; a
//!    single-direction pass cannot dir-fan).
//! 2. `threads < 2`, no planes, or `nplanes >= threads`: `PlanePar`.
//!    Planes alone occupy the pool; the bit-exact zero-overhead strategy
//!    wins outright.
//! 3. Multi-direction pass, `wc_min >= MIN_DIRFAN_COLS`, and the
//!    direction fan (`nplanes * ndirs`) alone covers the workers:
//!    `DirFan` — full occupancy without correction overhead, still
//!    bit-exact.
//! 4. [`auto_segments`] finds `s >= 2` (needs `wc_min >= 2 *`
//!    [`MIN_SEG_COLS`]): `Chained { s }` — bit-identical to the
//!    two-phase `Segmented { s }` it replaced at the same count, minus
//!    the phase barrier and the retained-panel traffic. The wavefront
//!    flag is off: the chained engine has no phases to overlap.
//! 5. Multi-direction pass wide enough to dir-fan: `DirFan` (can't
//!    segment, but ×4 width still helps).
//! 6. Otherwise `PlanePar`.
//!
//! Strategy selection deliberately ignores the live pool load so
//! identical requests produce identical bits run-to-run — `DirFan` and
//! `Segmented`/`Chained` order their arithmetic differently, so letting
//! a transient load flip between them would make serving output
//! nondeterministic. `pool_load` feeds only the *cost estimate* (the
//! span is computed against the capacity actually left) and the
//! release-sizing consumers below.
//!
//! ## Cost model
//!
//! Flop units per pixel per direction: [`SCAN_FLOPS_PER_PX`] = 7 for the
//! scan itself (`up + ct + dn + lam·x`: 5 mul + 3 add, counted as the
//! reference's 7-op inner body). The correction used to be a separate
//! 3-flop/px in-place pass ([`CORR_FLOPS_PER_PX`], kept as the two-pass
//! reference anchor: ~27% single-thread overhead at s = 8 on a 512²
//! plane, which is 3/7 · 7/8 of the scan work); with the correction
//! *fused into the scatter drain* the retained panel is read once and
//! written zero extra times, the recurrence runs on L1-hot columns the
//! epilogue was touching anyway, and the effective cost collapses to
//! [`FUSED_CORR_FLOPS_PER_PX`] ≈ 1 flop/px over the corrected
//! (s-1)/s of the columns — the memory-traffic elimination of the
//! paper's §5 kernel redesign, FlashAttention-2-style. `work` is the
//! total; `span` estimates the critical path given the pool width:
//! phase 1 divides by the strategy's fan width, the correction term by
//! the plane count, and wavefront mode divides that term by the
//! per-plane continuation count (`nplanes · ndirs` — drains are
//! per-direction continuations, so direction k's drain hides behind
//! both other planes' phase 1 and the same plane's later directions;
//! only the last drain's tail is exposed). `Chained` does the same
//! work as `Segmented` (identical arithmetic), but its correction is
//! look-back folding inside each chunk job rather than a second pass:
//! the exposed tail is one serial correction chain per (plane,
//! direction), and the `nplanes · ndirs` chains run concurrently — no
//! barrier, no continuation machinery, no retained-panel re-read.
//!
//! Consumers beyond the engine: the serving coordinator sizes eager
//! batch releases off the plan ([`eager_release_min`]) instead of the
//! raw pool-saturated bool — a plan whose fan width fits the pool's idle
//! capacity releases immediately, a wide plan on a busy pool holds out
//! for a fused batch.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

/// Minimum canonical columns per segment. Below this the per-segment
/// job dispatch dominates any occupancy gain. Lowered from 128 to 64
/// when the carry correction was fused into the scatter drain (the
/// correction no longer re-touches the retained panel, so the overhead
/// a segment must amortize shrank) — this opens the previously
/// plane-parallel-only single-direction serving band of 128–255
/// canonical columns to segmentation. It is also the compatibility
/// fence: every geometry the unit/e2e suites pin bit-identical is
/// narrower than `2 * MIN_SEG_COLS` (all are ≤ 64 columns), so the
/// planner can never move them off the bit-exact plane-parallel path
/// regardless of how wide the host pool is.
pub const MIN_SEG_COLS: usize = 64;

/// Minimum canonical columns for the direction fan-out: below this a
/// per-(plane, direction) job is too small to amortize the retained
/// panel and the drain continuation. Covers the 64 ≤ wc < 128 band
/// where segmentation is still fenced off (since the fused-correction
/// drain lowered [`MIN_SEG_COLS`] to 64, geometries with ≥ 128 columns
/// can segment instead when the fan alone can't fill the pool).
pub const MIN_DIRFAN_COLS: usize = 64;

/// Scan-recurrence flops per pixel per direction (the `up + ct + dn +
/// lam·x` inner body).
pub const SCAN_FLOPS_PER_PX: f64 = 7.0;

/// Carry-correction flops per pixel of the retired *two-pass* phase 2
/// (the `up + ct + dn` body run as a separate in-place panel pass).
/// Kept as the reference anchor the fused model below is measured
/// against; the production span formula uses
/// [`FUSED_CORR_FLOPS_PER_PX`].
pub const CORR_FLOPS_PER_PX: f64 = 3.0;

/// Effective carry-correction cost per pixel with the correction fused
/// into the scatter drain: the panel element is already in registers
/// for the epilogue, the correction recurrence runs on L1-hot columns,
/// and the only extra full-width op is the `phase1 + corr` add — ~1
/// flop/px over the corrected (s-1)/s of the columns.
pub const FUSED_CORR_FLOPS_PER_PX: f64 = 1.0;

/// Fraction of a lane's nominal throughput the explicit SIMD kernels
/// realize on the scan/correction phases. The inner loops are memory-
/// shaped (three tap streams + two column streams per fused
/// multiply-add), so wider vectors saturate bandwidth long before
/// they saturate issue width: the C mirror of the AVX2 kernel measured
/// ~2.8x over the unvectorized scalar body at 8 lanes on a
/// cache-resident W=64 chunk — matching `1 + (8 - 1) * 0.25 = 2.75`
/// rather than the nominal 8x. [`effective_lanes`] encodes that
/// derating; the cost model divides the vectorized flop terms by it.
pub const LANE_FRACTION: f64 = 0.25;

/// The derated speedup factor for `lanes`-wide kernels
/// (`1 + (lanes - 1) · LANE_FRACTION`): 1 lane → 1.0 (the scalar
/// fallback changes nothing), 4 lanes (NEON) → 1.75, 8 lanes (AVX2) →
/// 2.75. Monotone in `lanes`, so relative strategy ordering — which
/// never depends on the host anyway ([`plan_scan_with`] decides before
/// costing) — is preserved at every width.
pub fn effective_lanes(lanes: usize) -> f64 {
    1.0 + (lanes.max(1) as f64 - 1.0) * LANE_FRACTION
}

/// The engine a tiled stream runs *inside each row band*. Every inner
/// executes the band from the previous band's [`crate::scan::engine::ExternalCarry`]
/// and is bit-identical to the corresponding untiled strategy (band
/// boundaries fall on whole segment pieces, so the decomposition — and
/// therefore the bits — never changes with the band size).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileInner {
    /// Serial per-plane band scan (the `PlanePar` arithmetic); exact
    /// `==` `scan_l2r`.
    Seq,
    /// The two-phase segmented engine per band, keeping the *untiled*
    /// `s`-piece decomposition; exact `==` `Segmented { s }`.
    Segmented {
        /// Column segments per plane per direction (untiled count).
        s: usize,
    },
    /// The single-pass chained engine per band, keeping the untiled
    /// `s`-chunk decomposition; exact `==` `Chained { s }`.
    Chained {
        /// Column chunks per plane per direction (untiled count).
        s: usize,
    },
}

/// How a scan pass decomposes its work across the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanStrategy {
    /// Block-granular plane jobs; bit-identical to the serial reference.
    PlanePar,
    /// Two-phase segmented decomposition with `s` column segments per
    /// (plane, direction); exact `==` `scan_l2r_split` at count `s`.
    Segmented {
        /// Column segments per plane per direction.
        s: usize,
    },
    /// Per-(plane, direction) phase-1 fan with a fixed-order merge
    /// drain; bit-identical to `PlanePar`.
    DirFan,
    /// Single-pass chained decomposition with decoupled look-back and
    /// `s` column chunks per (plane, direction); exact `==`
    /// `Segmented { s }` (and `scan_l2r_split` at count `s`) with no
    /// phase barrier, retained panels, or second panel read.
    Chained {
        /// Column chunks per plane per direction.
        s: usize,
    },
    /// Bounded-memory streaming: execute the pass as a serial stream of
    /// canonical row bands of ~`band_rows` columns, each scanned by the
    /// `inner` engine from the previous band's serialized carry, with
    /// the band's staged taps + scratch leased and returned *within*
    /// the band. Peak workspace is one band's, not the image's; output
    /// is bit-identical to the untiled `inner` at every band size.
    Tiled {
        /// Canonical columns per band (the planner clamps degenerate
        /// values to at least 1; `>= wc` degenerates to one band ==
        /// the untiled engine).
        band_rows: usize,
        /// The engine each band runs.
        inner: TileInner,
    },
}

impl TileInner {
    /// The untiled strategy this inner is bit-identical to — the cost
    /// and footprint models price a band through it.
    pub fn as_strategy(self) -> ScanStrategy {
        match self {
            TileInner::Seq => ScanStrategy::PlanePar,
            TileInner::Segmented { s } => ScanStrategy::Segmented { s },
            TileInner::Chained { s } => ScanStrategy::Chained { s },
        }
    }
}

/// The planner's cost estimate for one pass under one strategy, in the
/// flop units of the module docs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanCost {
    /// Total work (all phases, all planes/directions).
    pub work_flops: f64,
    /// Estimated critical path given the pool width the plan was made
    /// for — the number the coordinator compares across release options.
    pub span_flops: f64,
    /// Phase-1 parallel fan width (independent jobs the plan launches).
    pub width: usize,
}

/// One scan pass's geometry, as the planner sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScanGeometry {
    /// N·C planes in the pass.
    pub nplanes: usize,
    /// Directions scanned and merged in the pass (1 or 4).
    pub ndirs: usize,
    /// Smallest canonical width among the pass's directions.
    pub wc_min: usize,
    /// Pixels per plane per direction (H·W).
    pub plane_px: usize,
    /// max(H, W) — the engine's column length, which sizes every
    /// workspace column and slab lease ([`workspace_footprint`]).
    pub hmax: usize,
}

impl ScanGeometry {
    /// Geometry of a single-direction scan over (N·C) = `nplanes`
    /// planes of `h x w` pixels — the serving backend's request shape.
    pub fn single_dir(nplanes: usize, h: usize, w: usize) -> ScanGeometry {
        ScanGeometry { nplanes, ndirs: 1, wc_min: w, plane_px: h * w, hmax: h.max(w) }
    }

    /// Geometry of a 4-direction merged pass (canonical widths `w` and
    /// `h` across the direction pairs).
    pub fn merged_4dir(nplanes: usize, h: usize, w: usize) -> ScanGeometry {
        ScanGeometry { nplanes, ndirs: 4, wc_min: w.min(h), plane_px: h * w, hmax: h.max(w) }
    }
}

/// An execution plan: the strategy, whether dependent stages run as
/// wavefront continuations, and the cost estimate that justified it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScanPlan {
    pub strategy: ScanStrategy,
    /// Run each plane's dependent stage as a continuation of that
    /// plane's phase-1 jobs (task-graph scheduling) instead of behind a
    /// global barrier. Meaningful for `Segmented` and `DirFan`.
    pub wavefront: bool,
    pub cost: PlanCost,
}

impl ScanPlan {
    /// Total workspace bytes this plan's strategy leases at peak, in the
    /// pool's size classes ([`workspace_footprint`] summed). The
    /// coordinator compares this against a bucket pool's retention cap
    /// when sizing eager releases under memory pressure
    /// ([`eager_release_min_mem`]).
    pub fn workspace_bytes(&self, geom: &ScanGeometry, threads: usize, tap_blocks: usize) -> usize {
        workspace_footprint(geom, self.strategy, threads, tap_blocks)
            .iter()
            .map(|&(class, count)| class * 4 * count)
            .sum()
    }

    /// Forced plan constructors for tests, benches, and callers that
    /// know their geometry. Costs are estimated for `threads` workers.
    pub fn plane(geom: &ScanGeometry, threads: usize) -> ScanPlan {
        ScanPlan::with(ScanStrategy::PlanePar, false, geom, threads)
    }

    pub fn segmented(s: usize, wavefront: bool, geom: &ScanGeometry, threads: usize) -> ScanPlan {
        ScanPlan::with(ScanStrategy::Segmented { s: s.max(1) }, wavefront, geom, threads)
    }

    pub fn dir_fan(wavefront: bool, geom: &ScanGeometry, threads: usize) -> ScanPlan {
        ScanPlan::with(ScanStrategy::DirFan, wavefront, geom, threads)
    }

    pub fn chained(s: usize, geom: &ScanGeometry, threads: usize) -> ScanPlan {
        ScanPlan::with(ScanStrategy::Chained { s: s.max(1) }, false, geom, threads)
    }

    pub fn tiled(band_rows: usize, inner: TileInner, geom: &ScanGeometry, threads: usize) -> ScanPlan {
        ScanPlan::with(ScanStrategy::Tiled { band_rows: band_rows.max(1), inner }, false, geom, threads)
    }

    fn with(strategy: ScanStrategy, wavefront: bool, geom: &ScanGeometry, threads: usize) -> ScanPlan {
        ScanPlan { strategy, wavefront, cost: plan_cost(geom, strategy, wavefront, threads) }
    }
}

/// The cost model of the module docs, for one strategy on `threads`
/// workers, at the host's detected SIMD lane width
/// ([`crate::scan::simd::lanes`]). Delegates to [`plan_cost_lanes`];
/// the lane width scales every strategy's vectorized terms by the same
/// [`effective_lanes`] factor, so it moves absolute estimates (what the
/// coordinator's release sizing consumes) without ever reordering
/// strategies.
pub fn plan_cost(
    geom: &ScanGeometry,
    strategy: ScanStrategy,
    wavefront: bool,
    threads: usize,
) -> PlanCost {
    plan_cost_lanes(geom, strategy, wavefront, threads, crate::scan::simd::lanes())
}

/// [`plan_cost`] with an explicit lane width — the host-independent
/// core the decision-table pins test at `lanes = 1` (where it
/// reproduces the pre-SIMD model exactly). The scan recurrence and the
/// carry correction both run in the lane kernels, so their flop terms
/// divide by [`effective_lanes`]; job-dispatch and width bookkeeping do
/// not.
pub fn plan_cost_lanes(
    geom: &ScanGeometry,
    strategy: ScanStrategy,
    wavefront: bool,
    threads: usize,
    lanes: usize,
) -> PlanCost {
    let threads = threads.max(1) as f64;
    let planes = geom.nplanes.max(1);
    let px = (geom.nplanes * geom.ndirs * geom.plane_px) as f64;
    let el = effective_lanes(lanes);
    let base = px * SCAN_FLOPS_PER_PX / el;
    match strategy {
        ScanStrategy::PlanePar => {
            let width = planes;
            PlanCost {
                work_flops: base,
                span_flops: base / threads.min(width as f64),
                width,
            }
        }
        ScanStrategy::DirFan => {
            let width = planes * geom.ndirs.max(1);
            PlanCost {
                work_flops: base,
                span_flops: base / threads.min(width as f64),
                width,
            }
        }
        ScanStrategy::Segmented { s } => {
            let s = s.max(1);
            let width = planes * geom.ndirs.max(1) * s;
            let corr = px * FUSED_CORR_FLOPS_PER_PX * (s as f64 - 1.0) / (s as f64 * el);
            let p1 = base / threads.min(width as f64);
            let p2 = corr / threads.min(planes as f64);
            // Wavefront: drains are per-direction continuations, so the
            // correction tail hides behind nplanes * ndirs other
            // in-flight stages instead of running after a barrier.
            let conts = (planes * geom.ndirs.max(1)) as f64;
            let span = if wavefront { p1 + p2 / conts } else { p1 + p2 };
            PlanCost { work_flops: base + corr, span_flops: span, width }
        }
        ScanStrategy::Chained { s } => {
            // Same arithmetic as Segmented at the same count; the
            // correction is folded into the chunk jobs, so the exposed
            // tail is one serial look-back chain per (plane, direction)
            // and the chains run concurrently — never longer than the
            // barrier form's correction pass, and there is no barrier.
            let s = s.max(1);
            let width = planes * geom.ndirs.max(1) * s;
            let corr = px * FUSED_CORR_FLOPS_PER_PX * (s as f64 - 1.0) / (s as f64 * el);
            let p1 = base / threads.min(width as f64);
            let chains = (planes * geom.ndirs.max(1)) as f64;
            PlanCost { work_flops: base + corr, span_flops: p1 + corr / chains, width }
        }
        ScanStrategy::Tiled { inner, .. } => {
            // A tiled stream runs the inner engine band by band over the
            // same pixels: same arithmetic, same total work. The bands
            // are serial, but they partition the very columns the
            // untiled span already charges, so the inner's estimate is
            // the model here too — tiling trades peak workspace for
            // (at most) some cross-band fan width, not for flops.
            plan_cost_lanes(geom, inner.as_strategy(), wavefront, threads, lanes)
        }
    }
}

/// The occupancy-aware segment-count rule (moved verbatim from
/// `fused::auto_segments`, which the planner subsumes): how many column
/// segments (if any) each plane should be decomposed into, given the
/// plane count, the smallest canonical width among the directions in the
/// pass, and the pool width.
///
/// Plane-parallel work is bit-identical to the serial reference and has
/// zero decomposition overhead, so it wins whenever the planes alone can
/// occupy the pool (`nplanes >= threads`). Below that — the paper's
/// §5.1 low-occupancy regime — segmenting buys parallel phase-1 scans at
/// the cost of a serial-per-plane correction pass, so it only pays when
/// phase 1 actually fans wider than the planes did. The segment count
/// targets ~2 phase-1 jobs per worker and never drops a segment below
/// [`MIN_SEG_COLS`] columns. Returns `None` for "stay plane-parallel".
pub fn auto_segments(nplanes: usize, wc_min: usize, threads: usize) -> Option<usize> {
    if threads < 2 || nplanes == 0 || nplanes >= threads {
        return None;
    }
    forced_segments(nplanes, wc_min, threads)
}

/// [`auto_segments`] without the occupancy bailout — the count the
/// `segment` override uses. Same formula, so wherever the auto rule
/// would segment, the forced rule picks the identical count.
fn forced_segments(nplanes: usize, wc_min: usize, threads: usize) -> Option<usize> {
    if threads < 2 || nplanes == 0 {
        return None;
    }
    let max_by_width = wc_min / MIN_SEG_COLS;
    let want = (2 * threads).div_ceil(nplanes);
    let s = want.min(max_by_width);
    (s >= 2).then_some(s)
}

// ---------------------------------------------------------------------
// Override plumbing: config knob / env var
// ---------------------------------------------------------------------

/// Planner override selected by config (`scan.plan`) or the
/// `GSPN2_SCAN_PLAN` env var.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanOverride {
    /// No override: the full auto decision rule.
    Auto,
    /// Always `PlanePar`.
    Plane,
    /// `Segmented` wherever a valid count exists (width fence still
    /// applies), ignoring pool occupancy; else `PlanePar`.
    Segment,
    /// `DirFan` for every multi-direction pass (bit-identical, so safe
    /// at any width); single-direction passes keep the auto rule.
    DirFan,
    /// `Chained` wherever a valid chunk count exists (same width fence
    /// as `Segment`), ignoring pool occupancy; else `PlanePar`.
    Chained,
    /// Wrap the auto decision in a `Tiled` stream at
    /// [`tile_band_rows`] — whatever strategy the auto rule picks runs
    /// band by band (bit-identical to it). The CI hook for running the
    /// whole suite through the streaming path.
    Tiled,
    /// `Tiled` with a `Chained` inner wherever a valid chunk count
    /// exists (same width fence as `chained`); else a `Seq` inner.
    /// Exercises the `External`-carry × look-back composition.
    TiledChained,
}

const OV_UNSET: u8 = u8::MAX;
static PLAN_OVERRIDE: AtomicU8 = AtomicU8::new(OV_UNSET);

fn parse_override(name: &str) -> Option<PlanOverride> {
    match name {
        "auto" => Some(PlanOverride::Auto),
        "plane" => Some(PlanOverride::Plane),
        "segment" => Some(PlanOverride::Segment),
        "dirfan" => Some(PlanOverride::DirFan),
        "chained" => Some(PlanOverride::Chained),
        "tiled" => Some(PlanOverride::Tiled),
        "tiled-chained" => Some(PlanOverride::TiledChained),
        _ => None,
    }
}

/// Set the process-wide planner override (the `scan.plan` config knob).
/// Accepts `auto | plane | segment | dirfan | chained | tiled |
/// tiled-chained`.
pub fn set_plan_override(name: &str) -> Result<(), String> {
    let ov = parse_override(name).ok_or_else(|| {
        format!(
            "unknown scan.plan {name:?} (want auto|plane|segment|dirfan|chained|tiled|tiled-chained)"
        )
    })?;
    PLAN_OVERRIDE.store(ov as u8, Ordering::Relaxed);
    Ok(())
}

/// The active planner override: the config knob if set, else
/// `GSPN2_SCAN_PLAN` (read once), else `Auto`. An *invalid* env value
/// panics rather than silently planning `Auto` — the env hook exists so
/// CI re-runs the suite under forced strategies, and a typo that
/// quietly tested the default instead would be a green lie.
pub fn plan_override() -> PlanOverride {
    let v = PLAN_OVERRIDE.load(Ordering::Relaxed);
    if v != OV_UNSET {
        return from_u8(v);
    }
    let ov = match std::env::var("GSPN2_SCAN_PLAN") {
        Ok(s) => parse_override(&s).unwrap_or_else(|| {
            panic!(
                "GSPN2_SCAN_PLAN={s:?} is not one of \
                 auto|plane|segment|dirfan|chained|tiled|tiled-chained"
            )
        }),
        Err(_) => PlanOverride::Auto,
    };
    PLAN_OVERRIDE.store(ov as u8, Ordering::Relaxed);
    ov
}

fn from_u8(v: u8) -> PlanOverride {
    match v {
        1 => PlanOverride::Plane,
        2 => PlanOverride::Segment,
        3 => PlanOverride::DirFan,
        4 => PlanOverride::Chained,
        5 => PlanOverride::Tiled,
        6 => PlanOverride::TiledChained,
        _ => PlanOverride::Auto,
    }
}

// Discriminant values used by the atomic above.
// (PlanOverride as u8: Auto=0, Plane=1, Segment=2, DirFan=3, Chained=4,
// Tiled=5, TiledChained=6.)

// ---------------------------------------------------------------------
// Tile band height: config knob / env var, and the auto-tiling rule
// ---------------------------------------------------------------------

/// Default canonical columns per tiled band. At the serving shapes this
/// keeps a band's staged taps + scratch in the tens of MiB while still
/// giving every band enough columns to amortize its staging pass.
pub const DEFAULT_TILE_BAND_ROWS: usize = 128;

static TILE_BAND_ROWS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide tiled band height (the `scan.tile_band_rows`
/// config knob). Zero is rejected — a zero band makes no progress.
pub fn set_tile_band_rows(rows: usize) -> Result<(), String> {
    if rows == 0 {
        return Err("scan.tile_band_rows must be >= 1".to_string());
    }
    TILE_BAND_ROWS.store(rows, Ordering::Relaxed);
    Ok(())
}

/// The active tiled band height: the config knob if set, else
/// `GSPN2_SCAN_TILE_BAND_ROWS` (read once), else
/// [`DEFAULT_TILE_BAND_ROWS`]. Mirrors [`plan_override`]'s env
/// handling, including the panic on an invalid value — CI forcing the
/// tiled plan through a typo'd band height must not silently test the
/// default.
pub fn tile_band_rows() -> usize {
    let v = TILE_BAND_ROWS.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let rows = match std::env::var("GSPN2_SCAN_TILE_BAND_ROWS") {
        Ok(s) => s.parse::<usize>().ok().filter(|&r| r > 0).unwrap_or_else(|| {
            panic!("GSPN2_SCAN_TILE_BAND_ROWS={s:?} is not a positive integer")
        }),
        Err(_) => DEFAULT_TILE_BAND_ROWS,
    };
    TILE_BAND_ROWS.store(rows, Ordering::Relaxed);
    rows
}

/// The auto-tiling rule: wrap `p` in a [`ScanStrategy::Tiled`] stream
/// (same inner arithmetic, bit-identical output) when its untiled
/// workspace demand would exceed the pool's retention cap — the
/// geometry is too big to execute in-cap any other way. `cap_bytes ==
/// 0` means no cap (never auto-tile); an already-tiled plan passes
/// through. Called by the engine after [`plan_scan`] with the pass's
/// staged-tap block count and storage precision; forced strategies
/// (tests, benches) bypass it, and the `tiled`/`tiled-chained`
/// overrides tile unconditionally through [`decide`] instead.
pub fn maybe_tile(
    p: ScanPlan,
    geom: &ScanGeometry,
    threads: usize,
    tap_blocks: usize,
    cap_bytes: usize,
    bf16: bool,
) -> ScanPlan {
    if cap_bytes == 0 || matches!(p.strategy, ScanStrategy::Tiled { .. }) {
        return p;
    }
    let prec = if bf16 { crate::scan::simd::Precision::Bf16 } else { crate::scan::simd::Precision::F32 };
    let bytes: usize = workspace_footprint_prec(geom, p.strategy, threads, tap_blocks, prec)
        .iter()
        .map(|&(class, count)| class * 4 * count)
        .sum();
    if bytes <= cap_bytes {
        return p;
    }
    let inner = match p.strategy {
        ScanStrategy::PlanePar | ScanStrategy::DirFan => TileInner::Seq,
        ScanStrategy::Segmented { s } => TileInner::Segmented { s },
        ScanStrategy::Chained { s } => TileInner::Chained { s },
        ScanStrategy::Tiled { .. } => unreachable!("checked above"),
    };
    ScanPlan::tiled(tile_band_rows(), inner, geom, threads)
}

// ---------------------------------------------------------------------
// The planner
// ---------------------------------------------------------------------

/// Plan one scan pass: the module-doc decision rule, honoring the
/// process-wide override. `pool_load` is the pool's current queued +
/// running job count ([`crate::util::ThreadPool::load`]); it feeds only
/// the cost estimate — strategy selection is load-independent so
/// identical requests produce identical bits.
pub fn plan_scan(geom: &ScanGeometry, pool_load: usize, threads: usize) -> ScanPlan {
    plan_scan_with(geom, pool_load, threads, plan_override())
}

/// [`plan_scan`] with an explicit override (the pure, testable core).
/// The strategy + wavefront decision never reads `pool_load` (bit
/// determinism — see the module docs); the returned cost estimate is
/// computed against the capacity the pool actually has left.
pub fn plan_scan_with(
    geom: &ScanGeometry,
    pool_load: usize,
    threads: usize,
    ov: PlanOverride,
) -> ScanPlan {
    let (strategy, wavefront) = decide(geom, threads, ov);
    let avail = threads.saturating_sub(pool_load).max(1);
    ScanPlan { strategy, wavefront, cost: plan_cost(geom, strategy, wavefront, avail) }
}

/// The load-independent strategy decision of the module docs.
fn decide(geom: &ScanGeometry, threads: usize, ov: PlanOverride) -> (ScanStrategy, bool) {
    let can_fan = geom.ndirs > 1;
    match ov {
        PlanOverride::Plane => return (ScanStrategy::PlanePar, false),
        PlanOverride::Segment => {
            return match forced_segments(geom.nplanes, geom.wc_min, threads) {
                Some(s) => (ScanStrategy::Segmented { s }, true),
                None => (ScanStrategy::PlanePar, false),
            };
        }
        PlanOverride::Chained => {
            return match forced_segments(geom.nplanes, geom.wc_min, threads) {
                Some(s) => (ScanStrategy::Chained { s }, false),
                None => (ScanStrategy::PlanePar, false),
            };
        }
        PlanOverride::Tiled => {
            // Tile whatever the auto rule picks: same inner arithmetic,
            // streamed band by band — the bits never change, so this is
            // safe to force across the whole suite.
            let (base, _) = decide(geom, threads, PlanOverride::Auto);
            let inner = match base {
                ScanStrategy::PlanePar | ScanStrategy::DirFan => TileInner::Seq,
                ScanStrategy::Segmented { s } => TileInner::Segmented { s },
                ScanStrategy::Chained { s } => TileInner::Chained { s },
                ScanStrategy::Tiled { .. } => unreachable!("auto rule never tiles"),
            };
            return (ScanStrategy::Tiled { band_rows: tile_band_rows(), inner }, false);
        }
        PlanOverride::TiledChained => {
            let inner = match forced_segments(geom.nplanes, geom.wc_min, threads) {
                Some(s) => TileInner::Chained { s },
                None => TileInner::Seq,
            };
            return (ScanStrategy::Tiled { band_rows: tile_band_rows(), inner }, false);
        }
        PlanOverride::DirFan if can_fan => {
            return (ScanStrategy::DirFan, true);
        }
        PlanOverride::DirFan | PlanOverride::Auto => {}
    }
    // Auto rule (also the single-direction fallback of the dirfan
    // override).
    if threads < 2 || geom.nplanes == 0 || geom.nplanes >= threads {
        return (ScanStrategy::PlanePar, false);
    }
    if can_fan && geom.wc_min >= MIN_DIRFAN_COLS {
        let fan_width = geom.nplanes * geom.ndirs;
        if fan_width >= threads {
            // The direction fan alone covers the workers: full
            // occupancy, zero overhead, exact bits.
            return (ScanStrategy::DirFan, true);
        }
        if let Some(s) = auto_segments(geom.nplanes, geom.wc_min, threads) {
            return (ScanStrategy::Chained { s }, false);
        }
        return (ScanStrategy::DirFan, true);
    }
    match auto_segments(geom.nplanes, geom.wc_min, threads) {
        Some(s) => (ScanStrategy::Chained { s }, false),
        None => (ScanStrategy::PlanePar, false),
    }
}

// ---------------------------------------------------------------------
// Workspace footprint: what a strategy leases, by pool size class
// ---------------------------------------------------------------------

/// The workspace demand of one pass under `strategy`, aggregated by the
/// pool's size classes: `(class_len, peak_count)` pairs, where
/// `class_len` is a buffer length already rounded to the
/// [`crate::util::workspace::BufferPool`] class it lands in and
/// `peak_count` the number of such buffers concurrently on lease at the
/// strategy's peak (for `threads` workers; `tap_blocks` is the pass's
/// N · Cw staged-tap block count).
///
/// This is how the coordinator pre-warms a bucket's pool at
/// registration — one `prewarm(class_len, count)` call per pair makes
/// the bucket's very first request allocation-free — and how
/// [`ScanPlan::workspace_bytes`] prices a plan for the memory-pressure
/// release rule. The model mirrors the engine's lease sites
/// (`FusedScratch`, staged taps, retained panels, phase-1 piece
/// scratch, `DrainScratch`; for `Chained` the look-back board payload,
/// per-chunk panels, and fold columns) and is deliberately a slight
/// over-estimate
/// for the wavefront schedules (it prices the barrier form's retained
/// panel block, which dominates the piece buffers).
pub fn workspace_footprint(
    geom: &ScanGeometry,
    strategy: ScanStrategy,
    threads: usize,
    tap_blocks: usize,
) -> Vec<(usize, usize)> {
    workspace_footprint_prec(geom, strategy, threads, tap_blocks, crate::scan::simd::precision())
}

/// [`workspace_footprint`] at an explicit storage precision — the
/// testable core, and what precision-threading callers price directly.
/// `Bf16` halves the classes that narrow in the engine (the staged tap
/// panels everywhere; the per-chunk local panels of `Chained`) and adds
/// the chained path's f32 staging slabs (the scan lands in f32 before
/// narrowing; the drain decodes back through a slab) plus its
/// full-precision aggregate column. Everything else — retained
/// segmented panels, carry/fold columns, the look-back board — stays
/// f32 by design (the recurrence and the published columns never
/// narrow).
pub fn workspace_footprint_prec(
    geom: &ScanGeometry,
    strategy: ScanStrategy,
    threads: usize,
    tap_blocks: usize,
    prec: crate::scan::simd::Precision,
) -> Vec<(usize, usize)> {
    let mut demand: std::collections::BTreeMap<usize, usize> = std::collections::BTreeMap::new();
    accumulate_footprint(&mut demand, geom, strategy, threads, tap_blocks, prec);
    demand.into_iter().collect()
}

/// Accumulate `count` buffers of `len` elements into the class-keyed
/// demand map — the one place every strategy arm's sizes funnel
/// through, so classes aggregate no matter which arm (or band
/// recursion) produced them.
fn add_class(demand: &mut std::collections::BTreeMap<usize, usize>, len: usize, count: usize) {
    if len > 0 && count > 0 {
        *demand.entry(crate::util::workspace::size_class(len)).or_default() += count;
    }
}

/// The zero-carry scan scratch every engine leases per concurrent job:
/// `slabs` pack/staging slabs plus the carry + zeros columns. The
/// plane path's `FusedScratch` holds two slabs; a segmented phase-1
/// piece or a chained chunk holds one (two at bf16, for the decode
/// slab) — the shared shape the strategy arms used to each spell out.
fn add_scan_scratch(
    demand: &mut std::collections::BTreeMap<usize, usize>,
    slab: usize,
    hmax: usize,
    slabs: usize,
    jobs: usize,
) {
    add_class(demand, slab, slabs * jobs);
    add_class(demand, hmax, 2 * jobs);
}

/// How a tiled band groups the untiled `s`-piece decomposition:
/// `(pieces_per_band, band_cols)` — whole consecutive pieces, at least
/// one, covering ~`band_rows` canonical columns. Mirrors the tiled
/// executor's grouping exactly (bands never re-cut a piece; that is
/// what keeps tiled output bit-identical to untiled).
fn band_pieces(wc: usize, s: usize, band_rows: usize) -> (usize, usize) {
    let s = s.max(1);
    let piece = wc.div_ceil(s);
    let g = (band_rows.max(piece) / piece).max(1).min(s);
    (g, (g * piece).min(wc))
}

fn accumulate_footprint(
    demand: &mut std::collections::BTreeMap<usize, usize>,
    geom: &ScanGeometry,
    strategy: ScanStrategy,
    threads: usize,
    tap_blocks: usize,
    prec: crate::scan::simd::Precision,
) {
    use crate::scan::simd::{bf16_len, Precision};
    let threads = threads.max(1);
    let planes = geom.nplanes;
    let ndirs = geom.ndirs.max(1);
    if planes == 0 || geom.plane_px == 0 {
        return;
    }
    let bf16 = prec == Precision::Bf16;
    let half = |len: usize| if bf16 { bf16_len(len) } else { len };
    let hmax = geom.hmax.max(1);
    let slab = crate::scan::fused::SLAB * hmax;
    if let ScanStrategy::Tiled { band_rows, inner } = strategy {
        // One band's demand IS the pass's peak: bands run serially and
        // return every lease (band taps, scratch, panels, board) before
        // the next band stages, and the `ExternalCarry` hand-off
        // columns between bands are plain owned buffers outside the
        // pool by design (KiB-scale, and the serialization seam for
        // sharding). Bands execute one direction at a time over whole
        // pieces of the untiled decomposition, so price one
        // single-direction band through the inner's own arm.
        let wc = geom.wc_min.max(1);
        let hc = (geom.plane_px / wc).max(1);
        let band_rows = band_rows.max(1);
        let (base, band_cols) = match inner {
            TileInner::Seq => (ScanStrategy::PlanePar, band_rows.min(wc)),
            TileInner::Segmented { s } => {
                let (g, cols) = band_pieces(wc, s, band_rows);
                (ScanStrategy::Segmented { s: g }, cols)
            }
            TileInner::Chained { s } => {
                let (g, cols) = band_pieces(wc, s, band_rows);
                (ScanStrategy::Chained { s: g }, cols)
            }
        };
        let band = ScanGeometry {
            nplanes: geom.nplanes,
            ndirs: 1,
            wc_min: band_cols,
            plane_px: hc * band_cols,
            hmax: geom.hmax,
        };
        accumulate_footprint(demand, &band, base, threads, tap_blocks, prec);
        return;
    }
    // Staged taps: one panel lease per direction, alive for the pass
    // (half-width words at bf16).
    add_class(demand, half(tap_blocks.max(1) * 3 * geom.plane_px), ndirs);
    if let ScanStrategy::Chained { s } = strategy {
        let s = s.max(1);
        // The look-back board: one [aggregate|prefix] slot of 2·hmax
        // floats per chunk, leased as a single payload for the pass.
        add_class(demand, 2 * hmax * planes * ndirs * s, 1);
        // Per concurrent chunk job: the local panel (~1/s of a plane,
        // half-width at bf16), the zero-carry scan scratch (pack slab +
        // carry + zeros), and the look-back fold columns (corr + next +
        // carry + agg).
        let jobs = threads.min(planes * ndirs * s).max(1);
        add_class(demand, half(geom.plane_px.div_ceil(s)), jobs);
        add_scan_scratch(demand, slab, hmax, if bf16 { 2 } else { 1 }, jobs);
        add_class(demand, hmax, if bf16 { 5 * jobs } else { 4 * jobs });
        return;
    }
    // Mirror run_engine's strategy dispatch: DirFan degenerates to the
    // plane path for single-direction passes, else runs segmented s=1.
    let segments = match strategy {
        ScanStrategy::PlanePar => None,
        ScanStrategy::Segmented { s } => Some(s.max(1)),
        ScanStrategy::DirFan => (ndirs > 1).then_some(1),
        ScanStrategy::Chained { .. } | ScanStrategy::Tiled { .. } => {
            unreachable!("handled above")
        }
    };
    match segments {
        None => {
            // One FusedScratch (b + h slabs, carry + zeros columns) per
            // concurrent plane-block job.
            let jobs = crate::scan::fused::plane_blocks(planes, threads).min(threads).max(1);
            add_scan_scratch(demand, slab, hmax, 2, jobs);
        }
        Some(s) => {
            // Retained phase-1 panels (the barrier form's single block).
            add_class(demand, planes * ndirs * geom.plane_px, 1);
            // Phase-1 piece scratch (pack slab + carry + zeros) per
            // concurrent job.
            let p1 = threads.min(planes * ndirs * s.max(1)).max(1);
            add_scan_scratch(demand, slab, hmax, 1, p1);
            // DrainScratch (3 columns + lazy staging slab) per
            // concurrent phase-2 plane.
            let p2 = threads.min(planes).max(1);
            add_class(demand, slab, p2);
            add_class(demand, hmax, 3 * p2);
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator consumption: release sizing off the cost estimate
// ---------------------------------------------------------------------

/// How many queued requests an eager (idle-worker) release should hold
/// out for, given one request's plan and the pool's occupancy. Replaces
/// the raw pool-`saturated()` bool with a graded rule off the plan's
/// cost estimate:
///
/// * idle pool (`load == 0`): release immediately — more requests add
///   no capacity, so holding only costs latency;
/// * no idle capacity: hold for a full fused `max_batch` (the old
///   saturated behavior — the release would only queue);
/// * partially busy: hold back in proportion to how badly the plan's
///   phase-1 fan (`cost.width`) overflows the capacity left — a narrow
///   plan slots into the gaps and releases eagerly, a wide one waits
///   for the batch to be worth the contention.
///
/// Aged heads are unaffected (callers release them through the age path
/// first, bounding any hold by `max_wait`).
pub fn eager_release_min(
    plan: &ScanPlan,
    pool_load: usize,
    threads: usize,
    max_batch: usize,
) -> usize {
    let max_batch = max_batch.max(1);
    if threads == 0 || pool_load == 0 {
        return 1;
    }
    let idle = threads.saturating_sub(pool_load);
    if idle == 0 {
        return max_batch;
    }
    plan.cost.width.max(1).div_ceil(idle).clamp(1, max_batch)
}

/// [`eager_release_min`] extended with workspace memory pressure: when
/// the coordinator's pool already has most of its retention cap out on
/// lease, releasing more concurrent scans just churns the allocator
/// (over-cap buffers are dropped on return, so every extra in-flight
/// scan becomes misses next round). The hold scales with the leased
/// fraction of `cap_bytes` — at or past the cap the worker holds for a
/// full fused `max_batch`, exactly like a saturated pool. `cap_bytes ==
/// 0` (no cap / no workspace) keeps the pure occupancy rule. Aged heads
/// still bypass this through the age path, so the hold never adds more
/// than `max_wait` latency.
pub fn eager_release_min_mem(
    plan: &ScanPlan,
    pool_load: usize,
    threads: usize,
    max_batch: usize,
    leased_bytes: u64,
    cap_bytes: usize,
) -> usize {
    let base = eager_release_min(plan, pool_load, threads, max_batch);
    if cap_bytes == 0 {
        return base;
    }
    let max_batch = max_batch.max(1);
    let frac = (leased_bytes as f64 / cap_bytes as f64).clamp(0.0, 1.0);
    let mem = ((frac * max_batch as f64).ceil() as usize).clamp(1, max_batch);
    base.max(mem)
}

/// [`eager_release_min_mem`] extended with *deadline pressure*: the
/// serving batcher hands the head request's remaining slack (time to
/// its explicit deadline; `None` for deadline-less traffic). A head
/// with at most one `max_wait` of slack releases immediately — holding
/// for a fuller batch would burn the entire execution budget queueing;
/// moderate slack (within 4x `max_wait`) halves the hold; comfortable
/// slack keeps the plan/memory-derived sizing unchanged. Occupancy and
/// memory pressure never override an urgent deadline: a request that
/// can still make its SLO goes now, a request with time to spare still
/// batches for throughput.
pub fn eager_release_min_slo(
    plan: &ScanPlan,
    pool_load: usize,
    threads: usize,
    max_batch: usize,
    leased_bytes: u64,
    cap_bytes: usize,
    head_slack: Option<std::time::Duration>,
    max_wait: std::time::Duration,
) -> usize {
    let base =
        eager_release_min_mem(plan, pool_load, threads, max_batch, leased_bytes, cap_bytes);
    match head_slack {
        None => base,
        Some(s) if s <= max_wait => 1,
        Some(s) if s <= max_wait * 4 => base.div_ceil(2),
        Some(_) => base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strat(geom: &ScanGeometry, load: usize, threads: usize) -> ScanStrategy {
        plan_scan_with(geom, load, threads, PlanOverride::Auto).strategy
    }

    /// The occupancy scheduler's decision rule (moved with the function
    /// from fused.rs — same pins).
    #[test]
    fn auto_segments_decision_rule() {
        // Saturated pool, narrow planes, or no pool: stay plane-parallel.
        assert_eq!(auto_segments(8, 512, 8), None);
        assert_eq!(auto_segments(16, 1024, 8), None);
        assert_eq!(auto_segments(1, 127, 8), None);
        assert_eq!(auto_segments(4, 512, 1), None);
        assert_eq!(auto_segments(0, 512, 8), None);
        // Low occupancy + wide planes: segment, bounded by width so no
        // segment drops below MIN_SEG_COLS columns.
        assert_eq!(auto_segments(1, 1024, 8), Some(16));
        assert_eq!(auto_segments(4, 512, 8), Some(4));
        assert_eq!(auto_segments(1, 512, 8), Some(8));
        assert_eq!(auto_segments(2, 4096, 16), Some(16));
        // The band the fused-correction drain opened (128 <= wc < 256):
        // previously fenced onto the plane path, now width-capped counts.
        assert_eq!(auto_segments(1, 255, 8), Some(3));
        assert_eq!(auto_segments(1, 128, 8), Some(2));
    }

    /// The planner decision table: geometry × threads × load → strategy.
    #[test]
    fn planner_decision_table() {
        // Enough planes (or no pool): plane-parallel, regardless of size.
        assert_eq!(strat(&ScanGeometry::single_dir(8, 512, 512), 0, 8), ScanStrategy::PlanePar);
        assert_eq!(strat(&ScanGeometry::single_dir(4, 512, 512), 0, 1), ScanStrategy::PlanePar);
        assert_eq!(strat(&ScanGeometry::merged_4dir(16, 384, 384), 0, 8), ScanStrategy::PlanePar);
        assert_eq!(strat(&ScanGeometry::single_dir(0, 64, 64), 0, 8), ScanStrategy::PlanePar);
        // Low-occupancy single-direction wide: the single-pass chained
        // engine at auto_segments' count (bit-identical to the
        // two-phase Segmented it replaced).
        assert_eq!(
            strat(&ScanGeometry::single_dir(1, 8, 512), 0, 8),
            ScanStrategy::Chained { s: 8 }
        );
        assert_eq!(
            strat(&ScanGeometry::single_dir(4, 512, 512), 0, 8),
            ScanStrategy::Chained { s: 4 }
        );
        // The single-direction serving band the fused-correction drain
        // opened (128 <= wc < 256; previously plane-parallel-only).
        assert_eq!(
            strat(&ScanGeometry::single_dir(1, 8, 192), 0, 8),
            ScanStrategy::Chained { s: 3 }
        );
        // Mid-occupancy multi-direction: the fan covers the pool with
        // bit-exact jobs — DirFan, even where segmentation was possible.
        assert_eq!(strat(&ScanGeometry::merged_4dir(2, 384, 384), 0, 8), ScanStrategy::DirFan);
        assert_eq!(strat(&ScanGeometry::merged_4dir(3, 64, 64), 0, 8), ScanStrategy::DirFan);
        // Fan too narrow for the pool on its own: chunked decomposition
        // wins when valid.
        assert_eq!(
            strat(&ScanGeometry::merged_4dir(1, 512, 512), 0, 16),
            ScanStrategy::Chained { s: 8 }
        );
        assert_eq!(
            strat(&ScanGeometry::merged_4dir(1, 128, 128), 0, 8),
            ScanStrategy::Chained { s: 2 }
        );
        // Too narrow to segment, multi-direction: fan anyway.
        assert_eq!(strat(&ScanGeometry::merged_4dir(1, 64, 64), 0, 8), ScanStrategy::DirFan);
        // Too narrow for either: plane.
        assert_eq!(strat(&ScanGeometry::merged_4dir(2, 32, 32), 0, 8), ScanStrategy::PlanePar);
        assert_eq!(strat(&ScanGeometry::single_dir(2, 64, 64), 0, 8), ScanStrategy::PlanePar);
    }

    /// Bit-determinism invariant: the strategy (and wavefront flag)
    /// never depends on the live pool load — only the cost estimate
    /// does, shrinking as capacity disappears.
    #[test]
    fn load_changes_cost_but_never_strategy() {
        let geoms = [
            ScanGeometry::single_dir(1, 8, 512),
            ScanGeometry::single_dir(4, 512, 512),
            ScanGeometry::merged_4dir(1, 512, 512),
            ScanGeometry::merged_4dir(2, 384, 384),
            ScanGeometry::single_dir(8, 64, 64),
        ];
        for geom in geoms {
            for threads in [2usize, 8, 16] {
                let base = plan_scan_with(&geom, 0, threads, PlanOverride::Auto);
                for load in [1usize, 3, 7, 100] {
                    let loaded = plan_scan_with(&geom, load, threads, PlanOverride::Auto);
                    assert_eq!(base.strategy, loaded.strategy, "{geom:?} t{threads} l{load}");
                    assert_eq!(base.wavefront, loaded.wavefront, "{geom:?} t{threads} l{load}");
                    assert!(
                        loaded.cost.span_flops >= base.cost.span_flops,
                        "span must not shrink under load: {geom:?} t{threads} l{load}"
                    );
                }
            }
        }
    }

    /// Every geometry the unit/e2e suites pin bit-identical must plan
    /// onto PlanePar on any realistic host width — the compatibility
    /// fence that keeps exact-equality tests meaningful everywhere.
    #[test]
    fn e2e_pinned_geometries_stay_plane_parallel() {
        let pinned = [
            ScanGeometry::single_dir(8, 64, 64),   // serving bucket c8 64x64
            ScanGeometry::single_dir(2, 8, 8),     // e2e small submits
            ScanGeometry::single_dir(6, 8, 12),    // unit-test shapes
            ScanGeometry::merged_4dir(6, 6, 7),    // pooled merged test
            ScanGeometry::merged_4dir(6, 5, 6),    // canonical unit test
            ScanGeometry::merged_4dir(4, 8, 8),    // compact unit forward
        ];
        for geom in pinned {
            for threads in [1usize, 2, 4, 8, 16, 64, 256] {
                for load in [0usize, 3, 1000] {
                    assert_eq!(
                        strat(&geom, load, threads),
                        ScanStrategy::PlanePar,
                        "{geom:?} t{threads} l{load}"
                    );
                }
            }
        }
    }

    #[test]
    fn overrides_respect_validity_fences() {
        let wide1 = ScanGeometry::single_dir(1, 8, 512);
        let narrow1 = ScanGeometry::single_dir(1, 8, 64);
        let merged = ScanGeometry::merged_4dir(2, 16, 96);
        // plane: always plane.
        assert_eq!(
            plan_scan_with(&wide1, 0, 8, PlanOverride::Plane).strategy,
            ScanStrategy::PlanePar
        );
        // segment: forced wherever a count exists (same count as auto in
        // the low-occupancy regime), fenced off below the width floor.
        assert_eq!(
            plan_scan_with(&wide1, 0, 8, PlanOverride::Segment).strategy,
            ScanStrategy::Segmented { s: 8 }
        );
        assert_eq!(
            plan_scan_with(&ScanGeometry::single_dir(8, 8, 512), 0, 8, PlanOverride::Segment)
                .strategy,
            ScanStrategy::Segmented { s: 2 }
        );
        assert_eq!(
            plan_scan_with(&narrow1, 0, 8, PlanOverride::Segment).strategy,
            ScanStrategy::PlanePar
        );
        // chained: same width fence and forced count as segment, same
        // bits as segment at that count, but single-pass (no wavefront
        // phases — the flag stays off).
        let chained = plan_scan_with(&wide1, 0, 8, PlanOverride::Chained);
        assert_eq!(chained.strategy, ScanStrategy::Chained { s: 8 });
        assert!(!chained.wavefront);
        assert_eq!(
            plan_scan_with(&ScanGeometry::single_dir(8, 8, 512), 0, 8, PlanOverride::Chained)
                .strategy,
            ScanStrategy::Chained { s: 2 }
        );
        assert_eq!(
            plan_scan_with(&narrow1, 0, 8, PlanOverride::Chained).strategy,
            ScanStrategy::PlanePar
        );
        // dirfan: any multi-direction pass (bit-identical at any width);
        // single-direction passes keep the auto rule.
        assert_eq!(
            plan_scan_with(&merged, 0, 8, PlanOverride::DirFan).strategy,
            ScanStrategy::DirFan
        );
        assert_eq!(
            plan_scan_with(&ScanGeometry::merged_4dir(9, 4, 4), 0, 2, PlanOverride::DirFan)
                .strategy,
            ScanStrategy::DirFan
        );
        assert_eq!(
            plan_scan_with(&wide1, 0, 8, PlanOverride::DirFan).strategy,
            ScanStrategy::Chained { s: 8 }
        );
    }

    #[test]
    fn cost_model_shapes() {
        let geom = ScanGeometry::single_dir(1, 512, 512);
        let plane = ScanPlan::plane(&geom, 8);
        let seg = ScanPlan::segmented(4, false, &geom, 8);
        let wave = ScanPlan::segmented(4, true, &geom, 8);
        // Segmenting adds correction work but shortens the span for a
        // single plane on a wide pool.
        assert!(seg.cost.work_flops > plane.cost.work_flops);
        assert!(seg.cost.span_flops < plane.cost.span_flops);
        // Wavefront never lengthens the estimated span.
        assert!(wave.cost.span_flops <= seg.cost.span_flops);
        // A single plane has nothing to hide its correction behind; with
        // more planes the wavefront discount kicks in.
        let geom4 = ScanGeometry::single_dir(4, 512, 512);
        let seg4 = ScanPlan::segmented(4, false, &geom4, 8);
        let wave4 = ScanPlan::segmented(4, true, &geom4, 8);
        assert!(wave4.cost.span_flops < seg4.cost.span_flops);
        // Chained: identical work to Segmented at the same count (same
        // arithmetic), span never worse than the barrier form — the
        // correction chains run concurrently with no phase boundary.
        let chained = ScanPlan::chained(4, &geom, 8);
        assert_eq!(chained.cost.work_flops, seg.cost.work_flops);
        assert!(chained.cost.span_flops <= seg.cost.span_flops);
        assert!(chained.cost.span_flops < plane.cost.span_flops);
        let chained4 = ScanPlan::chained(4, &geom4, 8);
        assert_eq!(chained4.cost.work_flops, seg4.cost.work_flops);
        assert!(chained4.cost.span_flops <= seg4.cost.span_flops);
        // Tiled prices through its inner: same arithmetic, streamed.
        let tiled = ScanPlan::tiled(128, TileInner::Chained { s: 4 }, &geom, 8);
        assert_eq!(tiled.cost, chained.cost);
        assert_eq!(ScanPlan::tiled(128, TileInner::Seq, &geom, 8).cost, plane.cost);
        // Fan width bookkeeping.
        let m = ScanGeometry::merged_4dir(2, 384, 384);
        assert_eq!(ScanPlan::dir_fan(true, &m, 8).cost.width, 8);
        assert_eq!(ScanPlan::segmented(3, true, &m, 8).cost.width, 24);
        assert_eq!(ScanPlan::chained(3, &m, 8).cost.width, 24);
        assert_eq!(ScanPlan::plane(&m, 8).cost.width, 2);
    }

    #[test]
    fn eager_release_sizing_from_plan_cost() {
        let geom = ScanGeometry::single_dir(8, 64, 64); // width 8 plan
        let plan = ScanPlan::plane(&geom, 8);
        // Idle pool swallows the fan: release immediately.
        assert_eq!(eager_release_min(&plan, 0, 8, 4), 1);
        // No idle capacity: hold for a full fused batch (the old
        // saturated() behavior).
        assert_eq!(eager_release_min(&plan, 8, 8, 4), 4);
        assert_eq!(eager_release_min(&plan, 100, 8, 4), 4);
        // Partial capacity: hold back proportionally to how badly the
        // plan overflows it.
        assert_eq!(eager_release_min(&plan, 6, 8, 4), 4); // 8 wide / 2 idle
        assert_eq!(eager_release_min(&plan, 4, 8, 4), 2); // 8 wide / 4 idle
        // Narrow plan on a mostly-idle pool: still eager.
        let narrow = ScanPlan::plane(&ScanGeometry::single_dir(1, 64, 64), 8);
        assert_eq!(eager_release_min(&narrow, 4, 8, 4), 1);
        // Degenerate pools never wedge.
        assert_eq!(eager_release_min(&plan, 0, 0, 4), 1);
        assert_eq!(eager_release_min(&plan, 0, 8, 0), 1);
    }

    #[test]
    fn workspace_footprint_classes_and_scaling() {
        // Degenerate geometries have no footprint.
        assert!(workspace_footprint(
            &ScanGeometry::single_dir(0, 64, 64),
            ScanStrategy::PlanePar,
            8,
            4
        )
        .is_empty());
        assert!(workspace_footprint(
            &ScanGeometry::single_dir(4, 0, 0),
            ScanStrategy::PlanePar,
            8,
            4
        )
        .is_empty());
        // Every entry is a power-of-two class >= the pool minimum, with a
        // positive count, and classes are unique (aggregated).
        let geom = ScanGeometry::single_dir(4, 96, 512);
        for strategy in [
            ScanStrategy::PlanePar,
            ScanStrategy::Segmented { s: 4 },
            ScanStrategy::DirFan,
            ScanStrategy::Chained { s: 4 },
            ScanStrategy::Tiled { band_rows: 128, inner: TileInner::Chained { s: 4 } },
            ScanStrategy::Tiled { band_rows: 128, inner: TileInner::Seq },
        ] {
            let fp = workspace_footprint(&geom, strategy, 8, 4);
            assert!(!fp.is_empty(), "{strategy:?}");
            for &(class, count) in &fp {
                assert!(class.is_power_of_two() && class >= 64, "{strategy:?} class {class}");
                assert!(count > 0, "{strategy:?}");
            }
            let mut classes: Vec<usize> = fp.iter().map(|&(c, _)| c).collect();
            classes.dedup();
            assert_eq!(classes.len(), fp.len(), "{strategy:?} classes must be aggregated");
        }
        // Segmented passes retain phase-1 panels on top of the plane
        // path's scratch, so they can only cost more bytes.
        let bytes = |s: ScanStrategy| {
            workspace_footprint(&geom, s, 8, 4)
                .iter()
                .map(|&(class, count)| class * 4 * count)
                .sum::<usize>()
        };
        assert!(bytes(ScanStrategy::Segmented { s: 4 }) > bytes(ScanStrategy::PlanePar));
        // The chained engine drops the retained-panel array (each chunk
        // holds only its own ~1/s panel), so it prices strictly below
        // the two-phase form at the same count.
        assert!(bytes(ScanStrategy::Chained { s: 4 }) < bytes(ScanStrategy::Segmented { s: 4 }));
        assert!(bytes(ScanStrategy::Chained { s: 4 }) > 0);
        // Tiny geometry: SLAB*hmax and hmax collapse into one class —
        // the aggregation the prewarm path depends on.
        let tiny = ScanGeometry::single_dir(2, 1, 2);
        let fp = workspace_footprint(&tiny, ScanStrategy::PlanePar, 4, 1);
        for &(class, _) in &fp {
            assert!(class.is_power_of_two() && class >= 64);
        }
        // The plan-level helper prices the same model in bytes.
        let plan = ScanPlan::plane(&geom, 8);
        assert_eq!(plan.workspace_bytes(&geom, 8, 4), bytes(ScanStrategy::PlanePar));
        assert!(plan.workspace_bytes(&geom, 8, 4) > 0);
    }

    #[test]
    fn plan_cost_lane_scaling() {
        // 1 lane is exactly the scalar model; wider kernels discount by
        // the pinned memory-bound fraction (8 lanes -> 2.75x effective).
        assert_eq!(effective_lanes(1), 1.0);
        assert_eq!(effective_lanes(0), 1.0);
        assert_eq!(effective_lanes(4), 1.75);
        assert_eq!(effective_lanes(8), 2.75);
        let geom = ScanGeometry::merged_4dir(2, 512, 512);
        for strategy in [
            ScanStrategy::PlanePar,
            ScanStrategy::Segmented { s: 8 },
            ScanStrategy::DirFan,
            ScanStrategy::Chained { s: 8 },
        ] {
            let c1 = plan_cost_lanes(&geom, strategy, false, 8, 1);
            let c8 = plan_cost_lanes(&geom, strategy, false, 8, 8);
            // Vectorized phases shrink; nothing else moves.
            assert!(c8.work_flops < c1.work_flops, "{strategy:?}");
            assert!(c8.span_flops < c1.span_flops, "{strategy:?}");
            assert_eq!(c8.width, c1.width, "{strategy:?}");
            // The discount is bounded by the effective lane factor (the
            // launch overhead term is not divided). (plan_cost itself is
            // plan_cost_lanes at the process kernel's width — not pinned
            // here because the SIMD engine suite flips that kernel
            // concurrently.)
            assert!(c1.work_flops / c8.work_flops <= effective_lanes(8) + 1e-9, "{strategy:?}");
        }
        // The lane discount divides every strategy's scan+correction
        // terms uniformly, so the relations the decision table pins on
        // survive at every lane width.
        for lanes in [1usize, 4, 8] {
            let seg = plan_cost_lanes(&geom, ScanStrategy::Segmented { s: 8 }, false, 8, lanes);
            let chained = plan_cost_lanes(&geom, ScanStrategy::Chained { s: 8 }, false, 8, lanes);
            let plane = plan_cost_lanes(&geom, ScanStrategy::PlanePar, false, 8, lanes);
            assert!(seg.work_flops > plane.work_flops, "lanes {lanes}");
            assert!(chained.work_flops > plane.work_flops, "lanes {lanes}");
            assert!(chained.work_flops <= seg.work_flops, "lanes {lanes}");
            assert!(chained.span_flops < plane.span_flops, "lanes {lanes}");
        }
    }

    #[test]
    fn workspace_footprint_bf16_halves_panels() {
        use crate::scan::simd::Precision;
        let geom = ScanGeometry::merged_4dir(2, 96, 512);
        let bytes = |s: ScanStrategy, prec: Precision| {
            workspace_footprint_prec(&geom, s, 8, 4, prec)
                .iter()
                .map(|&(class, count)| class * 4 * count)
                .sum::<usize>()
        };
        for strategy in [
            ScanStrategy::PlanePar,
            ScanStrategy::Segmented { s: 4 },
            ScanStrategy::DirFan,
            ScanStrategy::Chained { s: 4 },
        ] {
            // bf16 narrows the staged tap panels everywhere (and the
            // chained job panels), so it prices strictly below f32 even
            // with the chained path's extra decode slab + agg column.
            let f32b = bytes(strategy, Precision::F32);
            let bf16b = bytes(strategy, Precision::Bf16);
            assert!(bf16b < f32b, "{strategy:?}: bf16 {bf16b} !< f32 {f32b}");
            // f32 is the default the public pricer uses unless the
            // process override says otherwise (tests never set it).
            assert_eq!(workspace_footprint(&geom, strategy, 8, 4), {
                workspace_footprint_prec(&geom, strategy, 8, 4, Precision::F32)
            });
        }
        // The halving is exactly the packed-word count for the staged
        // taps: PlanePar's only precision-sensitive class is the tap
        // panel lease.
        use crate::scan::simd::bf16_len;
        use crate::util::workspace::size_class;
        let tap_len = 4usize.max(1) * 3 * geom.plane_px;
        let f32_fp = workspace_footprint_prec(&geom, ScanStrategy::PlanePar, 8, 4, Precision::F32);
        let bf_fp = workspace_footprint_prec(&geom, ScanStrategy::PlanePar, 8, 4, Precision::Bf16);
        let count_of = |fp: &[(usize, usize)], class: usize| {
            fp.iter().find(|&&(c, _)| c == class).map_or(0, |&(_, n)| n)
        };
        assert!(count_of(&f32_fp, size_class(tap_len)) >= geom.ndirs);
        assert!(count_of(&bf_fp, size_class(bf16_len(tap_len))) >= geom.ndirs);
        // Degenerate geometry stays empty at every precision.
        assert!(workspace_footprint_prec(
            &ScanGeometry::single_dir(0, 64, 64),
            ScanStrategy::Chained { s: 4 },
            8,
            4,
            Precision::Bf16
        )
        .is_empty());
    }

    #[test]
    fn eager_release_memory_pressure() {
        let geom = ScanGeometry::single_dir(8, 64, 64);
        let plan = ScanPlan::plane(&geom, 8);
        // No cap configured: pure occupancy rule.
        assert_eq!(eager_release_min_mem(&plan, 0, 8, 4, u64::MAX, 0), 1);
        // Idle pool, nothing leased: still eager.
        assert_eq!(eager_release_min_mem(&plan, 0, 8, 4, 0, 1 << 20), 1);
        // Pool fully leased against its cap: hold for a full batch even
        // though threads are idle.
        assert_eq!(eager_release_min_mem(&plan, 0, 8, 4, 1 << 20, 1 << 20), 4);
        // Monotone in leased bytes.
        let cap = 1usize << 20;
        let mut last = 0usize;
        for leased in [0u64, 1 << 18, 1 << 19, 3 << 18, 1 << 20, 1 << 21] {
            let hold = eager_release_min_mem(&plan, 0, 8, 4, leased, cap);
            assert!(hold >= last, "hold must not shrink as leased grows");
            assert!((1..=4).contains(&hold));
            last = hold;
        }
        // Memory pressure never lowers the occupancy floor.
        assert_eq!(eager_release_min_mem(&plan, 8, 8, 4, 0, cap), 4);
    }

    #[test]
    fn eager_release_sizing_with_deadline_pressure() {
        use std::time::Duration;
        let geom = ScanGeometry::single_dir(8, 64, 64); // width 8 plan
        let plan = ScanPlan::plane(&geom, 8);
        let w = Duration::from_micros(1_000);
        let slo = |load, slack: Option<Duration>| {
            eager_release_min_slo(&plan, load, 8, 4, 0, 1 << 20, slack, w)
        };
        // Deadline-less heads keep the plan/memory sizing exactly.
        assert_eq!(slo(8, None), 4);
        assert_eq!(slo(0, None), 1);
        // Urgent head (slack <= max_wait): release now, even on a
        // saturated pool.
        assert_eq!(slo(8, Some(Duration::from_micros(500))), 1);
        assert_eq!(slo(8, Some(w)), 1);
        assert_eq!(slo(8, Some(Duration::ZERO)), 1);
        // Moderate slack (<= 4x max_wait): halve the hold.
        assert_eq!(slo(8, Some(Duration::from_micros(3_000))), 2);
        // Comfortable slack: unchanged.
        assert_eq!(slo(8, Some(Duration::from_micros(10_000))), 4);
        // Memory pressure is likewise overridden by urgency and only
        // softened by moderate slack.
        let mem = |slack| eager_release_min_slo(&plan, 0, 8, 4, 1 << 20, 1 << 20, slack, w);
        assert_eq!(mem(None), 4);
        assert_eq!(mem(Some(Duration::from_micros(100))), 1);
        assert_eq!(mem(Some(Duration::from_micros(3_000))), 2);
    }

    #[test]
    fn override_parsing() {
        assert_eq!(parse_override("auto"), Some(PlanOverride::Auto));
        assert_eq!(parse_override("plane"), Some(PlanOverride::Plane));
        assert_eq!(parse_override("segment"), Some(PlanOverride::Segment));
        assert_eq!(parse_override("dirfan"), Some(PlanOverride::DirFan));
        assert_eq!(parse_override("chained"), Some(PlanOverride::Chained));
        assert_eq!(parse_override("tiled"), Some(PlanOverride::Tiled));
        assert_eq!(parse_override("tiled-chained"), Some(PlanOverride::TiledChained));
        assert_eq!(parse_override("tpu"), None);
        assert!(set_plan_override("bogus").is_err());
        assert!(set_tile_band_rows(0).is_err());
    }

    #[test]
    fn tiled_override_wraps_auto_decision() {
        let rows = tile_band_rows();
        // Wherever auto picks a strategy, `tiled` picks the Tiled wrap
        // of that same strategy (bit-identical inner), wavefront off.
        let cases = [
            (ScanGeometry::single_dir(8, 512, 512), TileInner::Seq), // auto: PlanePar
            (ScanGeometry::single_dir(4, 512, 512), TileInner::Chained { s: 4 }),
            (ScanGeometry::merged_4dir(2, 384, 384), TileInner::Seq), // auto: DirFan
        ];
        for (geom, inner) in cases {
            let p = plan_scan_with(&geom, 0, 8, PlanOverride::Tiled);
            assert_eq!(p.strategy, ScanStrategy::Tiled { band_rows: rows, inner }, "{geom:?}");
            assert!(!p.wavefront, "{geom:?}");
        }
        // tiled-chained: Chained inner wherever a chunk count exists
        // (same fence and count as the chained override)...
        let wide = ScanGeometry::single_dir(1, 8, 512);
        assert_eq!(
            plan_scan_with(&wide, 0, 8, PlanOverride::TiledChained).strategy,
            ScanStrategy::Tiled { band_rows: rows, inner: TileInner::Chained { s: 8 } }
        );
        // ...else the Seq inner (still tiled — the override's point is
        // exercising the streaming path).
        let narrow = ScanGeometry::single_dir(1, 8, 64);
        assert_eq!(
            plan_scan_with(&narrow, 0, 8, PlanOverride::TiledChained).strategy,
            ScanStrategy::Tiled { band_rows: rows, inner: TileInner::Seq }
        );
    }

    #[test]
    fn maybe_tile_bounds_oversized_footprints() {
        let rows = tile_band_rows();
        let geom = ScanGeometry::single_dir(4, 2048, 2048);
        let p = ScanPlan::plane(&geom, 8);
        let untiled = p.workspace_bytes(&geom, 8, 4);
        assert!(untiled > 0);
        // Cap comfortably above the demand, or no cap at all: the plan
        // passes through untouched.
        assert_eq!(maybe_tile(p, &geom, 8, 4, untiled * 2, false).strategy, p.strategy);
        assert_eq!(maybe_tile(p, &geom, 8, 4, 0, false).strategy, p.strategy);
        // Cap below the demand: wrapped in Tiled with the matching
        // inner, and the tiled footprint prices far below the untiled
        // one (the whole point — one band's leases, not the image's).
        let tiled = maybe_tile(p, &geom, 8, 4, untiled / 2, false);
        assert_eq!(
            tiled.strategy,
            ScanStrategy::Tiled { band_rows: rows, inner: TileInner::Seq }
        );
        let tiled_bytes = tiled.workspace_bytes(&geom, 8, 4);
        assert!(
            tiled_bytes * 2 <= untiled,
            "tiled {tiled_bytes} must be <= half of untiled {untiled}"
        );
        // The inner follows the wrapped strategy.
        let c = ScanPlan::chained(8, &geom, 8);
        assert_eq!(
            maybe_tile(c, &geom, 8, 4, 1, false).strategy,
            ScanStrategy::Tiled { band_rows: rows, inner: TileInner::Chained { s: 8 } }
        );
        let s = ScanPlan::segmented(8, true, &geom, 8);
        assert_eq!(
            maybe_tile(s, &geom, 8, 4, 1, false).strategy,
            ScanStrategy::Tiled { band_rows: rows, inner: TileInner::Segmented { s: 8 } }
        );
        // Already tiled: idempotent.
        let t = ScanPlan::tiled(64, TileInner::Seq, &geom, 8);
        assert_eq!(maybe_tile(t, &geom, 8, 4, 1, false).strategy, t.strategy);
        // bf16 prices the bf16 model (smaller, so a cap between the two
        // tiles f32 but not bf16).
        let f32b = untiled;
        let bf16b: usize = workspace_footprint_prec(
            &geom,
            ScanStrategy::PlanePar,
            8,
            4,
            crate::scan::simd::Precision::Bf16,
        )
        .iter()
        .map(|&(class, count)| class * 4 * count)
        .sum();
        assert!(bf16b < f32b);
        assert_eq!(maybe_tile(p, &geom, 8, 4, bf16b, true).strategy, p.strategy);
        assert!(matches!(
            maybe_tile(p, &geom, 8, 4, bf16b, false).strategy,
            ScanStrategy::Tiled { .. }
        ));
    }
}
