//! Pure-Rust GSPN propagation reference (the algorithmic core of [1]).
//!
//! * [`taps`] — tridiagonal propagation coefficients + the
//!   Stability-Context normalisation (row-stochastic w_i).
//! * [`core`] — the canonical left-to-right line scan (Eq. 1) with the
//!   GSPN-local chunked variant, plus output modulation (Eq. 2).
//! * [`direction`] — the four directional passes and learned merging.
//! * [`fused`] — the column-staged fused scan engine: pack →
//!   4-direction scan → merge → modulate in one pass, bit-identical to
//!   the reference path above (the production hot path; see its module
//!   docs for how it maps onto the paper's three GPU bottlenecks).
//! * [`gmatrix`] — the Eq. 4 dense expansion (linear-attention view),
//!   used for validation and attention-map introspection.
//! * [`compact`] — GSPN-2's compact channel propagation (§4.2):
//!   channel-shared weights + compressive proxy dimension.
//!
//! This module is the numerical ground truth for the PJRT artifacts
//! (integration tests compare both) and the workload description that
//! `crate::gpusim` costs out.
//!
//! CPU parallelism: every parallel entry point (`scan_l2r_pool`/`_par`,
//! `merged_4dir_pool`/`_par`, `scan_l2r_split` with `threads > 1`)
//! submits to the shared [`crate::util::ThreadPool`] — nothing in this
//! module spawns ad-hoc OS threads per call. Plane-level fan-out is
//! bit-identical to the serial reference; only the segment decomposition
//! reassociates (and is tested to 1e-4 against sequential). How a pooled
//! pass decomposes is decided by the execution planner
//! ([`plan::plan_scan`]): plane-parallel and the per-direction fan
//! (`DirFan`) are bit-identical to `scan_l2r`; a low-occupancy pass with
//! ≥ 128 canonical columns is chunk-decomposed, and its output is
//! bit-identical to [`split::scan_l2r_split`] at the planned count
//! instead ([`split`] is kept as that reference). The planner's
//! production decomposition is the single-pass *chained* engine
//! (`Chained`): each column chunk is one job that scans from a zero
//! carry, publishes its aggregate on a look-back board, resolves its
//! true carry from predecessors' published prefixes, folds the
//! correction into its still-hot panel, and drains — no phase barrier,
//! no retained-panel array, no second panel read. The two-phase
//! `Segmented` engine (forced via `scan.plan = segment` or the `_seg` /
//! `_seg_wave` entry points) is kept as the bit/bench reference; its
//! passes run wavefront by default — each (plane, direction)'s fused
//! correction + drain is its own pool continuation of that direction's
//! phase-1 jobs (chained to preserve the merge order), not a global
//! barrier — and in both engines the carry correction is computed
//! inside the scatter drain, so each panel is read once and never
//! re-written.
//!
//! # SIMD dispatch & precision
//!
//! The three inner loops of the fused engine — the `scan_col`
//! recurrence, the `correct_col` look-back/correction fold, and the
//! scatter epilogue's merge/modulate — live in [`simd`] as explicit
//! lane kernels: runtime-dispatched AVX2 (x86_64, 8 lanes) and NEON
//! (aarch64, 4 lanes) beside a scalar reference the vector kernels are
//! pinned **bit-identical** to (same association, no FMA — every lane
//! computes the exact scalar expression). The lane axis is the row
//! index within a canonical column: the previous column is read at
//! r-1/r/r+1, so there is no loop-carried dependency across rows, while
//! the column-to-column carry stays sequential in f32 exactly as the
//! recurrence demands. The kernel is detected once per process and can
//! be forced with `scan.simd = auto|scalar|avx2|neon` (env
//! `GSPN2_SCAN_SIMD`), mirroring the `scan.plan` override, so every
//! exact-pinned suite runs under any kernel.
//!
//! Orthogonally, `scan.precision = f32|bf16` (env
//! `GSPN2_SCAN_PRECISION`, default `f32`) stores the *staged tap
//! panels* and the chained engine's *job-local panels* as bf16 words
//! packed two per f32 pool slot — halving the staged working set and
//! the corresponding [`plan::workspace_footprint`] classes. Only
//! storage narrows: the scan recurrence, the carry columns, the
//! publication board, and every accumulation stay f32 (taps decode in
//! the lanes; panel stores round to nearest even). `f32` remains the
//! bit-exact default; `bf16` is fenced behind tolerance-pinned tests
//! (`|bf16 − f32| ≤ (|f32| + 1)·2⁻⁶` elementwise, documented in
//! [`simd`]) and is safe to enable when outputs feed activations or
//! attention maps rather than bit-compared artifacts.
//!
//! Scratch memory: every execution strategy leases its per-call
//! buffers (pack slabs, retained panels, staging columns, correction
//! buffers) from a [`crate::util::BufferPool`] workspace instead of
//! allocating. The public entry points use the process-global pool; the
//! `_ws` variants (`fused_scan_l2r_pool_ws`, `fused_scan_dir_pool_ws`,
//! `fused_merged_canonical_ws`) take an explicit workspace so callers —
//! the serving coordinator above all — can isolate and observe their
//! own pool; `fused_scan_l2r_pool_ws_into` additionally writes the
//! *output* into a workspace-recycled buffer
//! ([`crate::util::BufferPool::take_zeroed`]), which is how the
//! coordinator's reply tensors stop being the hot path's last per-
//! request allocation. Pooling is bit-transparent: leases are zero-reset exactly
//! where the old fresh-`vec!` code relied on zeroing, so pooled output
//! is `==` fresh output under every strategy (property-tested). The
//! planner prices a plan's workspace demand per size class
//! ([`plan::workspace_footprint`]) so pools can be pre-warmed at bucket
//! registration, and [`plan::eager_release_min_mem`] folds pool memory
//! pressure into batch-release sizing.

pub mod compact;
pub mod core;
pub mod direction;
pub mod fused;
pub mod gmatrix;
pub mod plan;
pub mod simd;
pub mod split;
pub mod taps;

pub use compact::{CompactGspnUnit, Proj};
pub use core::{
    kchunk_valid, output_modulation, output_modulation_owned, scan_flops, scan_l2r,
    scan_l2r_par, scan_l2r_pool,
};
pub use direction::{
    from_canonical, merged_4dir, merged_4dir_par, merged_4dir_pool, merged_4dir_ref, scan_dir,
    to_canonical, Direction, DIRECTIONS,
};
pub use fused::{
    fused_merged_4dir, fused_merged_4dir_chained, fused_merged_4dir_fan, fused_merged_4dir_par,
    fused_merged_4dir_pool, fused_merged_4dir_seg, fused_merged_4dir_seg_wave,
    fused_merged_4dir_seg_wave_twopass, fused_merged_canonical_ws, fused_scan_dir,
    fused_scan_dir_chained, fused_scan_dir_pool, fused_scan_dir_pool_ws, fused_scan_dir_seg,
    fused_scan_dir_seg_wave, fused_scan_dir_seg_wave_twopass, fused_scan_l2r,
    fused_scan_l2r_chained, fused_scan_l2r_par, fused_scan_l2r_pool, fused_scan_l2r_pool_ws,
    fused_scan_l2r_pool_ws_into, fused_scan_l2r_seg, fused_scan_l2r_seg_wave,
    fused_scan_l2r_seg_wave_twopass,
};
pub use gmatrix::{attention_map, expand_g};
pub use plan::{
    auto_segments, eager_release_min, eager_release_min_mem, eager_release_min_slo, plan_scan,
    workspace_footprint, workspace_footprint_prec, PlanOverride, ScanGeometry, ScanPlan,
    ScanStrategy,
};
pub use simd::{
    bf16_narrow, bf16_widen, set_precision_override, set_simd_override, Precision, SimdKernel,
};
pub use split::{scan_l2r_split, scan_l2r_split_pool, segment_transfer, Banded};
pub use taps::Taps;
