//! Pure-Rust GSPN propagation reference (the algorithmic core of [1]).
//!
//! * [`taps`] — tridiagonal propagation coefficients + the
//!   Stability-Context normalisation (row-stochastic w_i).
//! * [`core`] — the canonical left-to-right line scan (Eq. 1) with the
//!   GSPN-local chunked variant, plus output modulation (Eq. 2).
//! * [`direction`] — the four directional passes and learned merging.
//! * [`engine`] — the column-staged fused scan engine: pack →
//!   4-direction scan → merge → modulate in one pass, bit-identical to
//!   the reference path above (the production hot path). Split along
//!   the carry algebra into `engine/pack.rs` (canonical tap/slab
//!   staging), `engine/chunk.rs` (zero-/carried-state chunk scans),
//!   `engine/carry.rs` (carry resolution — see the `CarrySource`
//!   contract below), `engine/drain.rs` (the scatter/merge/modulate
//!   epilogue and the segmented engines), and `engine/tiled.rs` (the
//!   bounded-memory streaming band executor).
//! * [`fused`] — the compatibility facade re-exporting the engine's
//!   entry points under their historical `scan::fused::*` paths.
//! * [`gmatrix`] — the Eq. 4 dense expansion (linear-attention view),
//!   used for validation and attention-map introspection.
//! * [`compact`] — GSPN-2's compact channel propagation (§4.2):
//!   channel-shared weights + compressive proxy dimension.
//!
//! This module is the numerical ground truth for the PJRT artifacts
//! (integration tests compare both) and the workload description that
//! `crate::gpusim` costs out.
//!
//! CPU parallelism: every parallel entry point (`scan_l2r_pool`/`_par`,
//! `merged_4dir_pool`/`_par`, `scan_l2r_split` with `threads > 1`)
//! submits to the shared [`crate::util::ThreadPool`] — nothing in this
//! module spawns ad-hoc OS threads per call. Plane-level fan-out is
//! bit-identical to the serial reference; only the segment decomposition
//! reassociates (and is tested to 1e-4 against sequential). How a pooled
//! pass decomposes is decided by the execution planner
//! ([`plan::plan_scan`]): plane-parallel and the per-direction fan
//! (`DirFan`) are bit-identical to `scan_l2r`; a low-occupancy pass with
//! ≥ 128 canonical columns is chunk-decomposed, and its output is
//! bit-identical to [`split::scan_l2r_split`] at the planned count
//! instead ([`split`] is kept as that reference). The planner's
//! production decomposition is the single-pass *chained* engine
//! (`Chained`): each column chunk is one job that scans from a zero
//! carry, publishes its aggregate on a look-back board, resolves its
//! true carry from predecessors' published prefixes, folds the
//! correction into its still-hot panel, and drains — no phase barrier,
//! no retained-panel array, no second panel read. The two-phase
//! `Segmented` engine (forced via `scan.plan = segment` or the `_seg` /
//! `_seg_wave` entry points) is kept as the bit/bench reference; its
//! passes run wavefront by default — each (plane, direction)'s fused
//! correction + drain is its own pool continuation of that direction's
//! phase-1 jobs (chained to preserve the merge order), not a global
//! barrier — and in both engines the carry correction is computed
//! inside the scatter drain, so each panel is read once and never
//! re-written.
//!
//! # The `CarrySource` contract
//!
//! Every strategy above is a composition of the same primitives, glued
//! by one question: *where does this piece's entry carry come from?*
//! `engine::CarrySource` names the four answers —
//!
//! * `Zero` — scan from rest state; `seed` returns `false` and leaves
//!   the destination untouched, so callers keep the exact all-zero
//!   fast path (including `-0.0` preservation) of the historical code.
//! * `Resolved(&[f32])` — the carry column is already materialised
//!   (the segmented engine's phase-2 fold).
//! * `Lookback(board, block)` — resolve from a [`crate::util::workspace::BlockBoard`]
//!   publication (the chained engine's decoupled look-back).
//! * `External(carry, plane)` — a serialized [`engine::ExternalCarry`]
//!   hand-off from outside the call: the previous row-band of a tiled
//!   stream today, a remote shard's boundary column under LASP-2-style
//!   sequence parallelism tomorrow (`ExternalCarry::to_bytes` /
//!   `from_bytes` is the wire format).
//!
//! The invariant every source upholds: seeding a piece with the
//! *corrected* last column of its predecessor and rescanning is
//! bit-identical to the unsplit scan — chunk resets (`gi % chunk == 0`)
//! kill corrections at exactly the same columns either way. That
//! invariant is what makes the tiled executor exact.
//!
//! # Tiled streaming (bounded-memory high-res serving)
//!
//! `ScanStrategy::Tiled { band_rows, inner }` executes a huge geometry
//! as a stream of canonical row-band tiles: each band is scanned by the
//! full engine (any inner strategy — `TileInner::Seq`, `Segmented`,
//! `Chained`) from the `External` carry of the previous band, and each
//! band's staged taps + scratch are leased and returned *within* the
//! band, so peak workspace is bounded by one band instead of the whole
//! image. Band boundaries fall on whole segment-piece boundaries of the
//! untiled decomposition, so tiled output is `==` untiled output for
//! every band size (property-pinned). The planner wraps its own
//! decision in a Tiled plan when the footprint would exceed the
//! workspace cap ([`plan::maybe_tile`]); `scan.plan = tiled` /
//! `tiled-chained` (env `GSPN2_SCAN_PLAN`) forces it, and
//! `scan.tile_band_rows` (env `GSPN2_SCAN_TILE_BAND_ROWS`) sets the
//! band height.
//!
//! # SIMD dispatch & precision
//!
//! The three inner loops of the fused engine — the `scan_col`
//! recurrence, the `correct_col` look-back/correction fold, and the
//! scatter epilogue's merge/modulate — live in [`simd`] as explicit
//! lane kernels: runtime-dispatched AVX2 (x86_64, 8 lanes) and NEON
//! (aarch64, 4 lanes) beside a scalar reference the vector kernels are
//! pinned **bit-identical** to (same association, no FMA — every lane
//! computes the exact scalar expression). The lane axis is the row
//! index within a canonical column: the previous column is read at
//! r-1/r/r+1, so there is no loop-carried dependency across rows, while
//! the column-to-column carry stays sequential in f32 exactly as the
//! recurrence demands. The kernel is detected once per process and can
//! be forced with `scan.simd = auto|scalar|avx2|neon` (env
//! `GSPN2_SCAN_SIMD`), mirroring the `scan.plan` override, so every
//! exact-pinned suite runs under any kernel.
//!
//! Orthogonally, `scan.precision = f32|bf16` (env
//! `GSPN2_SCAN_PRECISION`, default `f32`) stores the *staged tap
//! panels* and the chained engine's *job-local panels* as bf16 words
//! packed two per f32 pool slot — halving the staged working set and
//! the corresponding [`plan::workspace_footprint`] classes. Only
//! storage narrows: the scan recurrence, the carry columns, the
//! publication board, and every accumulation stay f32 (taps decode in
//! the lanes; panel stores round to nearest even). `f32` remains the
//! bit-exact default; `bf16` is fenced behind tolerance-pinned tests
//! (`|bf16 − f32| ≤ (|f32| + 1)·2⁻⁶` elementwise, documented in
//! [`simd`]) and is safe to enable when outputs feed activations or
//! attention maps rather than bit-compared artifacts.
//!
//! Scratch memory: every execution strategy leases its per-call
//! buffers (pack slabs, retained panels, staging columns, correction
//! buffers) from a [`crate::util::BufferPool`] workspace instead of
//! allocating. The public entry points use the process-global pool; the
//! `_ws` variants (`fused_scan_l2r_pool_ws`, `fused_scan_dir_pool_ws`,
//! `fused_merged_canonical_ws`) take an explicit workspace so callers —
//! the serving coordinator above all — can isolate and observe their
//! own pool; `fused_scan_l2r_pool_ws_into` additionally writes the
//! *output* into a workspace-recycled buffer
//! ([`crate::util::BufferPool::take_zeroed`]), which is how the
//! coordinator's reply tensors stop being the hot path's last per-
//! request allocation. Pooling is bit-transparent: leases are zero-reset exactly
//! where the old fresh-`vec!` code relied on zeroing, so pooled output
//! is `==` fresh output under every strategy (property-tested). The
//! planner prices a plan's workspace demand per size class
//! ([`plan::workspace_footprint`]) so pools can be pre-warmed at bucket
//! registration, and [`plan::eager_release_min_mem`] folds pool memory
//! pressure into batch-release sizing.

pub mod compact;
pub mod core;
pub mod direction;
pub mod engine;
pub mod fused;
pub mod gmatrix;
pub mod plan;
pub mod simd;
pub mod split;
pub mod taps;

pub use compact::{CompactGspnUnit, Proj};
pub use core::{
    kchunk_valid, output_modulation, output_modulation_owned, scan_flops, scan_l2r,
    scan_l2r_par, scan_l2r_pool,
};
pub use direction::{
    from_canonical, merged_4dir, merged_4dir_par, merged_4dir_pool, merged_4dir_ref, scan_dir,
    to_canonical, Direction, DIRECTIONS,
};
pub use fused::{
    fused_merged_4dir, fused_merged_4dir_chained, fused_merged_4dir_fan, fused_merged_4dir_par,
    fused_merged_4dir_pool, fused_merged_4dir_seg, fused_merged_4dir_seg_wave,
    fused_merged_4dir_seg_wave_twopass, fused_merged_canonical_ws, fused_scan_dir,
    fused_scan_dir_chained, fused_scan_dir_pool, fused_scan_dir_pool_ws, fused_scan_dir_seg,
    fused_scan_dir_seg_wave, fused_scan_dir_seg_wave_twopass, fused_scan_l2r,
    fused_scan_l2r_chained, fused_scan_l2r_par, fused_scan_l2r_pool, fused_scan_l2r_pool_ws,
    fused_scan_l2r_pool_ws_into, fused_scan_l2r_seg, fused_scan_l2r_seg_wave,
    fused_scan_l2r_seg_wave_twopass, ExternalCarry,
};
pub use gmatrix::{attention_map, expand_g};
pub use plan::{
    auto_segments, eager_release_min, eager_release_min_mem, eager_release_min_slo, maybe_tile,
    plan_scan, set_tile_band_rows, tile_band_rows, workspace_footprint, workspace_footprint_prec,
    PlanOverride, ScanGeometry, ScanPlan, ScanStrategy, TileInner,
};
pub use simd::{
    bf16_narrow, bf16_widen, set_precision_override, set_simd_override, Precision, SimdKernel,
};
pub use split::{scan_l2r_split, scan_l2r_split_pool, segment_transfer, Banded};
pub use taps::Taps;
