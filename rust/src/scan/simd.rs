//! Runtime-dispatched SIMD lane kernels for the fused scan engine, plus
//! the opt-in reduced-precision (bf16) tap/panel storage they decode.
//!
//! # Lane layout
//!
//! The engine's inner loops all run over one *canonical column* of `hc`
//! contiguous f32s (staged column-major by `fused::StagedTaps` /
//! `pack_slab`). Along the propagation direction the recurrence is
//! sequential — column `i` needs column `i-1` — but *within* a column the
//! three-tap stencil reads the previous column at rows `r-1`, `r`, `r+1`
//! only: there is no loop-carried dependency over `r`. So the lanes run
//! along the row axis of a column (unit stride in every operand), the
//! boundary rows `r = 0` and `r = h-1` stay scalar with their literal
//! `0.0` terms, and the column-to-column carry stays a sequential hot
//! column exactly as in the scalar engine. This is the CPU analog of the
//! paper's "one warp per channel slice with the previous column staged in
//! shared memory": the warp is the vector register, the shared-memory
//! column is the L1-resident carry.
//!
//! # Bit-exactness
//!
//! Every vector kernel evaluates the *same association* as the pinned
//! scalar expression — `((tu*pm + tc*pc) + td*pp) + b`, element-wise IEEE
//! mul/add, **no FMA contraction** — so each lane computes bit-identically
//! to the scalar loop and the suite-wide `==` pins hold under any kernel.
//! The active kernel is chosen once per process from CPU detection and
//! can be forced via `scan.simd = auto|scalar|avx2|neon` or the
//! `GSPN2_SCAN_SIMD` env hook (mirroring `GSPN2_SCAN_PLAN`), so CI re-runs
//! the exact-pinned suites under every kernel the host supports.
//!
//! # Reduced precision (`scan.precision = bf16`)
//!
//! bf16 is f32 with the low 16 mantissa bits dropped: widening is an
//! exact bit shift, narrowing rounds to nearest-even. The opt-in mode
//! stores *read-mostly* operands — staged tap panels and the chained
//! scan's thread-local panels — as bf16 words packed two-per-f32-slot in
//! ordinary [`crate::util::workspace::BufferPool`] leases, halving the
//! staged working set. All arithmetic still happens in f32: taps widen in
//! the lanes, the recurrence carry and every accumulation stay f32, and
//! only storage narrows. The mode is NOT bit-exact and is fenced behind
//! tolerance-pinned tests; `f32` stays the default.

use std::sync::atomic::{AtomicU8, Ordering};

// ---------------------------------------------------------------------
// Kernel selection: detection, override plumbing
// ---------------------------------------------------------------------

/// An inner-kernel implementation the dispatcher can select. All three
/// are pinned bit-identical; they differ only in lane width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdKernel {
    /// Pinned scalar loops — the portable reference every vector kernel
    /// must match bit-for-bit.
    Scalar = 0,
    /// 8 x f32 AVX2 lanes (x86_64, runtime-detected).
    Avx2 = 1,
    /// 4 x f32 NEON lanes (aarch64).
    Neon = 2,
}

impl SimdKernel {
    pub fn name(self) -> &'static str {
        match self {
            SimdKernel::Scalar => "scalar",
            SimdKernel::Avx2 => "avx2",
            SimdKernel::Neon => "neon",
        }
    }

    /// f32 lanes per vector op (1 for scalar). Feeds the planner's
    /// effective-lanes cost discount and the bench host header.
    pub fn lanes(self) -> usize {
        match self {
            SimdKernel::Scalar => 1,
            SimdKernel::Avx2 => 8,
            SimdKernel::Neon => 4,
        }
    }

    /// Whether this host can run the kernel. Forcing an unsupported
    /// kernel is rejected at set time (config) or panics (env hook).
    pub fn supported(self) -> bool {
        match self {
            SimdKernel::Scalar => true,
            SimdKernel::Avx2 => avx2_supported(),
            SimdKernel::Neon => neon_supported(),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}
#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

#[cfg(target_arch = "aarch64")]
fn neon_supported() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}
#[cfg(not(target_arch = "aarch64"))]
fn neon_supported() -> bool {
    false
}

/// The widest kernel this host supports.
fn detect() -> SimdKernel {
    if SimdKernel::Avx2.supported() {
        SimdKernel::Avx2
    } else if SimdKernel::Neon.supported() {
        SimdKernel::Neon
    } else {
        SimdKernel::Scalar
    }
}

const OV_UNSET: u8 = u8::MAX;
static SIMD_OVERRIDE: AtomicU8 = AtomicU8::new(OV_UNSET);

fn parse_kernel(name: &str) -> Option<SimdKernel> {
    match name {
        "scalar" => Some(SimdKernel::Scalar),
        "avx2" => Some(SimdKernel::Avx2),
        "neon" => Some(SimdKernel::Neon),
        _ => None,
    }
}

/// Set the process-wide kernel override (the `scan.simd` config knob).
/// Accepts `auto | scalar | avx2 | neon`; `auto` clears the override so
/// the `GSPN2_SCAN_SIMD` env hook (then CPU detection) applies again.
/// Forcing a kernel this host cannot run is an error — a forced kernel
/// that silently fell back would turn the CI kernel-matrix legs into
/// no-ops.
pub fn set_simd_override(name: &str) -> Result<(), String> {
    if name == "auto" {
        SIMD_OVERRIDE.store(OV_UNSET, Ordering::Relaxed);
        return Ok(());
    }
    let k = parse_kernel(name)
        .ok_or_else(|| format!("unknown scan.simd {name:?} (want auto|scalar|avx2|neon)"))?;
    if !k.supported() {
        return Err(format!(
            "scan.simd = {name:?} is not supported on this host (detected: {})",
            detect().name()
        ));
    }
    SIMD_OVERRIDE.store(k as u8, Ordering::Relaxed);
    Ok(())
}

/// The active kernel: the config knob if set, else `GSPN2_SCAN_SIMD`
/// (read once), else CPU detection. As with `GSPN2_SCAN_PLAN`, an
/// *invalid* env value panics rather than silently dispatching the
/// default — the hook exists so CI re-runs the suite under forced
/// kernels, and a typo that quietly tested auto-detection instead would
/// be a green lie. An env value naming an unsupported kernel also
/// panics, for the same reason.
pub fn kernel() -> SimdKernel {
    let v = SIMD_OVERRIDE.load(Ordering::Relaxed);
    if v != OV_UNSET {
        return kernel_from_u8(v);
    }
    let k = match std::env::var("GSPN2_SCAN_SIMD") {
        Ok(s) if s == "auto" => detect(),
        Ok(s) => {
            let k = parse_kernel(&s).unwrap_or_else(|| {
                panic!("GSPN2_SCAN_SIMD={s:?} is not one of auto|scalar|avx2|neon")
            });
            if !k.supported() {
                panic!(
                    "GSPN2_SCAN_SIMD={s:?} is not supported on this host (detected: {})",
                    detect().name()
                );
            }
            k
        }
        Err(_) => detect(),
    };
    SIMD_OVERRIDE.store(k as u8, Ordering::Relaxed);
    k
}

fn kernel_from_u8(v: u8) -> SimdKernel {
    match v {
        1 => SimdKernel::Avx2,
        2 => SimdKernel::Neon,
        _ => SimdKernel::Scalar,
    }
}

/// f32 lanes of the active kernel — the planner's cost-model input.
pub fn lanes() -> usize {
    kernel().lanes()
}

/// Comma-joined list of the vector features this host reports, for the
/// bench JSON host header (`BENCH_scan` / `BENCH_serve`), so crossover
/// retuning can read lane context straight from CI artifacts.
pub fn detected_features() -> String {
    let mut fs: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("sse2", std::arch::is_x86_feature_detected!("sse2")),
            ("avx", std::arch::is_x86_feature_detected!("avx")),
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
        ] {
            if have {
                fs.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            fs.push("neon");
        }
    }
    if fs.is_empty() {
        fs.push("none");
    }
    fs.join(",")
}

// ---------------------------------------------------------------------
// Precision selection
// ---------------------------------------------------------------------

/// Storage precision for staged tap panels and chained thread-local
/// panels. Arithmetic is always f32; this only narrows what is *stored*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full-width storage — bit-exact, the default.
    F32 = 0,
    /// bf16 storage, f32 accumulation — halves staged bytes, tolerance-
    /// pinned (see the module docs) rather than `==`.
    Bf16 = 1,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }
}

static PREC_OVERRIDE: AtomicU8 = AtomicU8::new(OV_UNSET);

fn parse_precision(name: &str) -> Option<Precision> {
    match name {
        "f32" => Some(Precision::F32),
        "bf16" => Some(Precision::Bf16),
        _ => None,
    }
}

/// Set the process-wide storage precision (the `scan.precision` config
/// knob). Accepts `f32 | bf16`. NOTE: flipping this changes result bits
/// process-wide; unlike the kernel override it must never be toggled
/// around individual exact-pinned tests (the engine's bf16 tests thread
/// an explicit precision instead).
pub fn set_precision_override(name: &str) -> Result<(), String> {
    let p = parse_precision(name)
        .ok_or_else(|| format!("unknown scan.precision {name:?} (want f32|bf16)"))?;
    PREC_OVERRIDE.store(p as u8, Ordering::Relaxed);
    Ok(())
}

/// The active storage precision: config knob, else `GSPN2_SCAN_PRECISION`
/// (read once; invalid values panic like the other scan env hooks), else
/// the bit-exact `f32` default.
pub fn precision() -> Precision {
    let v = PREC_OVERRIDE.load(Ordering::Relaxed);
    if v != OV_UNSET {
        return if v == Precision::Bf16 as u8 { Precision::Bf16 } else { Precision::F32 };
    }
    let p = match std::env::var("GSPN2_SCAN_PRECISION") {
        Ok(s) => parse_precision(&s)
            .unwrap_or_else(|| panic!("GSPN2_SCAN_PRECISION={s:?} is not one of f32|bf16")),
        Err(_) => Precision::F32,
    };
    PREC_OVERRIDE.store(p as u8, Ordering::Relaxed);
    p
}

// ---------------------------------------------------------------------
// bf16 scalar conversions
// ---------------------------------------------------------------------

/// f32 elements needed to store `n` bf16 words in a pooled f32 lease
/// (two words per slot; see `Lease::as_u16`).
pub(crate) fn bf16_len(n: usize) -> usize {
    n.div_ceil(2)
}

/// Narrow an f32 to bf16 with round-to-nearest-even; NaN keeps its sign
/// and top mantissa bits with the quiet bit forced so it cannot round to
/// infinity.
#[inline]
pub fn bf16_narrow(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 1;
    }
    // Cannot overflow: the largest non-NaN payload is 0xff80_0000 (-inf).
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// Widen a bf16 word to f32 — exact (a pure bit shift).
#[inline]
pub fn bf16_widen(hbits: u16) -> f32 {
    f32::from_bits((hbits as u32) << 16)
}

// ---------------------------------------------------------------------
// Tap views: one type the kernels accept at either storage precision
// ---------------------------------------------------------------------

/// Borrowed staged tap panels (up, center, down) at the active storage
/// precision. Panels are column-major; [`TapPanels::col`] slices out one
/// canonical column for the kernels.
#[derive(Clone, Copy)]
pub(crate) enum TapPanels<'a> {
    F32 { tu: &'a [f32], tc: &'a [f32], td: &'a [f32] },
    Bf16 { tu: &'a [u16], tc: &'a [u16], td: &'a [u16] },
}

impl<'a> TapPanels<'a> {
    /// Column `j` of each tap panel (`hc` rows per column).
    #[inline]
    pub(crate) fn col(self, j: usize, hc: usize) -> TapCols<'a> {
        let (a, b) = (j * hc, (j + 1) * hc);
        match self {
            TapPanels::F32 { tu, tc, td } => {
                TapCols::F32 { tu: &tu[a..b], tc: &tc[a..b], td: &td[a..b] }
            }
            TapPanels::Bf16 { tu, tc, td } => {
                TapCols::Bf16 { tu: &tu[a..b], tc: &tc[a..b], td: &td[a..b] }
            }
        }
    }
}

/// One canonical column of taps, ready for a kernel call.
#[derive(Clone, Copy)]
pub(crate) enum TapCols<'a> {
    F32 { tu: &'a [f32], tc: &'a [f32], td: &'a [f32] },
    Bf16 { tu: &'a [u16], tc: &'a [u16], td: &'a [u16] },
}

// ---------------------------------------------------------------------
// Epilogue ops
// ---------------------------------------------------------------------

/// The fused scatter epilogue's per-element operation: first-direction
/// assign, softmax-weighted merge, or last-direction merge + u⊙h
/// modulation. An enum (not a closure) so contiguous drain runs can
/// dispatch to batch lane kernels while strided runs apply it per
/// element with the same arithmetic.
#[derive(Clone, Copy, Debug)]
pub(crate) enum EpOp {
    Assign,
    Merge(f32),
    MergeGain(f32, f32),
}

impl EpOp {
    /// The pinned per-element expression; every batch kernel must match
    /// it bit-for-bit.
    #[inline]
    pub(crate) fn apply(self, o: f32, v: f32) -> f32 {
        match self {
            EpOp::Assign => v,
            EpOp::Merge(wt) => o + wt * v,
            EpOp::MergeGain(wt, g) => (o + wt * v) * g,
        }
    }
}

// ---------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------

/// One column of the scan recurrence (`up + ct + dn + b` with literal
/// `0.0` boundary terms), dispatched to the active kernel. Bit-identical
/// across kernels by construction.
#[inline]
pub(crate) fn scan_col(prev: &[f32], b: &[f32], taps: TapCols, out: &mut [f32]) {
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 is only selectable on hosts that report AVX2.
        SimdKernel::Avx2 => unsafe { avx2::scan_col(prev, b, taps, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Neon is only selectable on hosts that report NEON.
        SimdKernel::Neon => unsafe { neon::scan_col(prev, b, taps, out) },
        _ => scalar::scan_col(prev, b, taps, out),
    }
}

/// One column of the carry-correction recurrence ([`scan_col`] without
/// the `b` term), dispatched to the active kernel.
#[inline]
pub(crate) fn correct_col(prev: &[f32], taps: TapCols, out: &mut [f32]) {
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `scan_col`.
        SimdKernel::Avx2 => unsafe { avx2::correct_col(prev, taps, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as in `scan_col`.
        SimdKernel::Neon => unsafe { neon::correct_col(prev, taps, out) },
        _ => scalar::correct_col(prev, taps, out),
    }
}

/// Apply an epilogue op over one contiguous run (`out[i] = op(out[i],
/// src[i])`), dispatched to the active kernel.
#[inline]
pub(crate) fn ep_apply(op: EpOp, out: &mut [f32], src: &[f32]) {
    debug_assert_eq!(out.len(), src.len());
    if let EpOp::Assign = op {
        // Bitwise copy regardless of kernel.
        out.copy_from_slice(src);
        return;
    }
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `scan_col`.
        SimdKernel::Avx2 => unsafe { avx2::ep_apply(op, out, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as in `scan_col`.
        SimdKernel::Neon => unsafe { neon::ep_apply(op, out, src) },
        _ => scalar::ep_apply(op, out, src),
    }
}

// ---------------------------------------------------------------------
// Scalar kernels: the pinned reference every vector kernel must match
// ---------------------------------------------------------------------

pub(crate) mod scalar {
    use super::{bf16_widen, EpOp, TapCols};

    /// The reference association, generic over tap storage (`wf` widens a
    /// stored tap to f32; for f32 taps it is the identity, which keeps the
    /// expression literally the pre-SIMD engine's).
    #[inline]
    fn scan_col_t<T: Copy>(
        prev: &[f32],
        b: &[f32],
        tu: &[T],
        tc: &[T],
        td: &[T],
        out: &mut [f32],
        wf: impl Fn(T) -> f32,
    ) {
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + wf(tc[0]) * prev[0] + 0.0 + b[0];
            return;
        }
        out[0] = 0.0 + wf(tc[0]) * prev[0] + wf(td[0]) * prev[1] + b[0];
        for r in 1..h - 1 {
            out[r] =
                wf(tu[r]) * prev[r - 1] + wf(tc[r]) * prev[r] + wf(td[r]) * prev[r + 1] + b[r];
        }
        let r = h - 1;
        out[r] = wf(tu[r]) * prev[r - 1] + wf(tc[r]) * prev[r] + 0.0 + b[r];
    }

    #[inline]
    fn correct_col_t<T: Copy>(
        prev: &[f32],
        tu: &[T],
        tc: &[T],
        td: &[T],
        out: &mut [f32],
        wf: impl Fn(T) -> f32,
    ) {
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + wf(tc[0]) * prev[0] + 0.0;
            return;
        }
        out[0] = 0.0 + wf(tc[0]) * prev[0] + wf(td[0]) * prev[1];
        for r in 1..h - 1 {
            out[r] = wf(tu[r]) * prev[r - 1] + wf(tc[r]) * prev[r] + wf(td[r]) * prev[r + 1];
        }
        let r = h - 1;
        out[r] = wf(tu[r]) * prev[r - 1] + wf(tc[r]) * prev[r] + 0.0;
    }

    pub(crate) fn scan_col(prev: &[f32], b: &[f32], taps: TapCols, out: &mut [f32]) {
        match taps {
            TapCols::F32 { tu, tc, td } => scan_col_t(prev, b, tu, tc, td, out, |v| v),
            TapCols::Bf16 { tu, tc, td } => scan_col_t(prev, b, tu, tc, td, out, bf16_widen),
        }
    }

    pub(crate) fn correct_col(prev: &[f32], taps: TapCols, out: &mut [f32]) {
        match taps {
            TapCols::F32 { tu, tc, td } => correct_col_t(prev, tu, tc, td, out, |v| v),
            TapCols::Bf16 { tu, tc, td } => correct_col_t(prev, tu, tc, td, out, bf16_widen),
        }
    }

    pub(crate) fn ep_apply(op: EpOp, out: &mut [f32], src: &[f32]) {
        match op {
            EpOp::Assign => out.copy_from_slice(src),
            _ => {
                for (o, &v) in out.iter_mut().zip(src.iter()) {
                    *o = op.apply(*o, v);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// AVX2 kernels (x86_64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    use super::{EpOp, TapCols};
    use core::arch::x86_64::*;

    /// Widen 8 bf16 words starting at `p` to f32 lanes: zero-extend each
    /// u16 to u32, shift into the high half — exactly `bf16_widen` per
    /// lane.
    ///
    /// # Safety
    /// AVX2 must be available and `p..p+8` readable.
    #[target_feature(enable = "avx2")]
    unsafe fn widen8(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
    }

    /// # Safety
    /// AVX2 must be available; slice lengths as in the scalar kernel
    /// (`prev.len() == out.len()`, taps/b at least `out.len()`).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn scan_col(prev: &[f32], b: &[f32], taps: TapCols, out: &mut [f32]) {
        match taps {
            TapCols::F32 { tu, tc, td } => scan_col_f32(prev, b, tu, tc, td, out),
            TapCols::Bf16 { tu, tc, td } => scan_col_bf16(prev, b, tu, tc, td, out),
        }
    }

    /// # Safety
    /// As in [`scan_col`].
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn correct_col(prev: &[f32], taps: TapCols, out: &mut [f32]) {
        match taps {
            TapCols::F32 { tu, tc, td } => correct_col_f32(prev, tu, tc, td, out),
            TapCols::Bf16 { tu, tc, td } => correct_col_bf16(prev, tu, tc, td, out),
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scan_col_f32(
        prev: &[f32],
        b: &[f32],
        tu: &[f32],
        tc: &[f32],
        td: &[f32],
        out: &mut [f32],
    ) {
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + tc[0] * prev[0] + 0.0 + b[0];
            return;
        }
        out[0] = 0.0 + tc[0] * prev[0] + td[0] * prev[1] + b[0];
        let mut r = 1;
        // In-bounds: r+8 <= h-1 keeps the furthest load (prev[r+1..r+9])
        // inside prev[..h] and the store inside out[1..h-1].
        while r + 8 <= h - 1 {
            let pm = _mm256_loadu_ps(prev.as_ptr().add(r - 1));
            let pc = _mm256_loadu_ps(prev.as_ptr().add(r));
            let pp = _mm256_loadu_ps(prev.as_ptr().add(r + 1));
            // Same association as the scalar loop; separate mul/add ops,
            // never FMA, so every lane is bit-identical.
            let mut acc = _mm256_add_ps(
                _mm256_mul_ps(_mm256_loadu_ps(tu.as_ptr().add(r)), pm),
                _mm256_mul_ps(_mm256_loadu_ps(tc.as_ptr().add(r)), pc),
            );
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_loadu_ps(td.as_ptr().add(r)), pp));
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(b.as_ptr().add(r)));
            _mm256_storeu_ps(out.as_mut_ptr().add(r), acc);
            r += 8;
        }
        while r < h - 1 {
            out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + td[r] * prev[r + 1] + b[r];
            r += 1;
        }
        let r = h - 1;
        out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + 0.0 + b[r];
    }

    #[target_feature(enable = "avx2")]
    unsafe fn scan_col_bf16(
        prev: &[f32],
        b: &[f32],
        tu: &[u16],
        tc: &[u16],
        td: &[u16],
        out: &mut [f32],
    ) {
        let w = super::bf16_widen;
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + w(tc[0]) * prev[0] + 0.0 + b[0];
            return;
        }
        out[0] = 0.0 + w(tc[0]) * prev[0] + w(td[0]) * prev[1] + b[0];
        let mut r = 1;
        while r + 8 <= h - 1 {
            let pm = _mm256_loadu_ps(prev.as_ptr().add(r - 1));
            let pc = _mm256_loadu_ps(prev.as_ptr().add(r));
            let pp = _mm256_loadu_ps(prev.as_ptr().add(r + 1));
            let mut acc = _mm256_add_ps(
                _mm256_mul_ps(widen8(tu.as_ptr().add(r)), pm),
                _mm256_mul_ps(widen8(tc.as_ptr().add(r)), pc),
            );
            acc = _mm256_add_ps(acc, _mm256_mul_ps(widen8(td.as_ptr().add(r)), pp));
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(b.as_ptr().add(r)));
            _mm256_storeu_ps(out.as_mut_ptr().add(r), acc);
            r += 8;
        }
        while r < h - 1 {
            out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + w(td[r]) * prev[r + 1] + b[r];
            r += 1;
        }
        let r = h - 1;
        out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + 0.0 + b[r];
    }

    #[target_feature(enable = "avx2")]
    unsafe fn correct_col_f32(prev: &[f32], tu: &[f32], tc: &[f32], td: &[f32], out: &mut [f32]) {
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + tc[0] * prev[0] + 0.0;
            return;
        }
        out[0] = 0.0 + tc[0] * prev[0] + td[0] * prev[1];
        let mut r = 1;
        while r + 8 <= h - 1 {
            let pm = _mm256_loadu_ps(prev.as_ptr().add(r - 1));
            let pc = _mm256_loadu_ps(prev.as_ptr().add(r));
            let pp = _mm256_loadu_ps(prev.as_ptr().add(r + 1));
            let mut acc = _mm256_add_ps(
                _mm256_mul_ps(_mm256_loadu_ps(tu.as_ptr().add(r)), pm),
                _mm256_mul_ps(_mm256_loadu_ps(tc.as_ptr().add(r)), pc),
            );
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_loadu_ps(td.as_ptr().add(r)), pp));
            _mm256_storeu_ps(out.as_mut_ptr().add(r), acc);
            r += 8;
        }
        while r < h - 1 {
            out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + td[r] * prev[r + 1];
            r += 1;
        }
        let r = h - 1;
        out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + 0.0;
    }

    #[target_feature(enable = "avx2")]
    unsafe fn correct_col_bf16(prev: &[f32], tu: &[u16], tc: &[u16], td: &[u16], out: &mut [f32]) {
        let w = super::bf16_widen;
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + w(tc[0]) * prev[0] + 0.0;
            return;
        }
        out[0] = 0.0 + w(tc[0]) * prev[0] + w(td[0]) * prev[1];
        let mut r = 1;
        while r + 8 <= h - 1 {
            let pm = _mm256_loadu_ps(prev.as_ptr().add(r - 1));
            let pc = _mm256_loadu_ps(prev.as_ptr().add(r));
            let pp = _mm256_loadu_ps(prev.as_ptr().add(r + 1));
            let mut acc = _mm256_add_ps(
                _mm256_mul_ps(widen8(tu.as_ptr().add(r)), pm),
                _mm256_mul_ps(widen8(tc.as_ptr().add(r)), pc),
            );
            acc = _mm256_add_ps(acc, _mm256_mul_ps(widen8(td.as_ptr().add(r)), pp));
            _mm256_storeu_ps(out.as_mut_ptr().add(r), acc);
            r += 8;
        }
        while r < h - 1 {
            out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + w(td[r]) * prev[r + 1];
            r += 1;
        }
        let r = h - 1;
        out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + 0.0;
    }

    /// # Safety
    /// AVX2 must be available; `out.len() == src.len()`.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn ep_apply(op: EpOp, out: &mut [f32], src: &[f32]) {
        let n = out.len();
        match op {
            EpOp::Assign => out.copy_from_slice(src),
            EpOp::Merge(wt) => {
                let vw = _mm256_set1_ps(wt);
                let mut i = 0;
                while i + 8 <= n {
                    let vo = _mm256_loadu_ps(out.as_ptr().add(i));
                    let vs = _mm256_loadu_ps(src.as_ptr().add(i));
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm256_add_ps(vo, _mm256_mul_ps(vw, vs)),
                    );
                    i += 8;
                }
                while i < n {
                    out[i] += wt * src[i];
                    i += 1;
                }
            }
            EpOp::MergeGain(wt, g) => {
                let vw = _mm256_set1_ps(wt);
                let vg = _mm256_set1_ps(g);
                let mut i = 0;
                while i + 8 <= n {
                    let vo = _mm256_loadu_ps(out.as_ptr().add(i));
                    let vs = _mm256_loadu_ps(src.as_ptr().add(i));
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add(i),
                        _mm256_mul_ps(_mm256_add_ps(vo, _mm256_mul_ps(vw, vs)), vg),
                    );
                    i += 8;
                }
                while i < n {
                    out[i] = (out[i] + wt * src[i]) * g;
                    i += 1;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// NEON kernels (aarch64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use super::{EpOp, TapCols};
    use core::arch::aarch64::*;

    /// Widen 4 bf16 words starting at `p` to f32 lanes — exactly
    /// `bf16_widen` per lane.
    ///
    /// # Safety
    /// NEON must be available and `p..p+4` readable.
    #[target_feature(enable = "neon")]
    unsafe fn widen4(p: *const u16) -> float32x4_t {
        vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vld1_u16(p))))
    }

    /// # Safety
    /// NEON must be available; slice lengths as in the scalar kernel.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn scan_col(prev: &[f32], b: &[f32], taps: TapCols, out: &mut [f32]) {
        match taps {
            TapCols::F32 { tu, tc, td } => scan_col_f32(prev, b, tu, tc, td, out),
            TapCols::Bf16 { tu, tc, td } => scan_col_bf16(prev, b, tu, tc, td, out),
        }
    }

    /// # Safety
    /// As in [`scan_col`].
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn correct_col(prev: &[f32], taps: TapCols, out: &mut [f32]) {
        match taps {
            TapCols::F32 { tu, tc, td } => correct_col_f32(prev, tu, tc, td, out),
            TapCols::Bf16 { tu, tc, td } => correct_col_bf16(prev, tu, tc, td, out),
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn scan_col_f32(
        prev: &[f32],
        b: &[f32],
        tu: &[f32],
        tc: &[f32],
        td: &[f32],
        out: &mut [f32],
    ) {
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + tc[0] * prev[0] + 0.0 + b[0];
            return;
        }
        out[0] = 0.0 + tc[0] * prev[0] + td[0] * prev[1] + b[0];
        let mut r = 1;
        while r + 4 <= h - 1 {
            let pm = vld1q_f32(prev.as_ptr().add(r - 1));
            let pc = vld1q_f32(prev.as_ptr().add(r));
            let pp = vld1q_f32(prev.as_ptr().add(r + 1));
            // Separate mul/add (no fused vmla), same association as scalar.
            let mut acc = vaddq_f32(
                vmulq_f32(vld1q_f32(tu.as_ptr().add(r)), pm),
                vmulq_f32(vld1q_f32(tc.as_ptr().add(r)), pc),
            );
            acc = vaddq_f32(acc, vmulq_f32(vld1q_f32(td.as_ptr().add(r)), pp));
            acc = vaddq_f32(acc, vld1q_f32(b.as_ptr().add(r)));
            vst1q_f32(out.as_mut_ptr().add(r), acc);
            r += 4;
        }
        while r < h - 1 {
            out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + td[r] * prev[r + 1] + b[r];
            r += 1;
        }
        let r = h - 1;
        out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + 0.0 + b[r];
    }

    #[target_feature(enable = "neon")]
    unsafe fn scan_col_bf16(
        prev: &[f32],
        b: &[f32],
        tu: &[u16],
        tc: &[u16],
        td: &[u16],
        out: &mut [f32],
    ) {
        let w = super::bf16_widen;
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + w(tc[0]) * prev[0] + 0.0 + b[0];
            return;
        }
        out[0] = 0.0 + w(tc[0]) * prev[0] + w(td[0]) * prev[1] + b[0];
        let mut r = 1;
        while r + 4 <= h - 1 {
            let pm = vld1q_f32(prev.as_ptr().add(r - 1));
            let pc = vld1q_f32(prev.as_ptr().add(r));
            let pp = vld1q_f32(prev.as_ptr().add(r + 1));
            let mut acc = vaddq_f32(
                vmulq_f32(widen4(tu.as_ptr().add(r)), pm),
                vmulq_f32(widen4(tc.as_ptr().add(r)), pc),
            );
            acc = vaddq_f32(acc, vmulq_f32(widen4(td.as_ptr().add(r)), pp));
            acc = vaddq_f32(acc, vld1q_f32(b.as_ptr().add(r)));
            vst1q_f32(out.as_mut_ptr().add(r), acc);
            r += 4;
        }
        while r < h - 1 {
            out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + w(td[r]) * prev[r + 1] + b[r];
            r += 1;
        }
        let r = h - 1;
        out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + 0.0 + b[r];
    }

    #[target_feature(enable = "neon")]
    unsafe fn correct_col_f32(prev: &[f32], tu: &[f32], tc: &[f32], td: &[f32], out: &mut [f32]) {
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + tc[0] * prev[0] + 0.0;
            return;
        }
        out[0] = 0.0 + tc[0] * prev[0] + td[0] * prev[1];
        let mut r = 1;
        while r + 4 <= h - 1 {
            let pm = vld1q_f32(prev.as_ptr().add(r - 1));
            let pc = vld1q_f32(prev.as_ptr().add(r));
            let pp = vld1q_f32(prev.as_ptr().add(r + 1));
            let mut acc = vaddq_f32(
                vmulq_f32(vld1q_f32(tu.as_ptr().add(r)), pm),
                vmulq_f32(vld1q_f32(tc.as_ptr().add(r)), pc),
            );
            acc = vaddq_f32(acc, vmulq_f32(vld1q_f32(td.as_ptr().add(r)), pp));
            vst1q_f32(out.as_mut_ptr().add(r), acc);
            r += 4;
        }
        while r < h - 1 {
            out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + td[r] * prev[r + 1];
            r += 1;
        }
        let r = h - 1;
        out[r] = tu[r] * prev[r - 1] + tc[r] * prev[r] + 0.0;
    }

    #[target_feature(enable = "neon")]
    unsafe fn correct_col_bf16(prev: &[f32], tu: &[u16], tc: &[u16], td: &[u16], out: &mut [f32]) {
        let w = super::bf16_widen;
        let h = out.len();
        if h == 1 {
            out[0] = 0.0 + w(tc[0]) * prev[0] + 0.0;
            return;
        }
        out[0] = 0.0 + w(tc[0]) * prev[0] + w(td[0]) * prev[1];
        let mut r = 1;
        while r + 4 <= h - 1 {
            let pm = vld1q_f32(prev.as_ptr().add(r - 1));
            let pc = vld1q_f32(prev.as_ptr().add(r));
            let pp = vld1q_f32(prev.as_ptr().add(r + 1));
            let mut acc = vaddq_f32(
                vmulq_f32(widen4(tu.as_ptr().add(r)), pm),
                vmulq_f32(widen4(tc.as_ptr().add(r)), pc),
            );
            acc = vaddq_f32(acc, vmulq_f32(widen4(td.as_ptr().add(r)), pp));
            vst1q_f32(out.as_mut_ptr().add(r), acc);
            r += 4;
        }
        while r < h - 1 {
            out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + w(td[r]) * prev[r + 1];
            r += 1;
        }
        let r = h - 1;
        out[r] = w(tu[r]) * prev[r - 1] + w(tc[r]) * prev[r] + 0.0;
    }

    /// # Safety
    /// NEON must be available; `out.len() == src.len()`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn ep_apply(op: EpOp, out: &mut [f32], src: &[f32]) {
        let n = out.len();
        match op {
            EpOp::Assign => out.copy_from_slice(src),
            EpOp::Merge(wt) => {
                let vw = vdupq_n_f32(wt);
                let mut i = 0;
                while i + 4 <= n {
                    let vo = vld1q_f32(out.as_ptr().add(i));
                    let vs = vld1q_f32(src.as_ptr().add(i));
                    vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(vo, vmulq_f32(vw, vs)));
                    i += 4;
                }
                while i < n {
                    out[i] += wt * src[i];
                    i += 1;
                }
            }
            EpOp::MergeGain(wt, g) => {
                let vw = vdupq_n_f32(wt);
                let vg = vdupq_n_f32(g);
                let mut i = 0;
                while i + 4 <= n {
                    let vo = vld1q_f32(out.as_ptr().add(i));
                    let vs = vld1q_f32(src.as_ptr().add(i));
                    vst1q_f32(
                        out.as_mut_ptr().add(i),
                        vmulq_f32(vaddq_f32(vo, vmulq_f32(vw, vs)), vg),
                    );
                    i += 4;
                }
                while i < n {
                    out[i] = (out[i] + wt * src[i]) * g;
                    i += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Values that stress rounding and special-case handling: signed
    /// zeros, subnormals, huge/tiny magnitudes, ordinary mixed signs.
    fn adversarial_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match rng.next_u64() % 8 {
                0 => 0.0,
                1 => -0.0,
                2 => 1.0e-39,
                3 => -1.0e-39,
                4 => 1.0e20,
                5 => -1.0e20,
                6 => 1.0e-20,
                _ => rng.uniform_in(-2.0, 2.0),
            })
            .collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: lane {i}: {x:?} vs {y:?}");
        }
    }

    #[test]
    fn names_lanes_and_detection() {
        assert_eq!(SimdKernel::Scalar.lanes(), 1);
        assert_eq!(SimdKernel::Avx2.lanes(), 8);
        assert_eq!(SimdKernel::Neon.lanes(), 4);
        assert!(SimdKernel::Scalar.supported());
        assert!(kernel().supported());
        assert_eq!(lanes(), kernel().lanes());
        assert!(!detected_features().is_empty());
    }

    #[test]
    fn override_parse_and_validity() {
        assert!(set_simd_override("bogus").is_err());
        // Forcing each named kernel succeeds exactly when the host
        // supports it. Flipping between bit-identical kernels is benign
        // for concurrently-running tests by construction.
        for k in [SimdKernel::Scalar, SimdKernel::Avx2, SimdKernel::Neon] {
            assert_eq!(set_simd_override(k.name()).is_ok(), k.supported(), "{}", k.name());
        }
        set_simd_override("scalar").unwrap();
        assert_eq!(kernel(), SimdKernel::Scalar);
        set_simd_override("auto").unwrap();
        assert!(kernel().supported());

        // Precision: only parse-level checks here. Storing bf16 in the
        // process-wide knob would corrupt concurrently-running `==`
        // tests, so the engine's bf16 tests thread an explicit precision
        // instead (see fused.rs) and benches own the global setter.
        assert!(set_precision_override("f64").is_err());
        assert_eq!(parse_precision("bf16"), Some(Precision::Bf16));
        assert_eq!(parse_precision("f32"), Some(Precision::F32));
        assert_eq!(Precision::Bf16.name(), "bf16");
        set_precision_override("f32").unwrap();
        assert_eq!(precision(), Precision::F32);
    }

    #[test]
    fn bf16_narrow_rounds_to_nearest_even() {
        assert_eq!(bf16_narrow(1.0), 0x3f80);
        assert_eq!(bf16_narrow(f32::from_bits(0x3f80_7fff)), 0x3f80); // below half: down
        assert_eq!(bf16_narrow(f32::from_bits(0x3f80_8001)), 0x3f81); // above half: up
        assert_eq!(bf16_narrow(f32::from_bits(0x3f80_8000)), 0x3f80); // tie: keep even
        assert_eq!(bf16_narrow(f32::from_bits(0x3f81_8000)), 0x3f82); // tie: round to even
        assert_eq!(bf16_narrow(f32::INFINITY), 0x7f80);
        assert_eq!(bf16_narrow(f32::NEG_INFINITY), 0xff80);
        assert_eq!(bf16_narrow(-0.0), 0x8000);
        assert_eq!(bf16_narrow(0.0), 0x0000);
        // f32::MAX is nearer 2^128 than the largest bf16: rounds to inf.
        assert_eq!(bf16_narrow(f32::MAX), 0x7f80);
        assert!(bf16_widen(bf16_narrow(f32::NAN)).is_nan());
        assert_eq!(bf16_len(0), 0);
        assert_eq!(bf16_len(1), 1);
        assert_eq!(bf16_len(7), 4);
        assert_eq!(bf16_len(8), 4);
    }

    #[test]
    fn bf16_widen_roundtrips_every_value() {
        for hb in 0..=u16::MAX {
            let f = bf16_widen(hb);
            if f.is_nan() {
                assert!(bf16_widen(bf16_narrow(f)).is_nan());
            } else {
                // Widening is exact, so narrowing must give back the word.
                assert_eq!(bf16_narrow(f), hb, "bf16 word {hb:#06x}");
            }
        }
    }

    #[test]
    fn bf16_narrow_error_is_bounded() {
        let mut rng = Rng::new(0xbf16);
        for _ in 0..20_000 {
            let v = rng.uniform_in(-100.0, 100.0);
            let w = bf16_widen(bf16_narrow(v));
            // Relative error of one bf16 rounding step is at most 2^-8.
            assert!((w - v).abs() <= v.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE);
        }
    }

    /// The vector kernels must match the scalar reference bit-for-bit at
    /// every size (remainder handling) and under adversarial values, for
    /// both tap storage precisions and all epilogue ops.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_bit_identical_to_scalar() {
        if !SimdKernel::Avx2.supported() {
            return;
        }
        let mut rng = Rng::new(0x51D1);
        let sizes =
            [1usize, 2, 3, 5, 8, 9, 10, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 129, 256, 511];
        for &h in &sizes {
            for _rep in 0..8 {
                let prev = adversarial_vec(&mut rng, h);
                let b = adversarial_vec(&mut rng, h);
                let tu = adversarial_vec(&mut rng, h);
                let tc = adversarial_vec(&mut rng, h);
                let td = adversarial_vec(&mut rng, h);
                let mut o1 = vec![0.0f32; h];
                let mut o2 = vec![0.0f32; h];

                let taps = TapCols::F32 { tu: &tu, tc: &tc, td: &td };
                scalar::scan_col(&prev, &b, taps, &mut o1);
                unsafe { avx2::scan_col(&prev, &b, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "scan_col f32");
                scalar::correct_col(&prev, taps, &mut o1);
                unsafe { avx2::correct_col(&prev, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "correct_col f32");

                let hu: Vec<u16> = tu.iter().map(|&v| bf16_narrow(v)).collect();
                let hc: Vec<u16> = tc.iter().map(|&v| bf16_narrow(v)).collect();
                let hd: Vec<u16> = td.iter().map(|&v| bf16_narrow(v)).collect();
                let taps = TapCols::Bf16 { tu: &hu, tc: &hc, td: &hd };
                scalar::scan_col(&prev, &b, taps, &mut o1);
                unsafe { avx2::scan_col(&prev, &b, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "scan_col bf16");
                scalar::correct_col(&prev, taps, &mut o1);
                unsafe { avx2::correct_col(&prev, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "correct_col bf16");

                for op in [EpOp::Assign, EpOp::Merge(0.257), EpOp::MergeGain(0.257, 1.37)] {
                    let base = adversarial_vec(&mut rng, h);
                    let src = adversarial_vec(&mut rng, h);
                    let mut a = base.clone();
                    let mut c = base.clone();
                    scalar::ep_apply(op, &mut a, &src);
                    unsafe { avx2::ep_apply(op, &mut c, &src) };
                    assert_bits_eq(&a, &c, "ep_apply");
                }
            }
        }
    }

    /// NEON twin of the AVX2 pin, compiled and run only on aarch64.
    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_kernels_bit_identical_to_scalar() {
        if !SimdKernel::Neon.supported() {
            return;
        }
        let mut rng = Rng::new(0x51D2);
        let sizes = [1usize, 2, 3, 4, 5, 6, 9, 16, 17, 31, 32, 33, 64, 65, 100, 129, 256, 511];
        for &h in &sizes {
            for _rep in 0..8 {
                let prev = adversarial_vec(&mut rng, h);
                let b = adversarial_vec(&mut rng, h);
                let tu = adversarial_vec(&mut rng, h);
                let tc = adversarial_vec(&mut rng, h);
                let td = adversarial_vec(&mut rng, h);
                let mut o1 = vec![0.0f32; h];
                let mut o2 = vec![0.0f32; h];

                let taps = TapCols::F32 { tu: &tu, tc: &tc, td: &td };
                scalar::scan_col(&prev, &b, taps, &mut o1);
                unsafe { neon::scan_col(&prev, &b, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "scan_col f32");
                scalar::correct_col(&prev, taps, &mut o1);
                unsafe { neon::correct_col(&prev, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "correct_col f32");

                let hu: Vec<u16> = tu.iter().map(|&v| bf16_narrow(v)).collect();
                let hc: Vec<u16> = tc.iter().map(|&v| bf16_narrow(v)).collect();
                let hd: Vec<u16> = td.iter().map(|&v| bf16_narrow(v)).collect();
                let taps = TapCols::Bf16 { tu: &hu, tc: &hc, td: &hd };
                scalar::scan_col(&prev, &b, taps, &mut o1);
                unsafe { neon::scan_col(&prev, &b, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "scan_col bf16");
                scalar::correct_col(&prev, taps, &mut o1);
                unsafe { neon::correct_col(&prev, taps, &mut o2) };
                assert_bits_eq(&o1, &o2, "correct_col bf16");

                for op in [EpOp::Assign, EpOp::Merge(0.257), EpOp::MergeGain(0.257, 1.37)] {
                    let base = adversarial_vec(&mut rng, h);
                    let src = adversarial_vec(&mut rng, h);
                    let mut a = base.clone();
                    let mut c = base.clone();
                    scalar::ep_apply(op, &mut a, &src);
                    unsafe { neon::ep_apply(op, &mut c, &src) };
                    assert_bits_eq(&a, &c, "ep_apply");
                }
            }
        }
    }
}
