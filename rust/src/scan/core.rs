//! The canonical left-to-right GSPN line scan (pure-Rust reference).
//!
//! Implements Eq. 1 of the paper exactly: for each column `i`,
//!
//!   h[:, i] = w_i · h[:, i-1] + lam[:, i] ⊙ x[:, i]
//!
//! with `w_i` tridiagonal row-stochastic (see `taps.rs`). `kchunk > 0`
//! selects the GSPN-local variant, resetting the hidden state at chunk
//! boundaries. This is the numerical ground truth the PJRT artifacts are
//! integration-tested against, and the workload whose memory/launch
//! behaviour `gpusim` models.
//!
//! The (N·C) plane loop is embarrassingly parallel; `scan_l2r_pool` /
//! `scan_l2r_par` fan it out over the shared [`ThreadPool`] while staying
//! bit-identical to the serial `scan_l2r` (planes share no accumulators,
//! so nothing reassociates).

use super::taps::{Taps, TAP_CENTER, TAP_DOWN, TAP_UP};
use crate::tensor::Tensor;
use crate::util::ThreadPool;

/// A kchunk is valid for width `w` when it is 0 (global scan) or divides
/// `w` exactly. The serving coordinator checks this at admission so a bad
/// request is rejected with a structured error instead of panicking a
/// worker on the assert below.
pub fn kchunk_valid(w: usize, kchunk: usize) -> bool {
    kchunk == 0 || (kchunk <= w && w % kchunk == 0)
}

/// Shared shape validation; returns the effective chunk width.
fn validate_scan_args(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> usize {
    assert_eq!(x.rank(), 4, "x must be (N, C, H, W)");
    assert_eq!(x.shape, lam.shape, "lam shape must match x");
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!((taps.n, taps.h, taps.w), (n, h, w), "taps geometry mismatch");
    assert!(taps.cw == 1 || taps.cw == c, "Cw must be 1 or C");
    let chunk = if kchunk == 0 { w } else { kchunk };
    assert!(w % chunk == 0, "kchunk={chunk} must divide W={w}");
    chunk
}

/// Reusable per-plane scratch (the two h-length state columns). The
/// serial loop reuses one across all planes, as the pre-refactor code
/// did; each pooled job owns its own. Contents need no zeroing between
/// planes: the `i % chunk == 0` reset fires on column 0.
struct PlaneScratch {
    hprev: Vec<f32>,
    hcur: Vec<f32>,
}

impl PlaneScratch {
    fn new(h: usize) -> PlaneScratch {
        PlaneScratch { hprev: vec![0.0f32; h], hcur: vec![0.0f32; h] }
    }
}

/// Scan one (ni, ci) plane of the recurrence into `os`, the plane's
/// output slice. Extracted from `scan_l2r` so the serial loop and the
/// pool-parallel fan-out run the *identical* per-plane code — plane-level
/// parallelism reassociates nothing, so the two paths are bit-identical.
fn scan_plane(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    ni: usize,
    ci: usize,
    chunk: usize,
    os: &mut [f32],
    scratch: &mut PlaneScratch,
) {
    let (c, h, w) = (x.shape[1], x.shape[2], x.shape[3]);
    let plane = h * w;
    let tap_plane = h * w;
    let cw = if taps.cw == 1 { 0 } else { ci };
    let xbase = (ni * c + ci) * plane;
    let tbase = (ni * taps.cw + cw) * 3 * tap_plane;
    // Hoisted tap-plane slices: keeps the inner loop free of
    // re-derived base offsets and lets bounds checks vanish
    // (EXPERIMENTS.md §Perf, L3 iteration 4).
    let t_up = &taps.t.data[tbase + TAP_UP * tap_plane..tbase + TAP_UP * tap_plane + tap_plane];
    let t_ct = &taps.t.data
        [tbase + TAP_CENTER * tap_plane..tbase + TAP_CENTER * tap_plane + tap_plane];
    let t_dn = &taps.t.data
        [tbase + TAP_DOWN * tap_plane..tbase + TAP_DOWN * tap_plane + tap_plane];
    let xs = &x.data[xbase..xbase + plane];
    let ls = &lam.data[xbase..xbase + plane];
    let PlaneScratch { hprev, hcur } = scratch;
    for i in 0..w {
        if i % chunk == 0 {
            hprev.iter_mut().for_each(|v| *v = 0.0);
        }
        for r in 0..h {
            let p = r * w + i;
            let up = if r > 0 { t_up[p] * hprev[r - 1] } else { 0.0 };
            let ct = t_ct[p] * hprev[r];
            let dn = if r + 1 < h { t_dn[p] * hprev[r + 1] } else { 0.0 };
            hcur[r] = up + ct + dn + ls[p] * xs[p];
            os[p] = hcur[r];
        }
        std::mem::swap(hprev, hcur);
    }
}

/// Forward scan. `x`, `lam`: (N, C, H, W); returns h with the same shape.
pub fn scan_l2r(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> Tensor {
    let chunk = validate_scan_args(x, taps, lam, kchunk);
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&x.shape);
    let plane = h * w;
    if n * c == 0 || plane == 0 {
        return out;
    }
    let mut scratch = PlaneScratch::new(h);
    for (p, os) in out.data.chunks_mut(plane).enumerate() {
        scan_plane(x, taps, lam, p / c, p % c, chunk, os, &mut scratch);
    }
    out
}

/// `scan_l2r` with the (N·C) plane loop fanned out over a shared thread
/// pool. Bit-identical to the serial path: each plane runs the same
/// `scan_plane` kernel, and planes never share accumulators.
pub fn scan_l2r_pool(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    let chunk = validate_scan_args(x, taps, lam, kchunk);
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&x.shape);
    let plane = h * w;
    if n * c == 0 || plane == 0 {
        return out;
    }
    let planes: Vec<(usize, &mut [f32])> = out.data.chunks_mut(plane).enumerate().collect();
    pool.map(planes, |(p, os)| {
        let mut scratch = PlaneScratch::new(h);
        scan_plane(x, taps, lam, p / c, p % c, chunk, os, &mut scratch)
    });
    out
}

/// `scan_l2r` over the process-wide shared pool ([`ThreadPool::global`]).
pub fn scan_l2r_par(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> Tensor {
    scan_l2r_pool(x, taps, lam, kchunk, ThreadPool::global())
}

/// Output modulation of Eq. 2: y = u ⊙ h with per-channel gain u (C,).
/// Borrowing wrapper kept for callers outside the fused path; owners
/// should pass ownership to [`output_modulation_owned`], and the fused
/// engine ([`super::fused`]) folds the modulation into its scatter
/// epilogue so no separate pass runs at all.
pub fn output_modulation(h: &Tensor, u: &[f32]) -> Tensor {
    output_modulation_owned(h.clone(), u)
}

/// [`output_modulation`] on an owned input: one in-place traversal, no
/// clone and no second pass over the data.
pub fn output_modulation_owned(mut h: Tensor, u: &[f32]) -> Tensor {
    let (c, hh, w) = (h.shape[1], h.shape[2], h.shape[3]);
    assert_eq!(u.len(), c);
    let plane = hh * w;
    if plane == 0 || h.data.is_empty() {
        return h;
    }
    for (p, os) in h.data.chunks_mut(plane).enumerate() {
        let g = u[p % c];
        for v in os {
            *v *= g;
        }
    }
    h
}

/// FLOP count of one scan (7 madds/pixel/channel: 3 tap muls + 2 adds +
/// 1 lam mul + 1 add). Used by gpusim and the MAC accounting.
pub fn scan_flops(n: usize, c: usize, h: usize, w: usize) -> u64 {
    7 * (n * c * h * w) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::taps::Taps;
    use crate::util::proptest::{check, ensure, ensure_close};
    use crate::util::Rng;

    fn case(seed: u64, n: usize, c: usize, h: usize, w: usize, cw: usize) -> (Tensor, Taps, Tensor) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let raw = Tensor::randn(&[n, cw, 3, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        (x, Taps::normalize(&raw), lam)
    }

    #[test]
    fn first_column_is_lam_x() {
        let (x, taps, lam) = case(0, 2, 3, 4, 5, 3);
        let out = scan_l2r(&x, &taps, &lam, 0);
        for ni in 0..2 {
            for ci in 0..3 {
                for r in 0..4 {
                    let want = lam.at(&[ni, ci, r, 0]) * x.at(&[ni, ci, r, 0]);
                    assert!((out.at(&[ni, ci, r, 0]) - want).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn width_one_trivial() {
        let (x, taps, lam) = case(1, 1, 2, 3, 1, 1);
        let out = scan_l2r(&x, &taps, &lam, 0);
        assert!(out.allclose(&lam.mul(&x), 1e-6, 1e-6));
    }

    #[test]
    fn manual_two_column_case() {
        // H=2, W=2, hand-computed recurrence.
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lam = Tensor::full(&[1, 1, 2, 2], 1.0);
        // Raw logits of 0 -> sigmoid 0.5 everywhere; boundary masking
        // leaves rows of H=2 with taps (0, .5, .5)/1 and (.5, .5, 0)/1.
        let raw = Tensor::zeros(&[1, 1, 3, 2, 2]);
        let taps = Taps::normalize(&raw);
        let out = scan_l2r(&x, &taps, &lam, 0);
        // col 0: h = x = [1, 3]. col 1 row 0: .5*h0 + .5*h1 + x01 = .5+1.5+2 = 4
        //        col 1 row 1: .5*h0 + .5*h1 + x11 = 2 + 4 = 6
        assert!((out.at(&[0, 0, 0, 1]) - 4.0).abs() < 1e-6);
        assert!((out.at(&[0, 0, 1, 1]) - 6.0).abs() < 1e-6);
    }

    #[test]
    fn linearity_in_x() {
        check("scan linear in x", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 3);
            let h = g.int_in(1, 6);
            let w = g.int_in(1, 6);
            let mut rng = Rng::new(g.rng.next_u64());
            let x1 = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let x2 = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let raw = Tensor::randn(&[n, 1, 3, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = Taps::normalize(&raw);
            let a = 1.7f32;
            let lhs = scan_l2r(&x1.scale(a).add(&x2), &taps, &lam, 0);
            let rhs = scan_l2r(&x1, &taps, &lam, 0).scale(a).add(&scan_l2r(&x2, &taps, &lam, 0));
            ensure_close(
                lhs.max_abs_diff(&rhs) as f64,
                0.0,
                1e-4,
                "linearity residual",
            )
        });
    }

    #[test]
    fn stability_bound() {
        // ||h_i||_inf <= cumulative max ||lam x||_inf (row-stochastic w).
        check("stability-context bound", |g| {
            let h = g.int_in(1, 8);
            let w = g.int_in(1, 10);
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[1, 1, h, w], &mut rng, 2.0);
            let raw = Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[1, 1, h, w], &mut rng, 2.0);
            let taps = Taps::normalize(&raw);
            let out = scan_l2r(&x, &taps, &lam, 0);
            let mut bound = 0.0f32;
            for i in 0..w {
                let mut colmax = 0.0f32;
                for r in 0..h {
                    colmax = colmax.max((lam.at(&[0, 0, r, i]) * x.at(&[0, 0, r, i])).abs());
                }
                bound += colmax;
                for r in 0..h {
                    ensure(
                        out.at(&[0, 0, r, i]).abs() <= bound + 1e-4,
                        format!("|h| {} > bound {}", out.at(&[0, 0, r, i]).abs(), bound),
                    )
                    .unwrap();
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chunk_reset_blocks_flow() {
        let (x, taps, lam) = case(7, 1, 1, 4, 8, 1);
        let base = scan_l2r(&x, &taps, &lam, 4);
        let mut x2 = x.clone();
        for r in 0..4 {
            for i in 0..4 {
                *x2.at_mut(&[0, 0, r, i]) += 50.0;
            }
        }
        let pert = scan_l2r(&x2, &taps, &lam, 4);
        for r in 0..4 {
            for i in 4..8 {
                assert_eq!(base.at(&[0, 0, r, i]), pert.at(&[0, 0, r, i]));
            }
        }
        assert!(base.max_abs_diff(&pert) > 1.0);
    }

    #[test]
    fn global_scan_propagates_across() {
        let (x, taps, lam) = case(8, 1, 1, 4, 8, 1);
        let base = scan_l2r(&x, &taps, &lam, 0);
        let mut x2 = x.clone();
        *x2.at_mut(&[0, 0, 2, 0]) += 10.0;
        let pert = scan_l2r(&x2, &taps, &lam, 0);
        let tail_diff: f32 = (0..4)
            .map(|r| (base.at(&[0, 0, r, 7]) - pert.at(&[0, 0, r, 7])).abs())
            .sum();
        assert!(tail_diff > 1e-4, "no propagation to last column");
    }

    #[test]
    fn kchunk_full_width_equals_global() {
        let (x, taps, lam) = case(9, 2, 2, 5, 6, 1);
        let a = scan_l2r(&x, &taps, &lam, 0);
        let b = scan_l2r(&x, &taps, &lam, 6);
        assert!(a.allclose(&b, 1e-7, 1e-7));
    }

    #[test]
    fn per_channel_vs_shared_differ() {
        let mut rng = Rng::new(10);
        let x = Tensor::randn(&[1, 3, 4, 5], &mut rng, 1.0);
        let lam = Tensor::randn(&[1, 3, 4, 5], &mut rng, 1.0);
        let raw_pc = Tensor::randn(&[1, 3, 3, 4, 5], &mut rng, 1.0);
        let raw_sh = Tensor::randn(&[1, 1, 3, 4, 5], &mut rng, 1.0);
        let a = scan_l2r(&x, &Taps::normalize(&raw_pc), &lam, 0);
        let b = scan_l2r(&x, &Taps::normalize(&raw_sh), &lam, 0);
        assert!(a.max_abs_diff(&b) > 1e-4);
    }

    #[test]
    fn output_modulation_scales_channels() {
        let h = Tensor::full(&[1, 2, 2, 2], 1.0);
        let y = output_modulation(&h, &[2.0, -1.0]);
        assert_eq!(y.at(&[0, 0, 1, 1]), 2.0);
        assert_eq!(y.at(&[0, 1, 0, 0]), -1.0);
    }

    #[test]
    fn flops_formula() {
        assert_eq!(scan_flops(2, 4, 8, 16), 7 * 2 * 4 * 8 * 16);
    }

    #[test]
    fn kchunk_validation() {
        assert!(kchunk_valid(64, 0));
        assert!(kchunk_valid(64, 16));
        assert!(kchunk_valid(64, 64));
        assert!(!kchunk_valid(64, 3));
        assert!(!kchunk_valid(64, 128));
        assert!(kchunk_valid(1, 1));
    }

    #[test]
    fn pool_scan_is_bit_identical_to_serial() {
        // Plane-level parallelism must not change a single bit: compare
        // with exact equality, not allclose.
        let pool = crate::util::ThreadPool::new(4);
        for (seed, n, c, h, w, cw) in
            [(20, 2, 3, 8, 12, 3), (21, 1, 1, 5, 7, 1), (22, 3, 4, 16, 16, 1)]
        {
            let (x, taps, lam) = case(seed, n, c, h, w, cw);
            for kchunk in [0, w] {
                let serial = scan_l2r(&x, &taps, &lam, kchunk);
                let pooled = scan_l2r_pool(&x, &taps, &lam, kchunk, &pool);
                assert_eq!(serial.data, pooled.data, "n{n} c{c} {h}x{w} k{kchunk}");
            }
        }
    }

    #[test]
    fn global_pool_scan_matches_serial() {
        let (x, taps, lam) = case(23, 2, 4, 6, 8, 1);
        let serial = scan_l2r(&x, &taps, &lam, 4);
        let pooled = scan_l2r_par(&x, &taps, &lam, 4);
        assert_eq!(serial.data, pooled.data);
    }
}
