//! The tiled streaming executor ([`crate::scan::plan::ScanStrategy::Tiled`]):
//! run a huge geometry as a stream of bands along the scan axis, each
//! band executed by the full existing engine from the [`ExternalCarry`]
//! handed off by the previous band.
//!
//! Memory, not arithmetic, is what tiling changes: every band leases
//! its staged taps, retained panels, and scratch from the workspace and
//! returns them before the next band starts, so peak `bytes_leased` is
//! bounded by ONE band regardless of the full geometry. The carry
//! columns crossing band boundaries are KiB-scale [`ExternalCarry`]
//! values — the serialization seam a LASP-2-style multi-node split
//! would ship between processes.
//!
//! Bit-exactness (`==` with the untiled engines and `scan_l2r` /
//! `scan_l2r_split`, pinned by tests) rests on three invariants:
//!
//! 1. **Directions run serially, bands within a direction serially.**
//!    Each output pixel receives its k = 0..ndirs epilogue ops in
//!    exactly the untiled order (bands of one direction write disjoint
//!    spatial regions).
//! 2. **Segment-bearing inners keep the untiled piece set.** A band
//!    groups whole pieces of `segment_bounds(wc, s)` — never re-cutting
//!    one — so phase-1 pieces, correction seams, and chunk resets (on
//!    global column indices throughout) are identical to the untiled
//!    `Segmented{s}` / `Chained{s}` run; the only change is *when* a
//!    piece's correction learns its carry (from the previous band's
//!    exit instead of an in-call chain — same f32 value either way,
//!    since a band's exit IS the corrected last column).
//! 3. **`Seq` bands replay the sequential recurrence.** The carry
//!    column crosses the band boundary exactly as it crosses a slab
//!    boundary inside [`run_plane`](super::chunk::run_plane).
//!
//! [`run_plane`]: super::chunk::run_plane

use super::carry::{run_engine_chained_into, CarrySource, ChainOpts, ExternalCarry};
use super::chunk::{scan_piece_into, scan_slab, segment_bounds, FusedScratch};
use super::drain::{drain_dir_fused, drain_scatter, DrainScratch};
use super::pack::{pack_slab, StagedTaps, SLAB};
use super::{out_tensor, DirInput};
use crate::scan::plan::TileInner;
use crate::scan::simd::Precision;
use crate::tensor::Tensor;
use crate::util::workspace::BufferPool;
use crate::util::ThreadPool;

/// Group the untiled piece list into bands of whole pieces: `g`
/// consecutive pieces per band, where `g` is the most pieces whose
/// combined extent stays within `band_rows` (always at least one —
/// a band never re-cuts a piece, so a `band_rows` smaller than one
/// piece degrades to one piece per band).
fn piece_groups(npieces: usize, piece_len: usize, band_rows: usize) -> Vec<(usize, usize)> {
    let g = (band_rows.max(piece_len) / piece_len).max(1);
    (0..npieces).step_by(g).map(|b0| (b0, (b0 + g).min(npieces))).collect()
}

/// Execute the pass as a stream of row-band tiles (see the module
/// docs). `band_rows` is the band extent along the scan axis in
/// canonical columns — spatial rows for T2B/B2T, spatial columns for
/// L2R/R2L; `inner` is the engine each band runs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine_tiled(
    dirs: &[DirInput<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    band_rows: usize,
    inner: TileInner,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
    prec: Precision,
) -> Tensor {
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let nplanes = out_shape[0] * c;
    let band_rows = band_rows.max(1);
    let mut out = out_tensor(out_shape, out_buf);
    let last = dirs.len() - 1;
    for (k, di) in dirs.iter().enumerate() {
        let (hc, wc) = (di.taps.h, di.taps.w);
        if wc == 0 {
            continue;
        }
        // The band hand-off: `entry` seeds this band, the band resolves
        // its own exit, and the pair swaps. `to_bytes`/`from_bytes` on
        // [`ExternalCarry`] is the (bit-exact) wire format a multi-node
        // split would insert right here.
        let mut entry = ExternalCarry::zeros(hc, nplanes);
        let mut exit = ExternalCarry::zeros(hc, nplanes);
        match inner {
            TileInner::Seq => {
                let mut lo = 0;
                while lo < wc {
                    let hi = (lo + band_rows).min(wc);
                    band_seq(
                        di, c, (h, w), lo, hi, wts, gain, k, last, &entry, &mut exit,
                        pool, ws, prec, &mut out.data,
                    );
                    std::mem::swap(&mut entry, &mut exit);
                    lo = hi;
                }
            }
            TileInner::Segmented { s } => {
                let bounds = segment_bounds(wc, s.max(1));
                let piece_len = bounds[0].1 - bounds[0].0;
                for (b0, b1) in piece_groups(bounds.len(), piece_len, band_rows) {
                    band_segmented(
                        di, c, (h, w), &bounds[b0..b1], wts, gain, k, last, &entry,
                        &mut exit, pool, ws, prec, &mut out.data,
                    );
                    std::mem::swap(&mut entry, &mut exit);
                }
            }
            TileInner::Chained { s } => {
                let s = s.max(1);
                let bounds = segment_bounds(wc, s);
                let piece_len = bounds[0].1 - bounds[0].0;
                let dir_one = std::slice::from_ref(di);
                for (b0, b1) in piece_groups(bounds.len(), piece_len, band_rows) {
                    let (lo, hi) = (bounds[b0].0, bounds[b1 - 1].1);
                    let staged = [StagedTaps::build_band(di.taps, pool, ws, prec, lo, hi)];
                    run_engine_chained_into(
                        dir_one,
                        &staged,
                        wts,
                        gain,
                        out_shape,
                        pool,
                        s,
                        ws,
                        prec,
                        ChainOpts {
                            band: Some((b0, b1)),
                            entry: Some(&entry),
                            exit: Some(&mut exit),
                            ep: Some((k, last)),
                        },
                        &mut out.data,
                    );
                    std::mem::swap(&mut entry, &mut exit);
                }
            }
        }
    }
    out
}

/// One `Seq` band of one direction: every plane advances the plain
/// sequential recurrence over columns `[lo, hi)` from its entry carry —
/// the same slab loop as the plane pipeline, with the band boundary
/// crossing the carry column exactly like a slab boundary.
#[allow(clippy::too_many_arguments)]
fn band_seq(
    di: &DirInput<'_>,
    c: usize,
    hw: (usize, usize),
    lo: usize,
    hi: usize,
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    k: usize,
    last: usize,
    entry: &ExternalCarry,
    exit: &mut ExternalCarry,
    pool: Option<&ThreadPool>,
    ws: &BufferPool,
    prec: Precision,
    out_data: &mut [f32],
) {
    let (h, w) = hw;
    let plane = h * w;
    let hc = di.taps.h;
    let hmax = h.max(w);
    let staged = StagedTaps::build_band(di.taps, pool, ws, prec, lo, hi);
    let jobs: Vec<(usize, &mut [f32], &mut [f32])> = out_data
        .chunks_mut(plane)
        .zip(exit.columns_mut())
        .enumerate()
        .map(|(p, (os, ec))| (p, os, ec))
        .collect();
    let run_one = |(p, os, ecol): (usize, &mut [f32], &mut [f32])| {
        let mut scratch = FusedScratch::new(hmax, ws);
        CarrySource::External(entry, p).seed(&mut scratch.carry[..hc]);
        let base = p * plane;
        let xs = &di.x.data[base..base + plane];
        let ls = &di.lam.data[base..base + plane];
        let taps = staged.panels(p / c, p % c);
        let gv = gain.map(|g| g[p % c]);
        let mut i0 = lo;
        while i0 < hi {
            let sw = SLAB.min(hi - i0);
            pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut scratch.b);
            scan_slab(
                hc,
                i0,
                sw,
                di.chunk,
                &scratch.b,
                taps,
                &scratch.zeros,
                &mut scratch.carry,
                &mut scratch.h,
            );
            drain_scatter(&scratch.h, h, w, di.d, i0, sw, hc, os, wts, k, last, gv);
            i0 += sw;
        }
        ecol[..hc].copy_from_slice(&scratch.carry[..hc]);
    };
    match pool {
        Some(pool) if pool.threads() > 1 && jobs.len() > 1 => pool.map(jobs, run_one),
        _ => jobs.into_iter().for_each(run_one),
    }
}

/// One `Segmented{s}` band of one direction: phase-1 scans the band's
/// (untiled-identical) pieces from zero carries into a band-sized
/// retained panel, phase-2 drains them through the fused-correction
/// drain seeded by the band's [`CarrySource::External`] entry. The exit
/// carry is the drain's tracked corrected last column.
#[allow(clippy::too_many_arguments)]
fn band_segmented(
    di: &DirInput<'_>,
    c: usize,
    hw: (usize, usize),
    pieces: &[(usize, usize)],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    k: usize,
    last: usize,
    entry: &ExternalCarry,
    exit: &mut ExternalCarry,
    pool: Option<&ThreadPool>,
    ws: &BufferPool,
    prec: Precision,
    out_data: &mut [f32],
) {
    let (h, w) = hw;
    let plane = h * w;
    let hc = di.taps.h;
    let hmax = h.max(w);
    let nplanes = out_data.len() / plane.max(1);
    let (lo, hi) = (pieces[0].0, pieces[pieces.len() - 1].1);
    let band_cols = hi - lo;
    let staged = [StagedTaps::build_band(di.taps, pool, ws, prec, lo, hi)];
    let dir_one = std::slice::from_ref(di);
    // Band-sized retained panels: per plane, the band's canonical
    // columns. Zero-reset for the same pool-history-independence
    // argument as the untiled segmented engine.
    let mut hbufs = ws.acquire_zeroed(nplanes * band_cols * hc);
    {
        let mut jobs: Vec<(usize, usize, usize, &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = &mut hbufs;
        for p in 0..nplanes {
            for &(plo, phi) in pieces {
                let (buf, tail) = std::mem::take(&mut rest).split_at_mut((phi - plo) * hc);
                rest = tail;
                jobs.push((p, plo, phi, buf));
            }
        }
        let scan_piece = |(p, plo, phi, buf): (usize, usize, usize, &mut [f32])| {
            scan_piece_into(dir_one, &staged, c, (h, w), hmax, p, 0, plo, phi, buf, ws);
        };
        match pool {
            Some(pool) if pool.threads() > 1 && jobs.len() > 1 => pool.map(jobs, scan_piece),
            _ => jobs.into_iter().for_each(scan_piece),
        }
    }
    let planes: Vec<(usize, &mut [f32], &[f32], &mut [f32])> = out_data
        .chunks_mut(plane)
        .zip(hbufs.chunks(band_cols * hc))
        .zip(exit.columns_mut())
        .enumerate()
        .map(|(p, ((os, pb), ec))| (p, os, pb, ec))
        .collect();
    let correct_and_drain = |(p, os, pb, ecol): (usize, &mut [f32], &[f32], &mut [f32])| {
        let mut scratch = DrainScratch::new(hmax, ws);
        let taps = staged[0].panels(p / c, p % c);
        let piece_refs: Vec<&[f32]> = pieces
            .iter()
            .map(|&(plo, phi)| &pb[(plo - lo) * hc..(phi - lo) * hc])
            .collect();
        drain_dir_fused(
            &piece_refs,
            pieces,
            hc,
            di.chunk,
            taps,
            (h, w),
            di.d,
            os,
            wts,
            k,
            last,
            gain.map(|g| g[p % c]),
            CarrySource::External(entry, p),
            &mut scratch,
        );
        ecol[..hc].copy_from_slice(&scratch.carry[..hc]);
    };
    match pool {
        Some(pool) if pool.threads() > 1 && planes.len() > 1 => {
            pool.map(planes, correct_and_drain);
        }
        _ => planes.into_iter().for_each(correct_and_drain),
    }
}
