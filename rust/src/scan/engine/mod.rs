//! The scan engine: one set of chunk/carry/drain primitives behind
//! every fused entry point and every execution strategy.
//!
//! Module map (the former monolithic `scan/fused.rs`, split along the
//! carry algebra):
//!
//! * [`pack`] — canonical staging: tap panel transposes ([`StagedTaps`]
//!   / [`TapView`], whole-axis or per-band), the `b = lam ⊙ x` slab
//!   pack, and the spatial↔canonical index maps ([`hw_src`]).
//! * [`chunk`] — chunk execution: the slab scan ([`scan_slab`]), the
//!   zero-carry piece bodies ([`scan_piece_into`] and its bf16 twin),
//!   the plane pipeline ([`run_plane`]), and the shared
//!   [`segment_bounds`] decomposition.
//! * [`carry`] — carry resolution: the [`CarrySource`] contract
//!   (`Zero` / `Resolved` / `Lookback` / `External`), the shared
//!   correction body ([`carry::correct_segment`]), the serializable
//!   [`ExternalCarry`] band/shard hand-off, and the single-pass chained
//!   engine ([`run_engine_chained`]).
//! * [`drain`] — the epilogue: the one scatter/merge/modulate dispatch
//!   ([`drain_scatter`]), the fused-correction drain
//!   ([`drain_dir_fused`], seeded from a [`CarrySource`]), and the
//!   barrier/wavefront segmented engines.
//! * [`tiled`] — the streaming row-band executor
//!   ([`run_engine_tiled`]): any inner strategy run band by band along
//!   the scan axis between [`ExternalCarry`] hand-offs, with per-band
//!   workspace leases so peak memory is bounded by one band.
//!
//! Every strategy — plane-parallel, segmented (barrier or wavefront),
//! chained, the direction fan, and the tiled stream — is a composition
//! of those primitives, and all of them are pinned bit-exact (`==`)
//! against the `scan_l2r` / `scan_l2r_split` references by the test
//! suite in this module. This file owns what is shared: the input
//! descriptors, strategy selection ([`run_engine`]), and the public
//! `fused_*` entry points.

use super::direction::{merge_weights, Direction, DIRECTIONS};
use super::plan::{self, ScanGeometry, ScanStrategy};
use super::simd::{self, Precision};
use super::taps::Taps;
use crate::tensor::Tensor;
use crate::util::workspace::BufferPool;
use crate::util::ThreadPool;

pub(crate) mod carry;
pub(crate) mod chunk;
pub(crate) mod drain;
pub(crate) mod pack;
pub(crate) mod tiled;

#[cfg(test)]
mod tests;

pub use carry::ExternalCarry;
pub(crate) use carry::{run_engine_chained, CarrySource, ChainOpts};
pub(crate) use chunk::{plane_blocks, segment_bounds, scan_piece_into, scan_slab, FusedScratch, run_plane};
pub(crate) use drain::{drain_dir_fused, drain_scatter, run_engine_segmented, DrainScratch};
pub(crate) use pack::{hw_src, pack_slab, Orientation, StagedTaps, TapView, SLAB};
pub(crate) use tiled::run_engine_tiled;

/// How a segmented run's phase 2 (carry correction + epilogue drain) is
/// scheduled and expressed. All three produce identical bits (pinned by
/// tests); they differ in memory traffic and overlap.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Phase2 {
    /// Global two-`map` barrier between the phases; correction fused
    /// into the drain.
    Barrier,
    /// The PR 4 schedule: one continuation per plane running the
    /// *two-pass* correct-then-drain ([`correct_and_drain_pieces`]) —
    /// it re-touches the retained panel in place before the drain
    /// re-reads it. Kept as the bit/bench reference the fused drain is
    /// measured against (`BENCH_scan`'s "two-pass" rows).
    WavePlane,
    /// Per-direction wavefront continuations (4 per plane) with the
    /// correction fused into the scatter drain — the production
    /// schedule behind every `wavefront` plan.
    WaveDir,
}

/// How an engine run decomposes its work across the pool. The engine
/// holds no selection heuristics of its own: `Auto` defers to the
/// planner ([`plan::plan_scan`]), `Forced` carries a caller- or
/// test-chosen plan verbatim.
#[derive(Clone, Copy)]
pub(crate) enum ExecSpec {
    /// Consult [`plan::plan_scan`] from the pass geometry + pool state.
    Auto,
    /// Execute exactly this strategy (segment counts clamped per
    /// direction to its canonical width) with the given phase-2
    /// schedule — the bit-identity testing / bench / plan-carrying
    /// hook.
    Forced(ScanStrategy, Phase2),
}

// ---------------------------------------------------------------------
// Input descriptors + engine core
// ---------------------------------------------------------------------

/// One direction's inputs to the fused engine.
pub(crate) struct DirInput<'a> {
    pub(crate) d: Direction,
    pub(crate) taps: &'a Taps,
    pub(crate) x: &'a Tensor,
    pub(crate) lam: &'a Tensor,
    pub(crate) layout: Orientation,
    /// Effective chunk width in canonical columns.
    pub(crate) chunk: usize,
}

fn effective_chunk(wc: usize, kchunk: usize) -> usize {
    let chunk = if kchunk == 0 { wc } else { kchunk };
    assert!(wc % chunk == 0, "kchunk={chunk} must divide W={wc}");
    chunk
}

fn validate_dir(x: &Tensor, taps: &Taps, lam: &Tensor, d: Direction) {
    assert_eq!(x.rank(), 4, "x must be (N, C, H, W)");
    assert_eq!(x.shape, lam.shape, "lam shape must match x");
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (hc, wc) = hw_src(h, w, d);
    assert_eq!((taps.n, taps.h, taps.w), (n, hc, wc), "taps geometry mismatch");
    assert!(taps.cw == 1 || taps.cw == c, "Cw must be 1 or C");
}

/// Materialize the engine's output tensor: the caller-recycled buffer
/// (must be zeroed and exactly `numel` long — the coordinator's
/// reply-recycling path, see [`fused_scan_l2r_pool_ws_into`]) or a
/// fresh zeroed allocation. The recycled buffer only replaces
/// `Tensor::zeros`, so every drain writes the same bits either way.
pub(crate) fn out_tensor(shape: &[usize], recycled: Option<Vec<f32>>) -> Tensor {
    match recycled {
        Some(buf) => {
            debug_assert!(buf.iter().all(|&v| v == 0.0), "recycled output must be zeroed");
            Tensor::from_vec(shape, buf)
        }
        None => Tensor::zeros(shape),
    }
}

/// Drive the fused pipeline over all (N·C) planes — serially, in
/// block-granular plane jobs on the pool, or (when the plan asks for
/// it) through the segment-parallel / direction-fan decompositions,
/// with or without wavefront continuations. `out_buf`, when given, is a
/// recycled zeroed buffer the output tensor is built over instead of a
/// fresh allocation.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine(
    dirs: &[DirInput<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    exec: ExecSpec,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
    prec: Option<Precision>,
) -> Tensor {
    let (n, c) = (out_shape[0], out_shape[1]);
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = n * c;
    if nplanes == 0 || plane == 0 {
        return out_tensor(out_shape, out_buf);
    }
    let hmax = h.max(w);
    let prec = prec.unwrap_or_else(simd::precision);
    let (strategy, phase2) = match exec {
        ExecSpec::Forced(s, p2) => (s, p2),
        ExecSpec::Auto => match pool {
            Some(pool) => {
                let geom = ScanGeometry {
                    nplanes,
                    ndirs: dirs.len(),
                    wc_min: dirs.iter().map(|di| di.taps.w).min().unwrap_or(0),
                    plane_px: plane,
                    hmax,
                };
                let p = plan::plan_scan(&geom, pool.load(), pool.threads());
                // Bounded-memory guard: when the chosen plan's footprint
                // would blow past the workspace cap, stream it as
                // row-band tiles of the same inner strategy instead
                // (exact same bits, peak leases bounded by one band).
                let tap_blocks =
                    dirs.iter().map(|di| di.taps.n * di.taps.cw).max().unwrap_or(1);
                let p = plan::maybe_tile(
                    p,
                    &geom,
                    pool.threads(),
                    tap_blocks,
                    ws.cap_bytes(),
                    prec == Precision::Bf16,
                );
                // A wavefront plan means the per-direction continuation
                // schedule; the PR 4 per-plane two-pass schedule is
                // test/bench-only.
                let p2 = if p.wavefront { Phase2::WaveDir } else { Phase2::Barrier };
                (p.strategy, p2)
            }
            None => (ScanStrategy::PlanePar, Phase2::Barrier),
        },
    };
    // The tiled stream stages taps and leases panels band by band —
    // dispatch before the whole-axis staging below so a bounded-memory
    // run never holds full-geometry panels.
    if let ScanStrategy::Tiled { band_rows, inner } = strategy {
        return run_engine_tiled(
            dirs, wts, gain, out_shape, pool, band_rows, inner, ws, out_buf, prec,
        );
    }
    let staged: Vec<StagedTaps<'_>> =
        dirs.iter().map(|d| StagedTaps::build(d.taps, pool, ws, prec)).collect();
    let segments = match strategy {
        ScanStrategy::PlanePar => None,
        ScanStrategy::Segmented { s } => Some(s.max(1)),
        // The chained strategy runs its own single-pass engine: there
        // are no phases, so the phase-2 schedule does not apply.
        ScanStrategy::Chained { s } => {
            return run_engine_chained(
                dirs,
                &staged,
                wts,
                gain,
                out_shape,
                pool,
                s.max(1),
                ws,
                out_buf,
                prec,
                ChainOpts::default(),
            );
        }
        // The direction fan is the s = 1 degenerate segmented run: one
        // full-width zero-carry (i.e. exact) phase-1 job per (plane,
        // direction), no correction, fixed-order merge drain. A
        // single-direction pass has nothing to fan: plane path.
        ScanStrategy::DirFan => (dirs.len() > 1).then_some(1),
        ScanStrategy::Tiled { .. } => unreachable!("tiled dispatched above"),
    };
    if let Some(segments) = segments {
        return run_engine_segmented(
            dirs, &staged, wts, gain, out_shape, pool, segments, phase2, ws, out_buf,
        );
    }
    let mut out = out_tensor(out_shape, out_buf);
    let gain_for = |ci: usize| gain.map(|g| g[ci]);

    match pool {
        Some(pool) if nplanes > 1 && pool.threads() > 1 => {
            let nblocks = plane_blocks(nplanes, pool.threads());
            let per_block = nplanes.div_ceil(nblocks);
            let jobs: Vec<(usize, &mut [f32])> =
                out.data.chunks_mut(per_block * plane).enumerate().collect();
            pool.map(jobs, |(bi, block)| {
                let mut scratch = FusedScratch::new(hmax, ws);
                for (j, os) in block.chunks_mut(plane).enumerate() {
                    let p = bi * per_block + j;
                    run_plane(
                        dirs,
                        &staged,
                        wts,
                        gain_for(p % c),
                        p / c,
                        p % c,
                        c,
                        (h, w),
                        os,
                        &mut scratch,
                    );
                }
            });
        }
        _ => {
            let mut scratch = FusedScratch::new(hmax, ws);
            for (p, os) in out.data.chunks_mut(plane).enumerate() {
                run_plane(
                    dirs,
                    &staged,
                    wts,
                    gain_for(p % c),
                    p / c,
                    p % c,
                    c,
                    (h, w),
                    os,
                    &mut scratch,
                );
            }
        }
    }
    out
}

/// Test-only fault injection for the wavefront phase-1 pieces and the
/// chained chunk jobs: lets the panic-propagation suites force exactly
/// one (plane, dir, lo, hi) piece to panic and assert the payload
/// surfaces as the collected graph/map error (not a `PoisonError`, a
/// secondary index panic, or a hung look-back waiter).
#[cfg(test)]
pub(crate) mod test_hooks {
    use std::sync::Mutex;

    pub(crate) static PANIC_PIECE: Mutex<Option<(usize, usize, usize, usize)>> =
        Mutex::new(None);

    pub(crate) fn maybe_panic(p: usize, k: usize, lo: usize, hi: usize) {
        let hit = crate::util::lock_unpoisoned(&PANIC_PIECE)
            .map_or(false, |t| t == (p, k, lo, hi));
        if hit {
            panic!("injected phase-1 panic at ({p},{k},{lo},{hi})");
        }
    }
}

// ---------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------

/// Fused directional scan (serial): bit-identical to
/// `scan_dir(x, taps, lam, d, kchunk)` with zero canonical copies.
pub fn fused_scan_dir(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, d, kchunk, None, BufferPool::global(), None)
}

/// [`fused_scan_dir`] with block-granular plane jobs on `pool`.
pub fn fused_scan_dir_pool(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, d, kchunk, Some(pool), BufferPool::global(), None)
}

/// [`fused_scan_dir_pool`] drawing all per-call scratch from an explicit
/// workspace pool instead of the process-global one — the serving entry:
/// the coordinator owns one pool so its hit/miss counters are isolated
/// and pre-warmable per bucket.
pub fn fused_scan_dir_pool_ws(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    pool: &ThreadPool,
    ws: &BufferPool,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, d, kchunk, Some(pool), ws, None)
}

fn fused_scan_dir_inner(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    pool: Option<&ThreadPool>,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
) -> Tensor {
    validate_dir(x, taps, lam, d);
    if x.data.is_empty() {
        return out_tensor(&x.shape, out_buf);
    }
    let chunk = effective_chunk(taps.w, kchunk);
    let dirs = [DirInput { d, taps, x, lam, layout: Orientation::Spatial, chunk }];
    run_engine(&dirs, None, None, &x.shape, pool, ExecSpec::Auto, ws, out_buf, None)
}

/// [`fused_scan_dir_pool`] under an explicit, caller-forced strategy +
/// phase-2 schedule. The pooled entry points normally consult the
/// planner ([`plan::plan_scan`]); this hook exists for tests, benches,
/// and plan-carrying callers that already decided.
#[allow(clippy::too_many_arguments)]
fn fused_scan_dir_forced(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_forced_ws(
        x,
        taps,
        lam,
        d,
        kchunk,
        strategy,
        phase2,
        pool,
        BufferPool::global(),
        None,
    )
}

/// [`fused_scan_dir_forced`] over an explicit workspace — the hook the
/// pooled-vs-fresh bit-exactness and zero-miss tests drive per strategy.
/// `prec` overrides the panel/tap storage precision *for this call
/// only* (tests must never flip the process-global precision override:
/// concurrently running `==` suites would observe it).
#[allow(clippy::too_many_arguments)]
fn fused_scan_dir_forced_ws(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
    ws: &BufferPool,
    prec: Option<Precision>,
) -> Tensor {
    validate_dir(x, taps, lam, d);
    if x.data.is_empty() {
        return Tensor::zeros(&x.shape);
    }
    let chunk = effective_chunk(taps.w, kchunk);
    let dirs = [DirInput { d, taps, x, lam, layout: Orientation::Spatial, chunk }];
    run_engine(
        &dirs,
        None,
        None,
        &x.shape,
        Some(pool),
        ExecSpec::Forced(strategy, phase2),
        ws,
        None,
        prec,
    )
}

/// [`fused_scan_dir_pool`] with a *forced* segment-parallel
/// decomposition: each plane's canonical columns are scanned as
/// `segments` zero-carry segments and carry-corrected — bit-identical
/// (exact `==`, pinned by tests) to running
/// [`super::split::scan_l2r_split`] on the canonically reoriented
/// tensors with the same count. Runs the barrier schedule; see
/// [`fused_scan_dir_seg_wave`] for the wavefront twin.
pub fn fused_scan_dir_seg(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_scan_dir_seg`] under per-direction wavefront scheduling:
/// each (plane, direction)'s fused correction + epilogue drain runs as
/// its own continuation of that direction's phase-1 segment jobs
/// instead of behind a global barrier. Scheduling only — exact `==`
/// with [`fused_scan_dir_seg`] (and the `scan_l2r_split` reference) at
/// the same count, pinned by tests.
pub fn fused_scan_dir_seg_wave(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::WaveDir, pool)
}

/// [`fused_scan_dir_seg_wave`] under the retired PR 4 schedule: one
/// continuation per plane running the *two-pass* correct-then-drain
/// (the retained panel is corrected in place, then re-read by the
/// drain). Exact `==` with both other schedules — kept as the bit and
/// bench reference the fused-correction drain is measured against.
pub fn fused_scan_dir_seg_wave_twopass(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::WavePlane, pool)
}

/// [`fused_scan_dir_seg`] executed by the single-pass chained engine
/// ([`ScanStrategy::Chained`], [`run_engine_chained`]): one decoupled
/// look-back job per (plane, direction, segment), no phase barrier, no
/// retained panels. Exact `==` with [`fused_scan_dir_seg`] (and hence
/// `scan_l2r_split`) at the same count, pinned by tests.
pub fn fused_scan_dir_chained(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Chained { s: segments };
    // The chained engine has no phase 2; the schedule arg is inert.
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_scan_dir_chained`] for the canonical left-to-right scan.
pub fn fused_scan_l2r_chained(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_chained(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// [`fused_scan_dir_seg`] for the canonical left-to-right scan: the
/// segmented twin of [`fused_scan_l2r_pool`], exact `==` with
/// [`super::split::scan_l2r_split`] at the same count.
pub fn fused_scan_l2r_seg(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_seg(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// [`fused_scan_l2r_seg`] under wavefront scheduling (see
/// [`fused_scan_dir_seg_wave`]).
pub fn fused_scan_l2r_seg_wave(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_seg_wave(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// [`fused_scan_l2r_seg_wave`] under the PR 4 two-pass schedule (see
/// [`fused_scan_dir_seg_wave_twopass`]).
pub fn fused_scan_l2r_seg_wave_twopass(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_seg_wave_twopass(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// Fused canonical scan (serial): bit-identical to `scan_l2r`.
pub fn fused_scan_l2r(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> Tensor {
    fused_scan_dir(x, taps, lam, Direction::L2R, kchunk)
}

/// [`fused_scan_l2r`] with block-granular plane jobs on `pool`.
pub fn fused_scan_l2r_pool(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_pool(x, taps, lam, Direction::L2R, kchunk, pool)
}

/// [`fused_scan_l2r_pool`] over an explicit workspace pool (see
/// [`fused_scan_dir_pool_ws`]) — what the coordinator's CPU batch path
/// calls so steady-state serving of a warm bucket allocates nothing in
/// the scan hot path.
pub fn fused_scan_l2r_pool_ws(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
    ws: &BufferPool,
) -> Tensor {
    fused_scan_dir_pool_ws(x, taps, lam, Direction::L2R, kchunk, pool, ws)
}

/// [`fused_scan_l2r_pool_ws`] writing its output into a caller-recycled
/// buffer — zeroed, exactly `x` elements long, typically
/// [`BufferPool::take_zeroed`] from the same workspace. This is the
/// coordinator's reply-recycling hook: with the output buffer taken
/// from (and, via the client's `ReplyLease` drop, donated back to) the
/// request workspace, a warm bucket's hot path performs no heap
/// allocation at all, reply tensor included. Bit-identical to the plain
/// entry — the buffer only replaces the fresh `Tensor::zeros`.
pub fn fused_scan_l2r_pool_ws_into(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
    ws: &BufferPool,
    out_buf: Vec<f32>,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, Direction::L2R, kchunk, Some(pool), ws, Some(out_buf))
}

/// [`fused_scan_l2r`] over the process-wide shared pool.
pub fn fused_scan_l2r_par(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> Tensor {
    fused_scan_l2r_pool(x, taps, lam, kchunk, ThreadPool::global())
}

fn merged_dirs<'a>(
    x: &'a Tensor,
    taps: [&'a Taps; 4],
    lam: &'a Tensor,
    kchunk: usize,
) -> Vec<DirInput<'a>> {
    DIRECTIONS
        .iter()
        .enumerate()
        .map(|(k, &d)| {
            validate_dir(x, taps[k], lam, d);
            DirInput {
                d,
                taps: taps[k],
                x,
                lam,
                layout: Orientation::Spatial,
                chunk: effective_chunk(taps[k].w, kchunk),
            }
        })
        .collect()
}

/// Fused four-direction merge (serial): bit-identical to the reference
/// [`super::direction::merged_4dir_ref`], with the pack, all four scans,
/// and the weighted merge in one engine pass.
pub fn fused_merged_4dir(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    let dirs = merged_dirs(x, taps, lam, kchunk);
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        None,
        &x.shape,
        None,
        ExecSpec::Auto,
        BufferPool::global(),
        None,
        None,
    )
}

/// [`fused_merged_4dir`] with block-granular plane jobs on `pool`.
pub fn fused_merged_4dir_pool(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    let dirs = merged_dirs(x, taps, lam, kchunk);
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        None,
        &x.shape,
        Some(pool),
        ExecSpec::Auto,
        BufferPool::global(),
        None,
        None,
    )
}

/// [`fused_merged_4dir_pool`] under an explicit strategy + phase-2
/// schedule (the forced hook behind the seg / fan variants below).
#[allow(clippy::too_many_arguments)]
fn fused_merged_4dir_forced(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
) -> Tensor {
    fused_merged_4dir_forced_ws(
        x,
        taps,
        lam,
        merge_logits,
        kchunk,
        strategy,
        phase2,
        pool,
        BufferPool::global(),
        None,
    )
}

/// [`fused_merged_4dir_forced`] over an explicit workspace — the merged
/// twin of [`fused_scan_dir_forced_ws`] for the pooled-vs-fresh tests,
/// with the same per-call `prec` override.
#[allow(clippy::too_many_arguments)]
fn fused_merged_4dir_forced_ws(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
    ws: &BufferPool,
    prec: Option<Precision>,
) -> Tensor {
    let dirs = merged_dirs(x, taps, lam, kchunk);
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        None,
        &x.shape,
        Some(pool),
        ExecSpec::Forced(strategy, phase2),
        ws,
        None,
        prec,
    )
}

/// [`fused_merged_4dir_pool`] with a *forced* segment count per
/// direction (clamped to each direction's canonical width) — the
/// segmented twin of the merged pass for tests and benches. Segment
/// arithmetic follows the `scan_l2r_split` decomposition per direction;
/// merge order and the epilogue fusion are unchanged. Barrier schedule;
/// [`fused_merged_4dir_seg_wave`] is the wavefront twin.
pub fn fused_merged_4dir_seg(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_merged_4dir_seg`] under per-direction wavefront scheduling:
/// 4 drain continuations per plane, each depending on its own
/// direction's phase-1 jobs plus the previous direction's drain (the
/// chain preserves the k = 0..4 merge order), with the correction fused
/// into the merge drain. Exact `==` with the barrier twin, pinned by
/// tests.
pub fn fused_merged_4dir_seg_wave(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::WaveDir, pool)
}

/// [`fused_merged_4dir_seg_wave`] under the retired PR 4 schedule: one
/// two-pass correct-then-drain continuation per plane (see
/// [`fused_scan_dir_seg_wave_twopass`]). Exact `==` with both other
/// schedules; the bench comparison row for the fused-correction drain.
pub fn fused_merged_4dir_seg_wave_twopass(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::WavePlane, pool)
}

/// [`fused_merged_4dir_seg`] executed by the single-pass chained engine
/// (see [`fused_scan_dir_chained`]): per-direction chunk chains with
/// decoupled look-back, the k = 0..4 merge order preserved by the
/// per-plane drain gates. Exact `==` with the barrier twin, pinned by
/// tests.
pub fn fused_merged_4dir_chained(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Chained { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_merged_4dir_pool`] with the *forced* per-direction phase-1
/// fan-out ([`ScanStrategy::DirFan`]): one zero-carry full-width scan
/// job per (plane, direction), drained through the fixed-k-order merge
/// epilogue per plane — bit-identical (exact `==`, pinned by tests) to
/// [`fused_merged_4dir`] and the serial reference, ×4 the parallel
/// width. `wavefront` runs each (plane, direction)'s drain as its own
/// continuation of that direction's scan, chained to keep the merge
/// order — direction k's drain overlaps direction k+1's scan; `false`
/// uses the two-phase barrier schedule.
pub fn fused_merged_4dir_fan(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    wavefront: bool,
    pool: &ThreadPool,
) -> Tensor {
    let phase2 = if wavefront { Phase2::WaveDir } else { Phase2::Barrier };
    fused_merged_4dir_forced(
        x,
        taps,
        lam,
        merge_logits,
        kchunk,
        ScanStrategy::DirFan,
        phase2,
        pool,
    )
}

/// [`fused_merged_4dir`] over the process-wide shared pool.
pub fn fused_merged_4dir_par(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    fused_merged_4dir_pool(x, taps, lam, merge_logits, kchunk, ThreadPool::global())
}

/// The compact unit's scan stage, fused end to end: per-direction
/// activations `xcs[k]` / `lamcs[k]` are already in canonical layout
/// (they come out of the unit's 1x1 projections), taps are canonical as
/// always, and the epilogue folds the merge *and* the `u ⊙ h` output
/// modulation into the scatter — the unit never materializes a
/// directional output, the merged tensor, or the modulation clone.
/// Output is the spatial (N, Cp, H, W) modulated merge, bit-identical to
/// the reference composition in `CompactGspnUnit::forward_ref` whenever
/// the planner ([`plan::plan_scan`]) picks a bit-exact strategy —
/// `PlanePar` or, in the mid-occupancy regime, `DirFan` (the
/// per-direction fan reassociates nothing). Only a low-occupancy
/// forward wide enough to segment (canonical widths ≥ 2 ·
/// [`plan::MIN_SEG_COLS`] = 128) follows the `scan_l2r_split`
/// segmented arithmetic instead.
#[allow(clippy::too_many_arguments)]
pub fn fused_merged_canonical(
    xcs: [&Tensor; 4],
    taps: [&Taps; 4],
    lamcs: [&Tensor; 4],
    merge_logits: &[f32; 4],
    u: &[f32],
    kchunk: usize,
    out_shape: &[usize],
    pool: &ThreadPool,
) -> Tensor {
    fused_merged_canonical_ws(
        xcs,
        taps,
        lamcs,
        merge_logits,
        u,
        kchunk,
        out_shape,
        pool,
        BufferPool::global(),
    )
}

/// [`fused_merged_canonical`] over an explicit workspace pool — what
/// [`CompactGspnUnit::forward_ws`](super::compact::CompactGspnUnit::forward_ws)
/// threads through so a serving coordinator's unit forwards draw from
/// its pre-warmed per-bucket pool.
#[allow(clippy::too_many_arguments)]
pub fn fused_merged_canonical_ws(
    xcs: [&Tensor; 4],
    taps: [&Taps; 4],
    lamcs: [&Tensor; 4],
    merge_logits: &[f32; 4],
    u: &[f32],
    kchunk: usize,
    out_shape: &[usize],
    pool: &ThreadPool,
    ws: &BufferPool,
) -> Tensor {
    let dirs: Vec<DirInput<'_>> = DIRECTIONS
        .iter()
        .enumerate()
        .map(|(k, &d)| {
            let (xc, lamc) = (xcs[k], lamcs[k]);
            assert_eq!(xc.rank(), 4, "xc must be (N, C, Hc, Wc)");
            assert_eq!(xc.shape, lamc.shape, "lamc shape must match xc");
            assert_eq!(
                (taps[k].n, taps[k].h, taps[k].w),
                (xc.shape[0], xc.shape[2], xc.shape[3]),
                "taps geometry mismatch"
            );
            assert!(
                taps[k].cw == 1 || taps[k].cw == xc.shape[1],
                "Cw must be 1 or C"
            );
            DirInput {
                d,
                taps: taps[k],
                x: xc,
                lam: lamc,
                layout: Orientation::Canonical,
                chunk: effective_chunk(taps[k].w, kchunk),
            }
        })
        .collect();
    assert_eq!(u.len(), out_shape[1], "gain length must be C");
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        Some(u),
        out_shape,
        Some(pool),
        ExecSpec::Auto,
        ws,
        None,
        None,
    )
}
