//! Chunk execution: the zero-/carried-state column scans every strategy
//! is built from.
//!
//! A *chunk* is a contiguous range of canonical columns of one (plane,
//! direction). [`scan_slab`] advances the recurrence across one SLAB of
//! columns with an explicit carry column; [`scan_piece_into`] (and its
//! bf16 twin) runs a whole `[lo, hi)` piece from a zero incoming carry —
//! the phase-1 body shared by the Segmented, Chained, DirFan, and Tiled
//! strategies; [`run_plane`] is the plane-parallel pipeline that scans a
//! full plane sequentially (pack → scan → drain per slab). Carry
//! *resolution* — turning a zero-carry piece into the true sequential
//! result — lives in `super::carry`.

use super::drain::drain_scatter;
use super::pack::{pack_slab, StagedTaps, TapView, SLAB};
use super::DirInput;
use crate::scan::simd::{self, bf16_narrow};
use crate::util::workspace::{BufferPool, Lease};

// ---------------------------------------------------------------------
// Scan: the unit-stride staged kernel
// ---------------------------------------------------------------------

// The per-column kernels — the scan recurrence (`up + ct + dn + b` with
// literal `0.0` boundary terms, exactly `core::scan_plane`'s expression)
// and the carry-correction fold (the same recurrence without the `b`
// term, exactly `split::phase2_plane`'s association) — live in
// [`super::simd`] as `scan_col` / `correct_col`: a pinned scalar
// reference plus runtime-dispatched AVX2/NEON lane kernels that are
// bit-identical to it. The engine calls them through the dispatcher so
// every strategy path picks up the active kernel and tap precision.

/// Scan one slab of canonical columns. `carry` holds the previous
/// slab's last column on entry and this slab's last column on return —
/// the "shared-memory" column handed across slab boundaries. Chunk
/// resets (`gi % chunk == 0`) substitute the zero column, exactly like
/// the reference's `hprev` reset.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_slab(
    hc: usize,
    i0: usize,
    sw: usize,
    chunk: usize,
    b: &[f32],
    taps: TapView,
    zeros: &[f32],
    carry: &mut [f32],
    hs: &mut [f32],
) {
    for i in 0..sw {
        let gi = i0 + i;
        let col = i * hc;
        let (done, rest) = hs.split_at_mut(col);
        let cur = &mut rest[..hc];
        let prev: &[f32] = if gi % chunk == 0 {
            &zeros[..hc]
        } else if i == 0 {
            &carry[..hc]
        } else {
            &done[col - hc..]
        };
        simd::scan_col(prev, &b[col..col + hc], taps.col(gi, hc), cur);
    }
    carry[..hc].copy_from_slice(&hs[(sw - 1) * hc..sw * hc]);
}

// ---------------------------------------------------------------------
// Per-job scratch + block sizing
// ---------------------------------------------------------------------

/// Per-job scratch: the b and h column slabs, the carry column, and the
/// zero column used at chunk resets. One per pool job, reused across
/// every plane (and direction) the job owns. Leased from the workspace:
/// the slabs are fully overwritten before every read, the carry/zeros
/// columns must start zero (the reference semantics), so only those two
/// are zero-reset.
pub(crate) struct FusedScratch<'w> {
    pub(crate) b: Lease<'w>,
    pub(crate) h: Lease<'w>,
    pub(crate) carry: Lease<'w>,
    pub(crate) zeros: Lease<'w>,
}

impl<'w> FusedScratch<'w> {
    pub(crate) fn new(hmax: usize, ws: &'w BufferPool) -> FusedScratch<'w> {
        FusedScratch {
            b: ws.acquire(SLAB * hmax),
            h: ws.acquire(SLAB * hmax),
            carry: ws.acquire_zeroed(hmax),
            zeros: ws.acquire_zeroed(hmax),
        }
    }
}

/// Number of plane-blocks to submit for `nplanes` planes: about two
/// blocks per worker for load balance, never more blocks than planes.
/// This is the "one kernel launch" fix: job count scales with the pool,
/// not with N·C. Shared with `Proj::apply`'s block dispatch so the
/// blocks-per-worker policy has one source of truth.
pub(crate) fn plane_blocks(nplanes: usize, threads: usize) -> usize {
    nplanes.min((2 * threads).max(1))
}

// ---------------------------------------------------------------------
// Segment-parallel decomposition (strategy selection lives in plan.rs)
// ---------------------------------------------------------------------

/// Segment bounds over `wc` canonical columns — the same decomposition
/// formula as `scan_l2r_split`, so for equal counts the segmented
/// arithmetic (and therefore every bit) matches the reference.
pub(crate) fn segment_bounds(wc: usize, segments: usize) -> Vec<(usize, usize)> {
    let segments = segments.clamp(1, wc.max(1));
    let seg_len = wc.div_ceil(segments).max(1);
    (0..wc).step_by(seg_len).map(|lo| (lo, (lo + seg_len).min(wc))).collect()
}

/// The fused per-plane pipeline: for each direction in order, walk the
/// plane in column slabs — pack `b = lam ⊙ x`, scan, scatter with the
/// epilogue op (assign / weighted merge / merge + modulate) — so every
/// staged value is consumed while still L1-hot.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_plane(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<f32>,
    ni: usize,
    ci: usize,
    c: usize,
    hw: (usize, usize),
    os: &mut [f32],
    scratch: &mut FusedScratch<'_>,
) {
    let (h, w) = hw;
    let plane = h * w;
    let last = dirs.len() - 1;
    for (k, di) in dirs.iter().enumerate() {
        let (hc, wc) = (di.taps.h, di.taps.w);
        let base = (ni * c + ci) * plane;
        let xs = &di.x.data[base..base + plane];
        let ls = &di.lam.data[base..base + plane];
        let taps = staged[k].panels(ni, ci);
        let mut i0 = 0;
        while i0 < wc {
            let sw = SLAB.min(wc - i0);
            pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut scratch.b);
            scan_slab(
                hc,
                i0,
                sw,
                di.chunk,
                &scratch.b,
                taps,
                &scratch.zeros,
                &mut scratch.carry,
                &mut scratch.h,
            );
            drain_scatter(&scratch.h, h, w, di.d, i0, sw, hc, os, wts, k, last, gain);
            i0 += sw;
        }
    }
}

// ---------------------------------------------------------------------
// Shared phase bodies + wavefront scheduling (phase 2 as a per-plane
// continuation)
// ---------------------------------------------------------------------

/// Phase 1 of one (plane, direction, segment) piece: pack and
/// unit-stride-scan columns `[lo, hi)` from a zero incoming carry into
/// `buf` (column-major, `(hi - lo) * hc`). The one shared phase-1 body
/// — the barrier engine calls it on preallocated panel slices, the
/// wavefront engine on owned piece buffers — so the two schedules
/// cannot drift apart arithmetically.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_piece_into(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    p: usize,
    k: usize,
    lo: usize,
    hi: usize,
    buf: &mut [f32],
    ws: &BufferPool,
) {
    let (h, w) = hw;
    let plane = h * w;
    let di = &dirs[k];
    let hc = di.taps.h;
    let base = p * plane;
    let xs = &di.x.data[base..base + plane];
    let ls = &di.lam.data[base..base + plane];
    let taps = staged[k].panels(p / c, p % c);
    // The pack slab is fully overwritten per slab; the carry must start
    // zero (a piece scans from a zero incoming carry and READS the carry
    // on its first column when `lo` is off a chunk boundary), and the
    // reset column must stay zero.
    let mut b = ws.acquire(SLAB * hmax);
    let mut carry = ws.acquire_zeroed(hmax);
    let zeros = ws.acquire_zeroed(hmax);
    let mut i0 = lo;
    while i0 < hi {
        let sw = SLAB.min(hi - i0);
        pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut b);
        let o = (i0 - lo) * hc;
        scan_slab(
            hc,
            i0,
            sw,
            di.chunk,
            &b,
            taps,
            &zeros,
            &mut carry,
            &mut buf[o..o + sw * hc],
        );
        i0 += sw;
    }
}

/// [`scan_piece_into`] retaining the piece as packed bf16 words — the
/// chained engine's reduced-precision panel path. The recurrence is
/// untouched: every slab scans in f32 through the very same
/// [`scan_slab`] (the f32 carry column crosses slab boundaries exactly
/// as in f32 mode), and only the *store* into the retained panel
/// narrows, via round-to-nearest-even. `agg` receives the piece's last
/// column at full f32 precision — the publication-board aggregate, so
/// look-back folds lose nothing to the panel narrowing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_piece_into_bf16(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    p: usize,
    k: usize,
    lo: usize,
    hi: usize,
    panel: &mut [u16],
    agg: &mut [f32],
    ws: &BufferPool,
) {
    let (h, w) = hw;
    let plane = h * w;
    let di = &dirs[k];
    let hc = di.taps.h;
    let base = p * plane;
    let xs = &di.x.data[base..base + plane];
    let ls = &di.lam.data[base..base + plane];
    let taps = staged[k].panels(p / c, p % c);
    let mut b = ws.acquire(SLAB * hmax);
    // f32 staging slab the scan lands in before narrowing; fully
    // overwritten per slab.
    let mut hslab = ws.acquire(SLAB * hmax);
    let mut carry = ws.acquire_zeroed(hmax);
    let zeros = ws.acquire_zeroed(hmax);
    let mut i0 = lo;
    while i0 < hi {
        let sw = SLAB.min(hi - i0);
        pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut b);
        scan_slab(
            hc,
            i0,
            sw,
            di.chunk,
            &b,
            taps,
            &zeros,
            &mut carry,
            &mut hslab[..sw * hc],
        );
        let o = (i0 - lo) * hc;
        for (dst, &v) in panel[o..o + sw * hc].iter_mut().zip(&hslab[..sw * hc]) {
            *dst = bf16_narrow(v);
        }
        i0 += sw;
    }
    agg.copy_from_slice(&carry[..agg.len()]);
}
