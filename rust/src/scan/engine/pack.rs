//! Pack layer: tap staging and the `b = lam ⊙ x` column-slab gather.
//!
//! Everything upstream of the scan recurrence lives here — the
//! column-major re-staging of the tridiagonal taps ([`StagedTaps`],
//! full-pass or per-band), the orientation-folding gather that builds
//! each SLAB-column block of `b = lam ⊙ x` ([`pack_slab`]), and the
//! direction → source-dims mapping ([`hw_src`]). The staged panels are
//! read through [`TapView`], which carries the first staged canonical
//! column so band stagings (the `Tiled` strategy holds only one band of
//! columns at a time) index with the same *global* column numbers the
//! rest of the engine uses — an untiled staging is simply `col0 == 0`.

use crate::scan::direction::Direction;
use crate::scan::simd::{self, bf16_narrow, Precision, TapCols, TapPanels};
use crate::scan::taps::{Taps, TAP_CENTER, TAP_DOWN, TAP_UP};
use crate::util::workspace::{BufferPool, Lease};
use crate::util::ThreadPool;

/// Canonical columns staged per slab. 32 columns keep the b/h slabs
/// L1-resident up to H = 256 while amortizing the slab loop overhead;
/// measured best among {8, 16, 32} at both acceptance geometries.
/// Crate-visible so the planner's workspace-footprint model
/// ([`plan::workspace_footprint`]) sizes slab leases with the engine's
/// real constant.
pub(crate) const SLAB: usize = 32;

// ---------------------------------------------------------------------
// Taps staging: full column-major panels, shared across channel planes
// ---------------------------------------------------------------------

/// Transpose columns `lo..hi` of an `h x w` row-major plane into an
/// `(hi-lo)`-columns-of-`h` panel (`dst[(i-lo)*h + r] = src[r*w + i]`)
/// through an 8x8 tile buffer, so reads are contiguous and writes flush
/// in contiguous 8-float runs. A full staging is `lo == 0, hi == w`; the
/// tiled strategy stages one column band at a time. Pure data movement —
/// no arithmetic, so banding cannot move a bit.
fn transpose_plane_cols(src: &[f32], h: usize, w: usize, lo: usize, hi: usize, dst: &mut [f32]) {
    const T: usize = 8;
    let mut tmp = [0.0f32; T * T];
    let mut r0 = 0;
    while r0 + T <= h {
        let mut i0 = lo;
        while i0 + T <= hi {
            for r in 0..T {
                let row = &src[(r0 + r) * w + i0..(r0 + r) * w + i0 + T];
                for i in 0..T {
                    tmp[i * T + r] = row[i];
                }
            }
            for i in 0..T {
                dst[(i0 + i - lo) * h + r0..(i0 + i - lo) * h + r0 + T]
                    .copy_from_slice(&tmp[i * T..i * T + T]);
            }
            i0 += T;
        }
        while i0 < hi {
            for r in r0..r0 + T {
                dst[(i0 - lo) * h + r] = src[r * w + i0];
            }
            i0 += 1;
        }
        r0 += T;
    }
    while r0 < h {
        for i in lo..hi {
            dst[(i - lo) * h + r0] = src[r0 * w + i];
        }
        r0 += 1;
    }
}

/// Narrowing twin of [`transpose_plane_cols`]: the same 8x8 tile walk,
/// but each store rounds to bf16 through the tile buffer, so the
/// reduced-precision mode writes its staged panels directly at half
/// width — no full-width f32 staging temporary ever exists, which is
/// what actually halves the staged footprint.
fn transpose_plane_cols_bf16(
    src: &[f32],
    h: usize,
    w: usize,
    lo: usize,
    hi: usize,
    dst: &mut [u16],
) {
    const T: usize = 8;
    let mut tmp = [0.0f32; T * T];
    let mut r0 = 0;
    while r0 + T <= h {
        let mut i0 = lo;
        while i0 + T <= hi {
            for r in 0..T {
                let row = &src[(r0 + r) * w + i0..(r0 + r) * w + i0 + T];
                for i in 0..T {
                    tmp[i * T + r] = row[i];
                }
            }
            for i in 0..T {
                let col = &mut dst[(i0 + i - lo) * h + r0..(i0 + i - lo) * h + r0 + T];
                for (o, &v) in col.iter_mut().zip(&tmp[i * T..i * T + T]) {
                    *o = bf16_narrow(v);
                }
            }
            i0 += T;
        }
        while i0 < hi {
            for r in r0..r0 + T {
                dst[(i0 - lo) * h + r] = bf16_narrow(src[r * w + i0]);
            }
            i0 += 1;
        }
        r0 += T;
    }
    while r0 < h {
        for i in lo..hi {
            dst[(i - lo) * h + r0] = bf16_narrow(src[r0 * w + i]);
        }
        r0 += 1;
    }
}

/// A read handle onto staged tap panels, carrying the first staged
/// canonical column. The engine always indexes taps by *global* column
/// number; a band staging holds only columns `[col0, col0 + cols)` and
/// shifts the index down here, so untiled code (`col0 == 0`) compiles to
/// exactly the old `TapPanels::col` path.
#[derive(Clone, Copy)]
pub(crate) struct TapView<'a> {
    panels: TapPanels<'a>,
    col0: usize,
}

impl<'a> TapView<'a> {
    /// The three tap columns for global canonical column `j`.
    #[inline]
    pub(crate) fn col(self, j: usize, hc: usize) -> TapCols<'a> {
        self.panels.col(j - self.col0, hc)
    }
}

/// Taps of one direction re-staged into column-major panels, shared
/// read-only across all plane jobs. With the channel-shared weights of
/// §4.2 (`Cw == 1`) each tap plane is staged once per batch item and
/// every channel plane reuses it. A *band* staging
/// ([`StagedTaps::build_band`]) holds only canonical columns
/// `[lo, hi)` of every block — the `Tiled` strategy's per-band working
/// set — and its [`TapView`]s translate global column indexes down.
pub(crate) struct StagedTaps<'w> {
    /// Layout: per (ni*cw + ci), three `hc x (hi-lo)` column-major
    /// panels in tap order (up, center, down). Leased from the
    /// workspace; every element is written by the staging transpose
    /// before any read, so the lease is not zero-reset. At
    /// `Precision::Bf16` the panels are bf16 words packed
    /// two-per-f32-slot ([`Lease::as_u16`]) and the lease is `bf16_len`
    /// of the f32 size — half the bytes.
    data: Lease<'w>,
    cw: usize,
    /// Staged elements per tap panel: `(hi - lo) * hc`.
    plane: usize,
    /// First staged canonical column (0 for a full staging).
    col0: usize,
    prec: Precision,
}

impl<'w> StagedTaps<'w> {
    pub(crate) fn build(
        taps: &Taps,
        pool: Option<&ThreadPool>,
        ws: &'w BufferPool,
        prec: Precision,
    ) -> StagedTaps<'w> {
        StagedTaps::build_band(taps, pool, ws, prec, 0, taps.w)
    }

    /// Stage only canonical columns `[lo, hi)` of every tap block — the
    /// per-band staging of the tiled strategy. Identical bits to the
    /// corresponding columns of a full staging (the transpose only moves
    /// data), so a banded pass reads exactly the tap words an untiled
    /// pass would.
    pub(crate) fn build_band(
        taps: &Taps,
        pool: Option<&ThreadPool>,
        ws: &'w BufferPool,
        prec: Precision,
        lo: usize,
        hi: usize,
    ) -> StagedTaps<'w> {
        let (hc, wc) = (taps.h, taps.w);
        let hi = hi.min(wc);
        let lo = lo.min(hi);
        let src_plane = hc * wc;
        let plane = (hi - lo) * hc;
        let blocks = taps.n * taps.cw;
        match prec {
            Precision::F32 => {
                let mut data = ws.acquire(blocks * 3 * plane);
                let stage_block = |(b, dst): (usize, &mut [f32])| {
                    let src = &taps.t.data[b * 3 * src_plane..(b + 1) * 3 * src_plane];
                    for tap in [TAP_UP, TAP_CENTER, TAP_DOWN] {
                        transpose_plane_cols(
                            &src[tap * src_plane..(tap + 1) * src_plane],
                            hc,
                            wc,
                            lo,
                            hi,
                            &mut dst[tap * plane..(tap + 1) * plane],
                        );
                    }
                };
                match pool {
                    Some(pool) if blocks > 1 && plane >= 1 << 12 => {
                        let jobs: Vec<(usize, &mut [f32])> =
                            data.chunks_mut(3 * plane).enumerate().collect();
                        pool.map(jobs, stage_block);
                    }
                    _ => {
                        for job in data.chunks_mut(3 * plane).enumerate() {
                            stage_block(job);
                        }
                    }
                }
                StagedTaps { data, cw: taps.cw, plane, col0: lo, prec }
            }
            Precision::Bf16 => {
                let mut data = ws.acquire(simd::bf16_len(blocks * 3 * plane));
                let stage_block = |(b, dst): (usize, &mut [u16])| {
                    let src = &taps.t.data[b * 3 * src_plane..(b + 1) * 3 * src_plane];
                    for tap in [TAP_UP, TAP_CENTER, TAP_DOWN] {
                        transpose_plane_cols_bf16(
                            &src[tap * src_plane..(tap + 1) * src_plane],
                            hc,
                            wc,
                            lo,
                            hi,
                            &mut dst[tap * plane..(tap + 1) * plane],
                        );
                    }
                };
                let words = &mut data.as_u16_mut()[..blocks * 3 * plane];
                match pool {
                    Some(pool) if blocks > 1 && plane >= 1 << 12 => {
                        let jobs: Vec<(usize, &mut [u16])> =
                            words.chunks_mut(3 * plane).enumerate().collect();
                        pool.map(jobs, stage_block);
                    }
                    _ => {
                        for job in words.chunks_mut(3 * plane).enumerate() {
                            stage_block(job);
                        }
                    }
                }
                StagedTaps { data, cw: taps.cw, plane, col0: lo, prec }
            }
        }
    }

    /// The three staged panels for channel `ci` of batch item `ni`
    /// (clamped for shared mode), at the staging precision, viewed
    /// through the staging's column offset.
    #[inline]
    pub(crate) fn panels(&self, ni: usize, ci: usize) -> TapView<'_> {
        let c = if self.cw == 1 { 0 } else { ci };
        let base = (ni * self.cw + c) * 3 * self.plane;
        let panels = match self.prec {
            Precision::F32 => {
                let s = &self.data[base..base + 3 * self.plane];
                TapPanels::F32 {
                    tu: &s[TAP_UP * self.plane..(TAP_UP + 1) * self.plane],
                    tc: &s[TAP_CENTER * self.plane..(TAP_CENTER + 1) * self.plane],
                    td: &s[TAP_DOWN * self.plane..(TAP_DOWN + 1) * self.plane],
                }
            }
            Precision::Bf16 => {
                let s = &self.data.as_u16()[base..base + 3 * self.plane];
                TapPanels::Bf16 {
                    tu: &s[TAP_UP * self.plane..(TAP_UP + 1) * self.plane],
                    tc: &s[TAP_CENTER * self.plane..(TAP_CENTER + 1) * self.plane],
                    td: &s[TAP_DOWN * self.plane..(TAP_DOWN + 1) * self.plane],
                }
            }
        };
        TapView { panels, col0: self.col0 }
    }
}

// ---------------------------------------------------------------------
// Pack: gather b = lam ⊙ x column slabs with orientation folded in
// ---------------------------------------------------------------------

/// How a direction's activations are laid out: shared spatial tensors
/// (orientation folded into the gather) or per-direction canonical
/// row-major tensors (the compact unit's case — its 1x1 projections
/// already produced canonical layouts, so the gather is a straight
/// transpose).
#[derive(Clone, Copy)]
pub(crate) enum Orientation {
    Spatial,
    Canonical,
}

/// Pack canonical columns `i0..i0+sw` of `b = lam ⊙ x` into the
/// column-major slab (`b[i*hc + r]` = canonical column `i0+i`, row `r`).
/// The product is the exact `ls[p] * xs[p]` unit of the reference
/// expression, computed during the gather so `x` and `lam` are each read
/// once and no staged copy of either exists.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_slab(
    xs: &[f32],
    ls: &[f32],
    h: usize,
    w: usize,
    d: Direction,
    layout: Orientation,
    i0: usize,
    sw: usize,
    hc: usize,
    b: &mut [f32],
) {
    match (layout, d) {
        // Spatial L2R and every canonical layout: canonical (r, i) is
        // row-major (r, i) of the source with dims (hc, wc) — for
        // spatial L2R those are just (H, W), so one transposing gather
        // covers both.
        (Orientation::Canonical, _) | (Orientation::Spatial, Direction::L2R) => {
            let wr = hw_src(h, w, d).1;
            for r in 0..hc {
                let base = r * wr + i0;
                let (xr, lr) = (&xs[base..base + sw], &ls[base..base + sw]);
                for i in 0..sw {
                    b[i * hc + r] = lr[i] * xr[i];
                }
            }
        }
        (Orientation::Spatial, Direction::R2L) => {
            // canonical (r, i) = spatial (r, W-1-i).
            for r in 0..h {
                let row = r * w;
                for i in 0..sw {
                    let p = row + w - 1 - (i0 + i);
                    b[i * hc + r] = ls[p] * xs[p];
                }
            }
        }
        (Orientation::Spatial, Direction::T2B) => {
            // canonical column i0+i is spatial row i0+i: contiguous on
            // both sides.
            for i in 0..sw {
                let row = (i0 + i) * w;
                let (xr, lr) = (&xs[row..row + w], &ls[row..row + w]);
                let bc = &mut b[i * hc..i * hc + hc];
                for r in 0..hc {
                    bc[r] = lr[r] * xr[r];
                }
            }
        }
        (Orientation::Spatial, Direction::B2T) => {
            // canonical column i0+i is spatial row H-1-(i0+i).
            for i in 0..sw {
                let row = (h - 1 - (i0 + i)) * w;
                let (xr, lr) = (&xs[row..row + w], &ls[row..row + w]);
                let bc = &mut b[i * hc..i * hc + hc];
                for r in 0..hc {
                    bc[r] = lr[r] * xr[r];
                }
            }
        }
    }
}

/// Source row-major dims for a direction/layout pair: spatial tensors
/// keep (H, W); canonical tensors are stored as (hc, wc).
#[inline]
pub(crate) fn hw_src(h: usize, w: usize, d: Direction) -> (usize, usize) {
    match d {
        Direction::L2R | Direction::R2L => (h, w),
        Direction::T2B | Direction::B2T => (w, h),
    }
}
