//! Epilogue drain: scatter scanned/corrected columns back to spatial
//! planes, and the two-phase (segmented) engines built on it.
//!
//! [`drain_scatter`] is the one epilogue-op dispatch every strategy
//! shares (assign / weighted merge / merge + modulate);
//! [`drain_dir_fused`] walks a direction's zero-carry pieces computing
//! the carry correction on the fly, seeded from an explicit
//! [`CarrySource`] — `Zero` for a pass that starts at the true origin
//! of the scan axis, `External` when a tiled band (or, later, a remote
//! shard) hands in the corrected carry of everything before it. The
//! barrier and wavefront segmented engines at the bottom compose these
//! with the phase-1 bodies from `super::chunk`.

use super::carry::{correct_segment, CarrySource};
use super::chunk::{scan_piece_into, segment_bounds};
use super::pack::{StagedTaps, TapView, SLAB};
#[cfg(test)]
use super::test_hooks;
use super::{out_tensor, DirInput, Phase2};
use crate::scan::direction::Direction;
use crate::scan::simd::{self, EpOp};
use crate::tensor::Tensor;
use crate::util::workspace::{BufferPool, Lease};
use crate::util::{lock_unpoisoned, GraphBuilder, NodeId, ThreadPool};
use std::sync::Mutex;

// ---------------------------------------------------------------------
// Scatter-back epilogue: inverse orientation + merge + modulation
// ---------------------------------------------------------------------

/// Drain a scanned slab back to the spatial plane, mapping canonical
/// (r, i0+i) to its spatial position and applying the epilogue op
/// (assign, weighted merge, or merge + modulation) per element. This is
/// the step that deletes the directional intermediates, the separate
/// accumulation loop, and `output_modulation`'s clone.
///
/// The op is a [`EpOp`] value, not a closure: the T2B/B2T arms drain in
/// contiguous `w`-length runs on *both* sides and dispatch to the batch
/// lane kernels ([`simd::ep_apply`]), while the L2R/R2L arms read the
/// slab with stride `hc` and apply the same pinned per-element
/// expression ([`EpOp::apply`]) scalar — bit-identical either way (a
/// strided gather was measured not worth the complexity on the row
/// arms; the column arms are where the epilogue bytes move).
fn scatter_slab(
    hs: &[f32],
    h: usize,
    w: usize,
    d: Direction,
    i0: usize,
    sw: usize,
    hc: usize,
    out: &mut [f32],
    op: EpOp,
) {
    match d {
        Direction::L2R => {
            for r in 0..h {
                let orow = &mut out[r * w + i0..r * w + i0 + sw];
                for (i, o) in orow.iter_mut().enumerate() {
                    *o = op.apply(*o, hs[i * hc + r]);
                }
            }
        }
        Direction::R2L => {
            for r in 0..h {
                let row = r * w;
                for i in 0..sw {
                    let p = row + w - 1 - (i0 + i);
                    out[p] = op.apply(out[p], hs[i * hc + r]);
                }
            }
        }
        Direction::T2B => {
            for i in 0..sw {
                let row = (i0 + i) * w;
                let orow = &mut out[row..row + w];
                let hcol = &hs[i * hc..i * hc + hc];
                simd::ep_apply(op, orow, &hcol[..w]);
            }
        }
        Direction::B2T => {
            for i in 0..sw {
                let row = (h - 1 - (i0 + i)) * w;
                let orow = &mut out[row..row + w];
                let hcol = &hs[i * hc..i * hc + hc];
                simd::ep_apply(op, orow, &hcol[..w]);
            }
        }
    }
}
/// The one epilogue-op dispatch every drain site shares: scatter `hs`
/// back to the spatial plane with the per-element op the pass calls for
/// — assign (single direction), weighted merge accumulate, or, on the
/// last direction of a modulated pass, merge + `u ⊙ h` gain. Keeping
/// this in one place is what keeps the plane, barrier-segmented,
/// wavefront, and dirfan drains bit-identical by construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn drain_scatter(
    hs: &[f32],
    h: usize,
    w: usize,
    d: Direction,
    i0: usize,
    sw: usize,
    hc: usize,
    os: &mut [f32],
    wts: Option<&[f32; 4]>,
    k: usize,
    last: usize,
    gain: Option<f32>,
) {
    let op = match wts {
        None => EpOp::Assign,
        Some(wts) => {
            let wt = wts[k];
            match gain.filter(|_| k == last) {
                None => EpOp::Merge(wt),
                Some(g) => EpOp::MergeGain(wt, g),
            }
        }
    };
    scatter_slab(hs, h, w, d, i0, sw, hc, os, op);
}

/// Per-drain scratch: the correction ping-pong columns, the tracked
/// inter-segment carry, and the slab used to stage corrected columns
/// before they scatter. O(SLAB·max(H, W)) — the correction never needs
/// panel-sized scratch. The staging slab is leased lazily on the first
/// corrected column, so drains that never stage (DirFan's s = 1 runs,
/// zero-carry planes) pay only the three small columns. The three
/// columns are zero-reset (the zero-carry skip reads them); the staging
/// slab is fully overwritten before every read, so it is not.
pub(crate) struct DrainScratch<'w> {
    pub(crate) ws: &'w BufferPool,
    pub(crate) corr: Lease<'w>,
    pub(crate) next: Lease<'w>,
    pub(crate) carry: Lease<'w>,
    pub(crate) colb: Option<Lease<'w>>,
}

impl<'w> DrainScratch<'w> {
    pub(crate) fn new(hmax: usize, ws: &'w BufferPool) -> DrainScratch<'w> {
        DrainScratch {
            ws,
            corr: ws.acquire_zeroed(hmax),
            next: ws.acquire_zeroed(hmax),
            carry: ws.acquire_zeroed(hmax),
            colb: None,
        }
    }
}

/// The fused-correction drain for one (plane, direction): walk the
/// direction's phase-1 segment pieces in column order, computing the
/// linear carry correction *on the fly* and scattering `phase1 + corr`
/// straight through the epilogue op — the retained panel is read once
/// and written zero extra times (the two-pass reference re-touched the
/// whole corrected region in place first, then read it all again).
///
/// Bit-exactness vs the two-pass order ([`correct_segment`] +
/// [`drain_scatter`], and hence `split::phase2_plane`): the correction
/// recurrence `corr_i = w_i · corr_{i-1}` never reads panel values, so
/// fusing changes no operand of any float op — `phase1 + corr` is the
/// same f32 add whether it lands in the panel or in the drain, the
/// all-zero carry skip is identical (eliding the correction keeps even
/// -0.0 pixels bit-identical), and the carry handed to segment k+1 is
/// the same corrected last column, tracked out of band instead of
/// re-read from the panel. Chunk resets kill the correction exactly
/// where the two-pass loop `break`s (including a reset landing on the
/// segment's first column). Validated bitwise against the two-pass
/// mirror in C over ~9k randomized geometry/chunk/zero-carry cases
/// before porting, and pinned `==` by the schedule-matrix tests.
///
/// Corrected columns are staged through a [`SLAB`]-column buffer so the
/// scatter keeps the slab pipeline's write locality; columns with no
/// live correction (segment 0, a zero carry, or past a chunk reset —
/// once dead, a correction never revives within a segment) scatter
/// straight from the piece with no staging copy.
#[allow(clippy::too_many_arguments)]
pub(crate) fn drain_dir_fused(
    pieces: &[&[f32]],
    bounds: &[(usize, usize)],
    hc: usize,
    chunk: usize,
    taps: TapView<'_>,
    hw: (usize, usize),
    d: Direction,
    os: &mut [f32],
    wts: Option<&[f32; 4]>,
    k: usize,
    last: usize,
    gain: Option<f32>,
    entry: CarrySource<'_>,
    s: &mut DrainScratch<'_>,
) {
    let (h, w) = hw;
    // Entry carry: where the drain's carry chain *starts*. `Zero` is the
    // whole-row case (nothing seeded, segment 0 is already exact); any
    // other source seeds the carry column so segment 0 corrects exactly
    // like a later segment would — the seam the Tiled engine hands its
    // `External` band carries through.
    let seeded = entry.seed(&mut s.carry[..hc]);
    for (si, (&(lo, hi), piece)) in bounds.iter().zip(pieces).enumerate() {
        let seglen = hi - lo;
        // Incoming carry: the previous segment's (corrected) last
        // column. The reference decomposition skips all-zero carries;
        // matching the skip keeps even -0.0 pixels bit-identical.
        let mut active = (si > 0 || seeded) && !s.carry[..hc].iter().all(|&v| v == 0.0);
        if active {
            s.corr[..hc].copy_from_slice(&s.carry[..hc]);
        }
        let mut j = 0;
        while j < seglen {
            if !active {
                // Everything from here to the segment end is already
                // exact (zero incoming carry, or a chunk reset killed
                // the correction — it can never re-activate within a
                // segment): scatter straight from the piece, no
                // staging copy at all.
                drain_scatter(
                    &piece[j * hc..seglen * hc],
                    h,
                    w,
                    d,
                    lo + j,
                    seglen - j,
                    hc,
                    os,
                    wts,
                    k,
                    last,
                    gain,
                );
                s.carry[..hc].copy_from_slice(&piece[(seglen - 1) * hc..seglen * hc]);
                break;
            }
            let sw = SLAB.min(seglen - j);
            if s.colb.as_ref().map_or(true, |cb| cb.len() < SLAB * hc) {
                // Staging slab: every column is fully written before the
                // scatter reads it, so a plain (non-zeroed) lease.
                s.colb = Some(s.ws.acquire(SLAB * hc));
            }
            let colb = s.colb.as_mut().unwrap();
            for i in 0..sw {
                let gi = lo + j + i;
                let src = &piece[(j + i) * hc..(j + i + 1) * hc];
                if active && gi % chunk == 0 {
                    // Chunk reset: the carry dies here and phase 1 was
                    // already exact from this column on.
                    active = false;
                }
                let dst = &mut colb[i * hc..(i + 1) * hc];
                if active {
                    simd::correct_col(&s.corr[..hc], taps.col(gi, hc), &mut s.next[..hc]);
                    for ((o, &p1), &cv) in dst.iter_mut().zip(src).zip(&s.next[..hc]) {
                        *o = p1 + cv;
                    }
                    std::mem::swap(&mut s.corr, &mut s.next);
                } else {
                    dst.copy_from_slice(src);
                }
            }
            drain_scatter(&colb[..], h, w, d, lo + j, sw, hc, os, wts, k, last, gain);
            if j + sw == seglen {
                // The corrected last column *is* segment k+1's carry.
                s.carry[..hc].copy_from_slice(&colb[(sw - 1) * hc..sw * hc]);
            }
            j += sw;
        }
    }
}

/// [`drain_dir_fused`] over the wavefront engine's per-segment piece
/// slots: the body of one per-direction drain continuation. Takes the
/// direction's pieces out of their hand-off slots (the graph's
/// dependency edges ordered the accesses, so the locks are uncontended;
/// poisoned slots are recovered — see the module notes on panic
/// hygiene) and runs the fused-correction drain for direction `k` of
/// plane `p`.
#[allow(clippy::too_many_arguments)]
fn drain_dir_pieces_fused(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    bounds: &[Vec<(usize, usize)>],
    wts: Option<&[f32; 4]>,
    gain: Option<f32>,
    p: usize,
    k: usize,
    c: usize,
    hw: (usize, usize),
    slots: &[Mutex<Option<Lease<'_>>>],
    os: &mut [f32],
    scratch: &mut DrainScratch<'_>,
) {
    let di = &dirs[k];
    let hc = di.taps.h;
    let taps = staged[k].panels(p / c, p % c);
    // Taking the leases out of the slots moves ownership here: they
    // return to the workspace pool when `bufs` drops, on every exit
    // path — including the early return below.
    let bufs: Vec<Option<Lease<'_>>> =
        slots.iter().map(|s| lock_unpoisoned(s).take()).collect();
    // A missing or wrong-size piece means its phase-1 job panicked
    // before handing the panel over; `run_graph` already holds that
    // payload — skip quietly so the caller reports the real panic, not
    // a confusing secondary index/Poison error.
    if bufs
        .iter()
        .zip(&bounds[k])
        .any(|(b, &(lo, hi))| b.as_ref().map_or(true, |b| b.len() != (hi - lo) * hc))
    {
        return;
    }
    let pieces: Vec<&[f32]> = bufs.iter().map(|b| b.as_deref().unwrap()).collect();
    drain_dir_fused(
        &pieces,
        &bounds[k],
        hc,
        di.chunk,
        taps,
        hw,
        di.d,
        os,
        wts,
        k,
        dirs.len() - 1,
        gain,
        CarrySource::Zero,
        scratch,
    );
}

/// Phase 2 of one plane off per-segment panel pieces, in the retired
/// PR 4 *two-pass* form: chain the true carry across segment boundaries
/// (the corrected last column of segment k *is* segment k+1's carry),
/// add the linear correction scan **in place** (a full read-modify-write
/// of every corrected panel column), then drain each corrected segment
/// through the fused scatter epilogue in the same k = 0..dirs order as
/// the plane path. Kept as the bit/bench reference the fused-correction
/// drain ([`drain_dir_fused`]) is pinned `==` against and measured
/// over (every element sees the same values in the same order, so the
/// bits match).
#[allow(clippy::too_many_arguments)]
fn correct_and_drain_pieces(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    bounds: &[Vec<(usize, usize)>],
    wts: Option<&[f32; 4]>,
    gain: Option<f32>,
    p: usize,
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    slots: &[Mutex<Option<Lease<'_>>>],
    os: &mut [f32],
    ws: &BufferPool,
) {
    let (h, w) = hw;
    let last = dirs.len() - 1;
    // Zero-reset: the zero-carry skip below reads `carry` before any
    // write, and the correction columns keep fresh-`vec!` semantics.
    let mut corr = ws.acquire_zeroed(hmax);
    let mut next = ws.acquire_zeroed(hmax);
    let mut carry = ws.acquire_zeroed(hmax);
    let mut slot = 0usize;
    for (k, di) in dirs.iter().enumerate() {
        let hc = di.taps.h;
        let taps = staged[k].panels(p / c, p % c);
        for (si, &(lo, hi)) in bounds[k].iter().enumerate() {
            // Taking the lease moves ownership here; it returns to the
            // pool when `buf` drops, even on the early return below.
            let taken = lock_unpoisoned(&slots[slot]).take();
            slot += 1;
            // A missing or wrong-size piece means its phase-1 job
            // panicked before handing the panel over; `run_graph`
            // already holds that payload — bail quietly so the caller
            // reports the real panic, not a secondary index/Poison
            // error.
            let Some(mut buf) = taken else { return };
            if buf.len() != (hi - lo) * hc {
                return;
            }
            // Incoming carry: the previous segment's (corrected) last
            // column. The reference decomposition skips all-zero
            // carries; matching the skip keeps even -0.0 pixels
            // bit-identical.
            if si > 0 && !carry[..hc].iter().all(|&v| v == 0.0) {
                correct_segment(
                    hc, di.chunk, lo, hi, taps, &carry, &mut corr, &mut next, &mut buf,
                );
            }
            carry[..hc].copy_from_slice(&buf[(hi - lo - 1) * hc..(hi - lo) * hc]);
            drain_scatter(&buf, h, w, di.d, lo, hi - lo, hc, os, wts, k, last, gain);
        }
    }
}

/// The segment-parallel engine (the fused §5.1 decomposition).
///
/// Phase 1 fans one job per (plane, direction, segment) — each packs and
/// unit-stride-scans its column range from a zero incoming carry with
/// the very same slab pipeline as the plane path, but retains the
/// canonical columns in a per-plane panel instead of scattering them
/// (chunk resets still fire on global column indices inside
/// [`scan_slab`]). Phase 2 fans one job per plane: for each direction it
/// chains the true carry across segment boundaries — the corrected last
/// column of segment k *is* segment k+1's carry — with the linear
/// correction scan (`correct_col` in [`super::simd`]) computed **on the fly inside the
/// scatter drain** ([`drain_dir_fused`]): the retained panel is read
/// once and never re-written, and the corrected values flow straight
/// through the fused scatter epilogue (inverse orientation + weighted
/// merge + modulation), so the directional output, merge, and
/// modulation intermediates still never exist — and neither does a
/// corrected copy of the panel.
///
/// Arithmetic per element is exactly `scan_l2r_split`'s two-phase order
/// (pinned `==` by tests); only the memory layout and the epilogue
/// fusion differ. The retained panels cost
/// O(nplanes · Σ_dirs hc·wc) floats — bounded in practice because the
/// planner only picks this path when `nplanes < threads`.
///
/// `phase2` selects the schedule: the two-`map` barrier below, or one
/// of the dependency-graph schedules of
/// [`run_engine_segmented_wave`] — same jobs, same bits, no global
/// rendezvous between phases.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine_segmented(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    segments: usize,
    phase2: Phase2,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
) -> Tensor {
    if phase2 != Phase2::Barrier {
        if let Some(pool) = pool {
            return run_engine_segmented_wave(
                dirs,
                staged,
                wts,
                gain,
                out_shape,
                pool,
                segments,
                phase2 == Phase2::WaveDir,
                ws,
                out_buf,
            );
        }
    }
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = out_shape[0] * c;
    let hmax = h.max(w);
    let bounds: Vec<Vec<(usize, usize)>> =
        dirs.iter().map(|di| segment_bounds(di.taps.w, segments)).collect();

    // Retained phase-1 canonical columns: per plane, the directions'
    // hc x wc column-major panels concatenated in direction order.
    let dir_off: Vec<usize> = dirs
        .iter()
        .scan(0usize, |acc, di| {
            let o = *acc;
            *acc += di.taps.h * di.taps.w;
            Some(o)
        })
        .collect();
    let per_plane: usize = dirs.iter().map(|di| di.taps.h * di.taps.w).sum();
    // Zero-reset like the fresh `vec!` it replaces: phase 1 overwrites
    // every panel element, but keeping the fresh-allocation semantics
    // makes the panels' contents independent of pool history by
    // construction (bit-exactness needs no full-coverage argument).
    let mut hbufs = ws.acquire_zeroed(nplanes * per_plane);

    // Phase 1: every (plane, direction, segment) scans independently
    // from a zero carry into its disjoint panel range.
    {
        let mut jobs: Vec<(usize, usize, usize, usize, &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = &mut hbufs;
        for p in 0..nplanes {
            for (k, di) in dirs.iter().enumerate() {
                for &(lo, hi) in &bounds[k] {
                    let (buf, tail) =
                        std::mem::take(&mut rest).split_at_mut((hi - lo) * di.taps.h);
                    rest = tail;
                    jobs.push((p, k, lo, hi, buf));
                }
            }
        }
        let scan_piece = |(p, k, lo, hi, buf): (usize, usize, usize, usize, &mut [f32])| {
            scan_piece_into(dirs, staged, c, (h, w), hmax, p, k, lo, hi, buf, ws);
        };
        match pool {
            Some(pool) if pool.threads() > 1 && jobs.len() > 1 => {
                pool.map(jobs, scan_piece);
            }
            _ => jobs.into_iter().for_each(scan_piece),
        }
    }

    // Phase 2: per plane, drain each direction's retained panel through
    // the fused correction + scatter epilogue in the same k = 0..dirs
    // order as the plane path. The panel is read-only from here on —
    // the correction never lands back in it.
    let mut out = out_tensor(out_shape, out_buf);
    let gain_for = |ci: usize| gain.map(|g| g[ci]);
    let last = dirs.len() - 1;
    let planes: Vec<(usize, &mut [f32], &[f32])> = out
        .data
        .chunks_mut(plane)
        .zip(hbufs.chunks(per_plane))
        .enumerate()
        .map(|(p, (os, pb))| (p, os, pb))
        .collect();
    let correct_and_drain = |(p, os, pb): (usize, &mut [f32], &[f32])| {
        let mut scratch = DrainScratch::new(hmax, ws);
        for (k, di) in dirs.iter().enumerate() {
            let (hc, wc) = (di.taps.h, di.taps.w);
            let taps = staged[k].panels(p / c, p % c);
            let panel = &pb[dir_off[k]..dir_off[k] + hc * wc];
            let pieces: Vec<&[f32]> =
                bounds[k].iter().map(|&(lo, hi)| &panel[lo * hc..hi * hc]).collect();
            drain_dir_fused(
                &pieces,
                &bounds[k],
                hc,
                di.chunk,
                taps,
                (h, w),
                di.d,
                os,
                wts,
                k,
                last,
                gain_for(p % c),
                CarrySource::Zero,
                &mut scratch,
            );
        }
    };
    match pool {
        Some(pool) if pool.threads() > 1 && planes.len() > 1 => {
            pool.map(planes, correct_and_drain);
        }
        _ => planes.into_iter().for_each(correct_and_drain),
    }
    out
}

/// The wavefront-scheduled segmented engine: the same (plane,
/// direction, segment) phase-1 jobs as the barrier engine, submitted as
/// a dependency graph ([`ThreadPool::run_graph`]) so no global
/// rendezvous exists anywhere in the pass. Two continuation shapes:
///
/// * `per_dir = true` (production): **one drain continuation per
///   (plane, direction)** — 4 per plane on a merged pass — running the
///   fused-correction drain ([`drain_dir_pieces_fused`]). Direction k's
///   drain depends on its *own* phase-1 pieces plus the same plane's
///   direction-(k-1) drain (the chain preserves the k = 0..4 merge
///   accumulation order on the shared output plane), so it overlaps
///   both other planes' phase 1 and the same plane's later directions'
///   scans.
/// * `per_dir = false`: the PR 4 schedule — one continuation per plane
///   over all directions, running the two-pass correct-then-drain
///   ([`correct_and_drain_pieces`]). Kept as the bit/bench reference
///   for the fused drain.
///
/// Phase-1 pieces hand their panels to the continuations through
/// per-(plane, direction, segment) slots, and the per-direction drains
/// share their output plane through a per-plane slot; the graph's
/// dependency edges are what order the accesses, so the locks are
/// uncontended (and recovered if poisoned — a panicking job must
/// surface as the collected graph payload, not a `PoisonError`).
/// Arithmetic is untouched — output is exact `==` with the barrier
/// engine (and hence `scan_l2r_split`), pinned by tests.
#[allow(clippy::too_many_arguments)]
fn run_engine_segmented_wave(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: &ThreadPool,
    segments: usize,
    per_dir: bool,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
) -> Tensor {
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = out_shape[0] * c;
    let hmax = h.max(w);
    let bounds: Vec<Vec<(usize, usize)>> =
        dirs.iter().map(|di| segment_bounds(di.taps.w, segments)).collect();
    let per_plane_slots: usize = bounds.iter().map(|b| b.len()).sum();
    // Piece hand-off slots hold *leased* panels: whatever is still in a
    // slot when this vec drops (e.g. drains skipped after a phase-1
    // panic) returns to the workspace pool instead of leaking.
    let slots: Vec<Mutex<Option<Lease<'_>>>> =
        (0..nplanes * per_plane_slots).map(|_| Mutex::new(None)).collect();

    let mut out = out_tensor(out_shape, out_buf);
    let conts = if per_dir { dirs.len() } else { 1 };
    let mut graph = GraphBuilder::with_capacity(nplanes * (per_plane_slots + conts));
    let bounds_ref = &bounds;
    let slots_ref = &slots;
    // One phase-1 piece node per (plane, direction, segment), identical
    // under both continuation shapes (the schedules cannot drift apart
    // in what phase 1 computes).
    macro_rules! submit_pieces {
        ($ids:ident, $p:expr, $k:expr, $slot:ident) => {
            for &(lo, hi) in &bounds_ref[$k] {
                let dst = &slots_ref[$slot];
                $slot += 1;
                let (p, k) = ($p, $k);
                let hc = dirs[k].taps.h;
                $ids.push(graph.submit(move || {
                    // Lease before the (test-only) fault hook so an
                    // injected panic unwinds while scratch is out on
                    // lease — the leak test covers the window that
                    // matters. Zeroed like the fresh `vec!` it replaces.
                    let mut buf = ws.acquire_zeroed((hi - lo) * hc);
                    #[cfg(test)]
                    test_hooks::maybe_panic(p, k, lo, hi);
                    scan_piece_into(dirs, staged, c, (h, w), hmax, p, k, lo, hi, &mut buf, ws);
                    *lock_unpoisoned(dst) = Some(buf);
                }));
            }
        };
    }
    if per_dir {
        // Per-plane output + scratch hand-off slots: the per-direction
        // drain chain of a plane shares its output plane and one drain
        // scratch through a single slot, ordered by the drain-(k-1) →
        // drain-k graph edges (one scratch allocation per plane, as in
        // the barrier path).
        let os_slots: Vec<Mutex<(&mut [f32], DrainScratch<'_>)>> = out
            .data
            .chunks_mut(plane)
            .map(|os| Mutex::new((os, DrainScratch::new(hmax, ws))))
            .collect();
        for (p, os_slot) in os_slots.iter().enumerate() {
            let gv = gain.map(|g| g[p % c]);
            let mut prev_drain: Option<NodeId> = None;
            let mut slot = p * per_plane_slots;
            for (k, _) in dirs.iter().enumerate() {
                let mut deps = Vec::with_capacity(bounds[k].len() + 1);
                let dir_slot0 = slot;
                submit_pieces!(deps, p, k, slot);
                if let Some(prev) = prev_drain {
                    deps.push(prev);
                }
                let dir_slots = &slots_ref[dir_slot0..slot];
                prev_drain = Some(graph.submit_after(&deps, move || {
                    let mut guard = lock_unpoisoned(os_slot);
                    let (os, scratch) = &mut *guard;
                    drain_dir_pieces_fused(
                        dirs, staged, bounds_ref, wts, gv, p, k, c, (h, w), dir_slots,
                        os, scratch,
                    );
                }));
            }
        }
        if let Err(e) = pool.run_graph(graph) {
            std::panic::resume_unwind(e.into_payload());
        }
    } else {
        for (p, os) in out.data.chunks_mut(plane).enumerate() {
            let mut piece_ids = Vec::with_capacity(per_plane_slots);
            let mut slot = p * per_plane_slots;
            for (k, _) in dirs.iter().enumerate() {
                submit_pieces!(piece_ids, p, k, slot);
            }
            let plane_slots = &slots_ref[p * per_plane_slots..(p + 1) * per_plane_slots];
            let gv = gain.map(|g| g[p % c]);
            graph.submit_after(&piece_ids, move || {
                correct_and_drain_pieces(
                    dirs,
                    staged,
                    bounds_ref,
                    wts,
                    gv,
                    p,
                    c,
                    (h, w),
                    hmax,
                    plane_slots,
                    os,
                    ws,
                );
            });
        }
        if let Err(e) = pool.run_graph(graph) {
            std::panic::resume_unwind(e.into_payload());
        }
    }
    out
}
