use super::*;
use crate::scan::core::{scan_l2r, scan_l2r_pool};
use crate::scan::direction::{merged_4dir_ref, scan_dir};
use crate::scan::plan::TileInner;
use crate::util::lock_unpoisoned;
use crate::util::proptest::{check, ensure};
use crate::util::Rng;

fn divisors(w: usize) -> Vec<usize> {
    (1..=w).filter(|d| w % d == 0).collect()
}

fn mk_taps(rng: &mut Rng, n: usize, cw: usize, h: usize, w: usize) -> Taps {
    Taps::normalize(&Tensor::randn(&[n, cw, 3, h, w], rng, 1.0))
}

/// The tentpole pinning property: the fused engine is exactly equal
/// (`==` on `data`, not allclose) to the serial reference across
/// random shapes, every kchunk divisor, shared and per-channel taps,
/// and all four directions — including H=1 and W=1 edge geometries.
#[test]
fn fused_scan_pinned_bit_exact_to_reference() {
    check("fused == scan_dir reference", |g| {
        let n = g.int_in(1, 2);
        let c = g.int_in(1, 3);
        let h = g.int_in(1, 7);
        let w = g.int_in(1, 7);
        let cw = *g.pick(&[1, c]);
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, cw, hc, wc);
            let mut kchunks = vec![0usize];
            kchunks.extend(divisors(wc));
            for k in kchunks {
                let reference = scan_dir(&x, &taps, &lam, d, k);
                let fused = fused_scan_dir(&x, &taps, &lam, d, k);
                ensure(
                    reference.shape == fused.shape && reference.data == fused.data,
                    format!("fused != ref: n{n} c{c} {h}x{w} cw{cw} {d:?} k{k}"),
                )?;
            }
        }
        Ok(())
    });
}

/// Slab-boundary coverage: widths around multiples of SLAB, so the
/// carry column crossing and the partial last slab are both hit,
/// including kchunk resets landing inside and on slab boundaries.
#[test]
fn fused_scan_exact_across_slab_boundaries() {
    let mut rng = Rng::new(39);
    for w in [SLAB - 1, SLAB, SLAB + 1, 2 * SLAB, 2 * SLAB + 3] {
        let (n, c, h) = (1, 2, 5);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let mut kchunks = vec![0usize];
        kchunks.extend(divisors(w));
        for k in kchunks {
            let reference = scan_l2r(&x, &taps, &lam, k);
            let fused = fused_scan_l2r(&x, &taps, &lam, k);
            assert_eq!(reference.data, fused.data, "w={w} k={k}");
        }
    }
}

#[test]
fn fused_merged_pinned_bit_exact_to_reference() {
    check("fused merged == merged_4dir_ref", |g| {
        let n = g.int_in(1, 2);
        let c = g.int_in(1, 3);
        let h = g.int_in(1, 6);
        let w = g.int_in(1, 6);
        let cw = *g.pick(&[1, c]);
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, cw, h, w);
        let t_rl = mk_taps(&mut rng, n, cw, h, w);
        let t_tb = mk_taps(&mut rng, n, cw, w, h);
        let t_bt = mk_taps(&mut rng, n, cw, w, h);
        let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [
            g.f32_in(-2.0, 2.0),
            g.f32_in(-2.0, 2.0),
            g.f32_in(-2.0, 2.0),
            g.f32_in(-2.0, 2.0),
        ];
        // kchunk must divide the canonical width of every direction.
        let mut kchunks = vec![0usize];
        kchunks.extend(divisors(w).into_iter().filter(|k| h % k == 0));
        for k in kchunks {
            let reference = merged_4dir_ref(&x, taps, &lam, &logits, k);
            let fused = fused_merged_4dir(&x, taps, &lam, &logits, k);
            ensure(
                reference.data == fused.data,
                format!("fused merged != ref: n{n} c{c} {h}x{w} cw{cw} k{k}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn fused_pool_bit_identical_to_fused_serial_and_reference() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(40);
    for (n, c, h, w, cw) in
        [(2, 3, 8, 12, 3), (1, 1, 5, 7, 1), (3, 4, 16, 16, 1), (1, 2, 1, 6, 1), (1, 2, 6, 1, 2)]
    {
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, cw, h, w);
        for kchunk in [0, w] {
            let reference = scan_l2r(&x, &taps, &lam, kchunk);
            let serial = fused_scan_l2r(&x, &taps, &lam, kchunk);
            let pooled = fused_scan_l2r_pool(&x, &taps, &lam, kchunk, &pool);
            assert_eq!(reference.data, serial.data, "serial n{n} c{c} {h}x{w} k{kchunk}");
            assert_eq!(reference.data, pooled.data, "pooled n{n} c{c} {h}x{w} k{kchunk}");
        }
    }
}

#[test]
fn fused_merged_pool_bit_identical_to_reference() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(41);
    let (n, c, h, w) = (2, 3, 6, 7);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let taps = [&t_lr, &t_lr, &t_tb, &t_tb];
    let logits = [0.4f32, -0.2, 1.1, 0.0];
    let reference = merged_4dir_ref(&x, taps, &lam, &logits, 0);
    let pooled = fused_merged_4dir_pool(&x, taps, &lam, &logits, 0, &pool);
    let global = fused_merged_4dir_par(&x, taps, &lam, &logits, 0);
    assert_eq!(reference.data, pooled.data);
    assert_eq!(reference.data, global.data);
}

#[test]
fn fused_canonical_merge_modulate_matches_reference_composition() {
    // The compact-unit path: canonical per-direction activations,
    // fused merge + u ⊙ h modulation vs the explicit reference
    // composition (scan_l2r_pool + from_canonical + merge pass +
    // output_modulation).
    use crate::scan::direction::{from_canonical, to_canonical};
    let pool = crate::util::ThreadPool::new(2);
    let mut rng = Rng::new(42);
    let (n, c, h, w) = (2, 3, 5, 6);
    let xp = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let logits = [0.3f32, -0.7, 0.2, 1.0];
    let u: Vec<f32> = (0..c).map(|i| 0.5 + i as f32).collect();
    let mut xcs = Vec::new();
    let mut taps = Vec::new();
    let mut lamcs = Vec::new();
    for d in DIRECTIONS {
        let xc = to_canonical(&xp, d);
        let (hc, wc) = (xc.shape[2], xc.shape[3]);
        taps.push(mk_taps(&mut rng, n, 1, hc, wc));
        lamcs.push(Tensor::randn(&xc.shape, &mut rng, 1.0));
        xcs.push(xc);
    }
    let fused = fused_merged_canonical(
        [&xcs[0], &xcs[1], &xcs[2], &xcs[3]],
        [&taps[0], &taps[1], &taps[2], &taps[3]],
        [&lamcs[0], &lamcs[1], &lamcs[2], &lamcs[3]],
        &logits,
        &u,
        0,
        &xp.shape,
        &pool,
    );
    let wts = merge_weights(&logits);
    let mut merged = Tensor::zeros(&xp.shape);
    for (k, d) in DIRECTIONS.iter().enumerate() {
        let hcan = scan_l2r_pool(&xcs[k], &taps[k], &lamcs[k], 0, &pool);
        let y = from_canonical(&hcan, *d);
        for (o, v) in merged.data.iter_mut().zip(&y.data) {
            *o += wts[k] * v;
        }
    }
    let reference = crate::scan::core::output_modulation_owned(merged, &u);
    assert_eq!(reference.data, fused.data);
}

#[test]
fn fused_empty_and_degenerate_geometries() {
    // N·C = 0 and H = 0 return zeros without panicking, as the
    // reference does.
    let x = Tensor::zeros(&[0, 3, 4, 5]);
    let lam = Tensor::zeros(&[0, 3, 4, 5]);
    let taps = Taps::normalize(&Tensor::zeros(&[0, 1, 3, 4, 5]));
    let out = fused_scan_l2r(&x, &taps, &lam, 0);
    assert_eq!(out.shape, vec![0, 3, 4, 5]);

    let x = Tensor::zeros(&[1, 2, 0, 5]);
    let lam = Tensor::zeros(&[1, 2, 0, 5]);
    let taps = Taps::normalize(&Tensor::zeros(&[1, 1, 3, 0, 5]));
    let out = fused_scan_l2r(&x, &taps, &lam, 0);
    assert!(out.data.is_empty());
}

#[test]
fn block_count_scales_with_pool_not_planes() {
    assert_eq!(plane_blocks(1000, 4), 8);
    assert_eq!(plane_blocks(3, 4), 3);
    assert_eq!(plane_blocks(0, 4), 0);
    assert_eq!(plane_blocks(16, 1), 2);
}

// -----------------------------------------------------------------
// Segment-parallel decomposition
// -----------------------------------------------------------------

use crate::scan::split::scan_l2r_split;

/// The tentpole pinning property for the segmented path: exact `==`
/// with the reference decomposition `scan_l2r_split` across segment
/// counts and boundaries — including W = 1, more segments than
/// columns, and a 1-thread pool (helping-wait execution).
#[test]
fn segmented_fused_exact_eq_scan_l2r_split() {
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(50);
    for (n, c, h, w, cw) in [
        (1, 1, 5, 12, 1),
        (1, 2, 3, 64, 2),
        (2, 3, 8, 40, 1),
        (1, 1, 1, 7, 1),
        (1, 2, 9, 1, 1),
        (1, 1, 4, 2 * SLAB + 3, 1),
    ] {
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, cw, h, w);
        for segments in [1usize, 2, 3, 5, 8, w, w + 9, 500] {
            let reference = scan_l2r_split(&x, &taps, &lam, segments, 1);
            let seg1 = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool1);
            let seg3 = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool3);
            assert_eq!(
                reference.data, seg1.data,
                "1-thread n{n} c{c} {h}x{w} cw{cw} S{segments}"
            );
            assert_eq!(
                reference.data, seg3.data,
                "3-thread n{n} c{c} {h}x{w} cw{cw} S{segments}"
            );
        }
    }
}

#[test]
fn segmented_fused_split_identity_property() {
    let pool = crate::util::ThreadPool::new(2);
    check("fused segmented == scan_l2r_split", |g| {
        let n = g.int_in(1, 2);
        let c = g.int_in(1, 3);
        let h = g.int_in(1, 9);
        let w = g.int_in(1, 40);
        let segments = g.int_in(1, 7);
        let cw = *g.pick(&[1, c]);
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, cw, h, w);
        let reference = scan_l2r_split(&x, &taps, &lam, segments, 1);
        let seg = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool);
        ensure(
            reference.data == seg.data,
            format!("segmented != split: n{n} c{c} {h}x{w} cw{cw} S{segments}"),
        )
    });
}

/// Segment boundaries landing on chunk resets carry nothing across,
/// so the segmented path collapses to the exact plane-path bits.
#[test]
fn segmented_chunk_aligned_is_exact_vs_reference() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(51);
    let (n, c, h, w) = (1, 2, 6, 64);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    // S = 4 -> seg_len = 16; kchunk = 8 divides 16, so every segment
    // starts on a reset.
    let reference = scan_l2r(&x, &taps, &lam, 8);
    let seg = fused_scan_l2r_seg(&x, &taps, &lam, 8, 4, &pool);
    assert_eq!(reference.data, seg.data);
}

/// Unaligned chunk resets inside segments stay numerically
/// equivalent (the carry dies at the reset; only pre-reset columns
/// reassociate).
#[test]
fn segmented_chunk_unaligned_is_close() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(52);
    let (n, c, h, w) = (1, 1, 7, 96);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let reference = scan_l2r(&x, &taps, &lam, 32);
    // S = 5 -> seg_len = 20: boundaries at 20/40/60/80 never align
    // with the resets at 32/64.
    let seg = fused_scan_l2r_seg(&x, &taps, &lam, 32, 5, &pool);
    assert!(
        reference.allclose(&seg, 1e-4, 1e-4),
        "max diff {}",
        reference.max_abs_diff(&seg)
    );
}

/// The merged 4-direction segmented pass: tolerance-pinned against
/// the serial reference composition, and bit-deterministic across
/// pool widths (scheduling never changes segmented arithmetic).
#[test]
fn segmented_merged_close_to_reference_and_deterministic() {
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(53);
    let (n, c, h, w) = (1, 2, 24, 40);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_rl = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let t_bt = mk_taps(&mut rng, n, 1, w, h);
    let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
    let logits = [0.4f32, -0.2, 1.1, 0.0];
    let reference = merged_4dir_ref(&x, taps, &lam, &logits, 0);
    let a = fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, 4, &pool1);
    let b = fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, 4, &pool3);
    assert_eq!(a.data, b.data, "pool width changed segmented bits");
    assert!(
        reference.allclose(&a, 1e-4, 1e-4),
        "max diff {}",
        reference.max_abs_diff(&a)
    );
}

/// Whenever the planner picks plane-parallel, the pooled entry
/// points are exactly the PR 2 engine — bit-identical to the serial
/// reference. Any geometry narrower than 2 * plan::MIN_SEG_COLS
/// canonical columns (everything the unit/e2e suites pin) can never
/// be segmented regardless of host pool width.
#[test]
fn auto_plane_regime_stays_bit_identical() {
    let pool = crate::util::ThreadPool::new(7);
    let mut rng = Rng::new(54);
    let (n, c, h, w) = (1, 2, 32, 64);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    assert_eq!(plan::auto_segments(n * c, w, pool.threads()), None);
    let reference = scan_l2r(&x, &taps, &lam, 0);
    let pooled = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
    assert_eq!(reference.data, pooled.data);
}

/// When the planner does segment, the pooled entry point produces
/// exactly the scan_l2r_split bits for the count it chose.
#[test]
fn auto_low_occupancy_matches_split_reference() {
    let pool = crate::util::ThreadPool::new(4);
    let mut rng = Rng::new(55);
    let (n, c, h, w) = (1, 1, 8, 256);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let s = plan::auto_segments(n * c, w, pool.threads())
        .expect("low occupancy must segment");
    assert_eq!(s, 4);
    let viapool = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
    let reference = scan_l2r_split(&x, &taps, &lam, s, 1);
    assert_eq!(reference.data, viapool.data);
}

/// The single-direction serving band the fused-correction drain
/// opened (128 <= wc < 256, previously fenced onto the plane path):
/// the planner now segments it, and the pooled entry point produces
/// exactly the scan_l2r_split bits at the planned count.
#[test]
fn auto_midwidth_band_segments_and_matches_split() {
    let pool = crate::util::ThreadPool::new(4);
    let mut rng = Rng::new(57);
    let (n, c, h, w) = (1, 1, 8, 192);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let s = plan::auto_segments(n * c, w, pool.threads())
        .expect("the 128..256 band must segment now");
    assert_eq!(s, 3);
    let viapool = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
    let reference = scan_l2r_split(&x, &taps, &lam, s, 1);
    assert_eq!(reference.data, viapool.data);
}

/// Orientation folding in the segmented path, pinned exactly: the
/// segmented directional scan equals `scan_l2r_split` run on the
/// canonically reoriented tensors (data movement changes no bits).
#[test]
fn segmented_all_directions_match_canonical_split() {
    use crate::scan::direction::{from_canonical, to_canonical};
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(56);
    let (n, c, h, w) = (1, 2, 6, 9);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    for d in DIRECTIONS {
        let (hc, wc) = hw_src(h, w, d);
        let taps = mk_taps(&mut rng, n, 1, hc, wc);
        let xc = to_canonical(&x, d);
        let lamc = to_canonical(&lam, d);
        for segments in [2usize, 3] {
            let want =
                from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
            let got = fused_scan_dir_seg(&x, &taps, &lam, d, 0, segments, &pool);
            assert_eq!(want.data, got.data, "{d:?} S{segments}");
        }
    }
}

#[test]
fn segmented_empty_and_degenerate_geometries() {
    let pool = crate::util::ThreadPool::new(2);
    let x = Tensor::zeros(&[0, 3, 4, 5]);
    let lam = Tensor::zeros(&[0, 3, 4, 5]);
    let taps = Taps::normalize(&Tensor::zeros(&[0, 1, 3, 4, 5]));
    let out = fused_scan_l2r_seg(&x, &taps, &lam, 0, 3, &pool);
    assert_eq!(out.shape, vec![0, 3, 4, 5]);

    let x = Tensor::zeros(&[1, 2, 0, 5]);
    let lam = Tensor::zeros(&[1, 2, 0, 5]);
    let taps = Taps::normalize(&Tensor::zeros(&[1, 1, 3, 0, 5]));
    let out = fused_scan_l2r_seg(&x, &taps, &lam, 0, 3, &pool);
    assert!(out.data.is_empty());
}

// -----------------------------------------------------------------
// Wavefront scheduling + the direction fan
// -----------------------------------------------------------------

/// The tentpole pinning property for wavefront scheduling and the
/// fused-correction drain: neither the dependency-graph schedule nor
/// fusing the correction into the drain changes what is computed —
/// exact `==` across the full schedule matrix (barrier,
/// per-direction wavefront, PR 4 two-pass single-continuation) with
/// the `scan_l2r_split` reference, across segment counts, chunk
/// resets, pool widths (including the 1-thread all-helping case),
/// and slab-boundary widths.
#[test]
fn wavefront_exact_eq_barrier_and_split() {
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(60);
    for (n, c, h, w, cw) in [
        (1, 1, 5, 12, 1),
        (2, 3, 8, 40, 1),
        (1, 2, 9, 1, 1),
        (1, 1, 4, 2 * SLAB + 3, 1),
        (2, 2, 6, 96, 2),
    ] {
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, cw, h, w);
        for segments in [1usize, 2, 3, 5, w + 9] {
            let reference = scan_l2r_split(&x, &taps, &lam, segments, 1);
            let barrier = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool3);
            let wave1 = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, segments, &pool1);
            let wave3 = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, segments, &pool3);
            let twopass =
                fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, 0, segments, &pool3);
            assert_eq!(
                reference.data, barrier.data,
                "barrier n{n} c{c} {h}x{w} S{segments}"
            );
            assert_eq!(
                reference.data, wave1.data,
                "wave 1-thread n{n} c{c} {h}x{w} S{segments}"
            );
            assert_eq!(
                reference.data, wave3.data,
                "wave 3-thread n{n} c{c} {h}x{w} S{segments}"
            );
            assert_eq!(
                reference.data, twopass.data,
                "PR4 two-pass n{n} c{c} {h}x{w} S{segments}"
            );
        }
    }
}

/// Wavefront with chunk resets landing inside segments: the carry
/// dies at resets exactly like the barrier path.
#[test]
fn wavefront_chunked_matches_barrier_bits() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(61);
    let (n, c, h, w) = (1, 2, 7, 96);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    for (kchunk, segments) in [(32usize, 5usize), (8, 4), (96, 3)] {
        let barrier = fused_scan_l2r_seg(&x, &taps, &lam, kchunk, segments, &pool);
        let wave = fused_scan_l2r_seg_wave(&x, &taps, &lam, kchunk, segments, &pool);
        let twopass =
            fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, kchunk, segments, &pool);
        assert_eq!(barrier.data, wave.data, "k{kchunk} S{segments}");
        assert_eq!(barrier.data, twopass.data, "two-pass k{kchunk} S{segments}");
    }
}

/// The merged 4-direction pass under wavefront scheduling: exact
/// `==` with the barrier twin for every direction/orientation mix.
#[test]
fn wavefront_merged_exact_eq_barrier() {
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(62);
    let (n, c, h, w) = (1, 2, 24, 40);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_rl = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let t_bt = mk_taps(&mut rng, n, 1, w, h);
    let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
    let logits = [0.4f32, -0.2, 1.1, 0.0];
    for segments in [1usize, 4] {
        let barrier = fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, segments, &pool3);
        let wave1 = fused_merged_4dir_seg_wave(&x, taps, &lam, &logits, 0, segments, &pool1);
        let wave3 = fused_merged_4dir_seg_wave(&x, taps, &lam, &logits, 0, segments, &pool3);
        let twopass =
            fused_merged_4dir_seg_wave_twopass(&x, taps, &lam, &logits, 0, segments, &pool3);
        assert_eq!(barrier.data, wave1.data, "S{segments}");
        assert_eq!(barrier.data, wave3.data, "S{segments}");
        assert_eq!(barrier.data, twopass.data, "two-pass S{segments}");
    }
}

/// Directional scans under wavefront scheduling match the canonical
/// split reference exactly, per direction (orientation folding does
/// not interact with the schedule).
#[test]
fn wavefront_all_directions_match_canonical_split() {
    use crate::scan::direction::{from_canonical, to_canonical};
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(63);
    let (n, c, h, w) = (1, 2, 6, 9);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    for d in DIRECTIONS {
        let (hc, wc) = hw_src(h, w, d);
        let taps = mk_taps(&mut rng, n, 1, hc, wc);
        let xc = to_canonical(&x, d);
        let lamc = to_canonical(&lam, d);
        for segments in [2usize, 3] {
            let want =
                from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
            let got = fused_scan_dir_seg_wave(&x, &taps, &lam, d, 0, segments, &pool);
            let twopass =
                fused_scan_dir_seg_wave_twopass(&x, &taps, &lam, d, 0, segments, &pool);
            assert_eq!(want.data, got.data, "{d:?} S{segments}");
            assert_eq!(want.data, twopass.data, "two-pass {d:?} S{segments}");
        }
    }
}

/// The direction fan is bit-identical to the fused merge (and hence
/// the serial reference): a full-width zero-carry scan per (plane,
/// direction) reassociates nothing, and the drain replays the fixed
/// k = 0..4 merge order. Both schedules, several pool widths, tiny
/// and slab-crossing widths, H=1/W=1 edges.
#[test]
fn dirfan_exact_eq_fused_merge_reference() {
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(64);
    for (n, c, h, w) in [(2, 3, 6, 7), (1, 1, 1, 6), (1, 2, 6, 1), (1, 2, 24, 2 * SLAB + 3)]
    {
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.3f32, -0.7, 0.2, 1.0];
        let reference = merged_4dir_ref(&x, taps, &lam, &logits, 0);
        for pool in [&pool1, &pool3] {
            for wavefront in [false, true] {
                let fan =
                    fused_merged_4dir_fan(&x, taps, &lam, &logits, 0, wavefront, pool);
                assert_eq!(
                    reference.data, fan.data,
                    "n{n} c{c} {h}x{w} wf{wavefront}"
                );
            }
        }
    }
}

/// DirFan with chunk resets: the fan scans full width with resets
/// folded into phase 1, so chunked output equals the chunked
/// reference exactly too.
#[test]
fn dirfan_chunked_exact_eq_reference() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(65);
    let (n, c, h, w) = (1, 2, 8, 8);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let taps = [&t_lr, &t_lr, &t_tb, &t_tb];
    let logits = [0.1f32, 0.5, -0.3, 0.0];
    for kchunk in [0usize, 4, 8] {
        let reference = merged_4dir_ref(&x, taps, &lam, &logits, kchunk);
        let fan = fused_merged_4dir_fan(&x, taps, &lam, &logits, kchunk, true, &pool);
        assert_eq!(reference.data, fan.data, "k{kchunk}");
    }
}

/// A planner-forced plan carried end to end through the forced hook
/// equals running the plan's strategy directly (the plan-carrying
/// path the serving/bench layers use).
#[test]
fn planned_execution_matches_direct_strategy_calls() {
    use crate::scan::plan::{plan_scan_with, PlanOverride};
    let pool = crate::util::ThreadPool::new(4);
    let mut rng = Rng::new(66);
    let (n, c, h, w) = (1, 1, 8, 256);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let geom = ScanGeometry::single_dir(n * c, h, w);
    let p = plan_scan_with(&geom, 0, pool.threads(), PlanOverride::Auto);
    let ScanStrategy::Chained { s } = p.strategy else {
        panic!("expected a chained plan, got {:?}", p.strategy);
    };
    assert!(!p.wavefront, "the chained engine has no phases to wavefront");
    let via_auto = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
    let direct = fused_scan_l2r_chained(&x, &taps, &lam, 0, s, &pool);
    assert_eq!(via_auto.data, direct.data);
    // The chained engine replaced the two-phase Segmented plan at
    // the same count bit-for-bit.
    let twophase = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, s, &pool);
    assert_eq!(via_auto.data, twophase.data);
}

// -----------------------------------------------------------------
// The fused-correction drain
// -----------------------------------------------------------------

/// The fused-correction drain property: exact `==` against the
/// `scan_l2r_split` reference across random shapes (including H=1,
/// W=1, and slab-crossing widths), all 4 directions, segment
/// counts, and the full schedule matrix — per-direction wavefront,
/// barrier, and the PR 4 two-pass single-continuation. Plus, under
/// random kchunk divisors (split has no chunk form), all three
/// schedules stay bit-identical to each other.
#[test]
fn fused_correction_drain_schedule_matrix_property() {
    use crate::scan::direction::{from_canonical, to_canonical};
    let pool = crate::util::ThreadPool::new(3);
    check("fused drain == split across schedules", |g| {
        let n = g.int_in(1, 2);
        let c = g.int_in(1, 2);
        let h = g.int_in(1, 9);
        let w = g.int_in(1, 2 * SLAB + 8);
        let segments = g.int_in(1, 5);
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, 1, hc, wc);
            let xc = to_canonical(&x, d);
            let lamc = to_canonical(&lam, d);
            let want =
                from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
            let barrier = fused_scan_dir_seg(&x, &taps, &lam, d, 0, segments, &pool);
            let wave = fused_scan_dir_seg_wave(&x, &taps, &lam, d, 0, segments, &pool);
            let twopass =
                fused_scan_dir_seg_wave_twopass(&x, &taps, &lam, d, 0, segments, &pool);
            let tag = format!("n{n} c{c} {h}x{w} {d:?} S{segments}");
            ensure(want.data == barrier.data, format!("barrier != split: {tag}"))?;
            ensure(want.data == wave.data, format!("wave != split: {tag}"))?;
            ensure(want.data == twopass.data, format!("two-pass != split: {tag}"))?;
            // Chunk resets inside segments: the three schedules must
            // agree bit-for-bit (the chunked split reference is the
            // barrier engine itself).
            let kchunk = *g.pick(&divisors(wc));
            let cb = fused_scan_dir_seg(&x, &taps, &lam, d, kchunk, segments, &pool);
            let cw_ = fused_scan_dir_seg_wave(&x, &taps, &lam, d, kchunk, segments, &pool);
            let ct =
                fused_scan_dir_seg_wave_twopass(&x, &taps, &lam, d, kchunk, segments, &pool);
            ensure(cb.data == cw_.data, format!("chunked wave != barrier: {tag} k{kchunk}"))?;
            ensure(cb.data == ct.data, format!("chunked two-pass != barrier: {tag} k{kchunk}"))?;
        }
        Ok(())
    });
}

// -----------------------------------------------------------------
// The single-pass chained engine
// -----------------------------------------------------------------

/// The tentpole exactness property: the single-pass chained engine
/// (decoupled look-back, no phase barrier) is exact `==` against
/// `scan_l2r_split` across random shapes (including H=1, W=1, and
/// slab-crossing widths), all 4 directions, chunk counts, shared
/// and per-channel taps, and both the serial path (1-thread pool)
/// and concurrent chains with work-assist (3-thread pool). Under
/// random kchunk divisors (split has no chunk form) chained must
/// equal the two-phase barrier engine bit-for-bit — the claim that
/// retiring the barrier changed the schedule and nothing else.
#[test]
fn chained_engine_exact_eq_split_property() {
    use crate::scan::direction::{from_canonical, to_canonical};
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    check("chained == split across shapes", |g| {
        let n = g.int_in(1, 2);
        let c = g.int_in(1, 2);
        let h = g.int_in(1, 9);
        let w = g.int_in(1, 2 * SLAB + 8);
        let segments = g.int_in(1, 5);
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let cw = *g.pick(&[1, c]);
            let taps = mk_taps(&mut rng, n, cw, hc, wc);
            let xc = to_canonical(&x, d);
            let lamc = to_canonical(&lam, d);
            let want =
                from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
            let tag = format!("n{n} c{c} cw{cw} {h}x{w} {d:?} S{segments}");
            for (pname, pool) in [("pool1", &pool1), ("pool3", &pool3)] {
                let got = fused_scan_dir_chained(&x, &taps, &lam, d, 0, segments, pool);
                ensure(want.data == got.data, format!("chained != split: {tag} {pname}"))?;
            }
            // Chunk resets inside chunks: the chunked split
            // reference is the two-phase barrier engine itself.
            let kchunk = *g.pick(&divisors(wc));
            let barrier = fused_scan_dir_seg(&x, &taps, &lam, d, kchunk, segments, &pool3);
            let chained =
                fused_scan_dir_chained(&x, &taps, &lam, d, kchunk, segments, &pool3);
            ensure(
                barrier.data == chained.data,
                format!("chunked chained != barrier: {tag} k{kchunk}"),
            )?;
        }
        Ok(())
    });
}

/// The merged 4-direction pass under the chained engine: the
/// per-plane drain gates preserve the k = 0..4 merge order, so
/// chained output is exact `==` the two-phase barrier merged engine
/// at every chunk count (and, at S = 1, the serial merged
/// reference) — on the degenerate H=1 / W=1 geometries and a
/// slab-crossing width too.
#[test]
fn chained_merged_4dir_exact_eq_segmented() {
    let pool1 = crate::util::ThreadPool::new(1);
    let pool3 = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(74);
    for (n, c, h, w) in [(2, 3, 6, 7), (1, 1, 1, 6), (1, 2, 6, 1), (1, 2, 24, 2 * SLAB + 3)]
    {
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.3f32, -0.7, 0.2, 1.0];
        let serial = merged_4dir_ref(&x, taps, &lam, &logits, 0);
        for segments in [1usize, 2, 3] {
            let reference =
                fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, segments, &pool3);
            for (pname, pool) in [("pool1", &pool1), ("pool3", &pool3)] {
                let got =
                    fused_merged_4dir_chained(&x, taps, &lam, &logits, 0, segments, pool);
                assert_eq!(
                    reference.data, got.data,
                    "n{n} c{c} {h}x{w} S{segments} {pname}"
                );
            }
            if segments == 1 {
                assert_eq!(serial.data, reference.data, "n{n} c{c} {h}x{w} S1 serial");
            }
        }
    }
}

/// Satellite regression: a panicking phase-1 job in the wavefront
/// path must surface as the original panic payload (collected
/// MapError-style through `run_graph`), not as a `PoisonError` or a
/// secondary index panic from a dependent drain reading a missing
/// piece — and the engine/pool must stay healthy afterwards.
#[test]
fn wavefront_phase1_panic_propagates_original_payload() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let pool = crate::util::ThreadPool::new(2);
    let mut rng = Rng::new(70);
    let (n, c, h, w) = (1, 2, 5, 160);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    // w=160, S=2 -> bounds (0,80),(80,160). Inject into the second
    // piece of plane 0 — a (plane, dir, lo, hi) tuple no other
    // test's geometry produces (every other suite's segment ends
    // are < 80 or land elsewhere), so concurrently running tests
    // never trip the hook.
    for schedule in ["wave-dir", "two-pass"] {
        *lock_unpoisoned(&test_hooks::PANIC_PIECE) = Some((0, 0, 80, 160));
        let caught = catch_unwind(AssertUnwindSafe(|| match schedule {
            "wave-dir" => fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, 2, &pool),
            _ => fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, 0, 2, &pool),
        }));
        *lock_unpoisoned(&test_hooks::PANIC_PIECE) = None;
        let payload = match caught {
            Ok(_) => panic!("{schedule}: wavefront must rethrow the phase-1 panic"),
            Err(p) => p,
        };
        let msg = crate::util::panic_message(&*payload);
        assert!(
            msg.contains("injected phase-1 panic"),
            "{schedule}: expected the injected payload, got {msg:?}"
        );
    }
    // Poisoned hand-off slots are recovered; the next run is clean
    // and exact.
    let reference = scan_l2r_split(&x, &taps, &lam, 2, 1);
    let after = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, 2, &pool);
    assert_eq!(reference.data, after.data);
}

// -----------------------------------------------------------------
// Workspace pooling
// -----------------------------------------------------------------

/// Pooled scratch changes no bits: every strategy/schedule produces
/// the same output from a cold workspace (all misses), a warm one
/// (reused, dirty buffers), and equals the `scan_l2r_split` /
/// serial reference. This is the pooled-vs-fresh half of the
/// allocation-free acceptance invariant.
#[test]
fn pooled_output_bit_identical_to_fresh_workspace_across_strategies() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(71);
    let (n, c, h, w) = (1, 2, 7, 96);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let cases = [
        (ScanStrategy::PlanePar, Phase2::Barrier),
        (ScanStrategy::Segmented { s: 3 }, Phase2::Barrier),
        (ScanStrategy::Segmented { s: 3 }, Phase2::WaveDir),
        (ScanStrategy::Segmented { s: 3 }, Phase2::WavePlane),
        (ScanStrategy::Chained { s: 3 }, Phase2::Barrier),
    ];
    for (strategy, phase2) in cases {
        let reference = match strategy {
            ScanStrategy::Segmented { s } | ScanStrategy::Chained { s } => {
                scan_l2r_split(&x, &taps, &lam, s, 1)
            }
            _ => scan_l2r(&x, &taps, &lam, 0),
        };
        let warm_ws = BufferPool::new(usize::MAX);
        for round in 0..3 {
            let cold_ws = BufferPool::new(usize::MAX);
            let cold = fused_scan_dir_forced_ws(
                &x, &taps, &lam, Direction::L2R, 0, strategy, phase2, &pool, &cold_ws,
                None,
            );
            let warm = fused_scan_dir_forced_ws(
                &x, &taps, &lam, Direction::L2R, 0, strategy, phase2, &pool, &warm_ws,
                None,
            );
            assert_eq!(
                reference.data, cold.data,
                "cold != ref: {strategy:?} {phase2:?} round {round}"
            );
            assert_eq!(
                reference.data, warm.data,
                "warm != ref: {strategy:?} {phase2:?} round {round}"
            );
        }
        // Everything leased came back.
        assert_eq!(warm_ws.stats().bytes_leased, 0, "{strategy:?} {phase2:?}");
    }
    // The merged direction fan (the strategy the single-direction
    // matrix above cannot reach).
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_rl = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let t_bt = mk_taps(&mut rng, n, 1, w, h);
    let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
    let logits = [0.4f32, -0.2, 1.1, 0.0];
    let reference = merged_4dir_ref(&x, mtaps, &lam, &logits, 0);
    let warm_ws = BufferPool::new(usize::MAX);
    for phase2 in [Phase2::Barrier, Phase2::WaveDir] {
        for round in 0..2 {
            let fan = fused_merged_4dir_forced_ws(
                &x,
                mtaps,
                &lam,
                &logits,
                0,
                ScanStrategy::DirFan,
                phase2,
                &pool,
                &warm_ws,
                None,
            );
            assert_eq!(reference.data, fan.data, "dirfan {phase2:?} round {round}");
        }
    }
    assert_eq!(warm_ws.stats().bytes_leased, 0);
}

/// The reply-recycling entry: an output buffer taken from the
/// workspace produces bit-identical results to the fresh-allocating
/// entry, and donating the result's storage back makes the next
/// take a pool hit — the coordinator's whole-request
/// allocation-free loop, exercised at the engine level.
#[test]
fn recycled_output_buffer_bit_identical_and_donated() {
    // 1 thread: the serial lease sequence makes the zero-miss
    // assertion deterministic (the 2+-thread schedules are covered
    // by the bit-exactness suites).
    let pool = crate::util::ThreadPool::new(1);
    let mut rng = Rng::new(77);
    let (n, c, h, w) = (1, 3, 7, 40);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let want = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
    let ws = BufferPool::new(usize::MAX);
    let out = fused_scan_l2r_pool_ws_into(
        &x,
        &taps,
        &lam,
        0,
        &pool,
        &ws,
        ws.take_zeroed(x.data.len()),
    );
    assert_eq!(out.data, want.data);
    assert_eq!(ws.stats().bytes_leased, 0);
    // Donate the reply storage back; the rerun's take must hit.
    ws.donate(out.data);
    let before = ws.stats();
    let out = fused_scan_l2r_pool_ws_into(
        &x,
        &taps,
        &lam,
        0,
        &pool,
        &ws,
        ws.take_zeroed(x.data.len()),
    );
    let after = ws.stats();
    assert_eq!(out.data, want.data);
    assert!(after.hits > before.hits, "recycled take must be served from the pool");
    assert_eq!(
        after.misses, before.misses,
        "a donated reply buffer must make the next take allocation-free"
    );
}

/// The allocation-free invariant at the engine level: on the
/// deterministic (serial-execution) paths, repeating an identical
/// call against a warm workspace records ZERO pool misses — the
/// second run's every acquire is served from buffers the first run
/// returned. A 1-thread pool takes the serial branches of every
/// barrier strategy, so the lease sequence is reproducible.
#[test]
fn warm_workspace_rerun_records_zero_misses() {
    let pool1 = crate::util::ThreadPool::new(1);
    let mut rng = Rng::new(72);
    let (n, c, h, w) = (1, 2, 6, 48);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    for strategy in [
        ScanStrategy::PlanePar,
        ScanStrategy::Segmented { s: 3 },
        ScanStrategy::Chained { s: 3 },
    ] {
        let ws = BufferPool::new(usize::MAX);
        let first = fused_scan_dir_forced_ws(
            &x, &taps, &lam, Direction::L2R, 0, strategy, Phase2::Barrier, &pool1, &ws,
            None,
        );
        let s1 = ws.stats();
        assert!(s1.misses > 0, "{strategy:?}: cold run must allocate");
        assert_eq!(s1.bytes_leased, 0, "{strategy:?}: leases must all return");
        let second = fused_scan_dir_forced_ws(
            &x, &taps, &lam, Direction::L2R, 0, strategy, Phase2::Barrier, &pool1, &ws,
            None,
        );
        let s2 = ws.stats();
        assert_eq!(
            s2.misses, s1.misses,
            "{strategy:?}: warm rerun allocated from the heap"
        );
        assert!(s2.hits > s1.hits, "{strategy:?}: warm rerun must hit the pool");
        assert_eq!(first.data, second.data);
    }
    // The merged fan on the barrier schedule is serial on a 1-thread
    // pool too.
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let mtaps = [&t_lr, &t_lr, &t_tb, &t_tb];
    let logits = [0.3f32, -0.7, 0.2, 1.0];
    let ws = BufferPool::new(usize::MAX);
    let first = fused_merged_4dir_forced_ws(
        &x,
        mtaps,
        &lam,
        &logits,
        0,
        ScanStrategy::DirFan,
        Phase2::Barrier,
        &pool1,
        &ws,
        None,
    );
    let s1 = ws.stats();
    let second = fused_merged_4dir_forced_ws(
        &x,
        mtaps,
        &lam,
        &logits,
        0,
        ScanStrategy::DirFan,
        Phase2::Barrier,
        &pool1,
        &ws,
        None,
    );
    assert_eq!(ws.stats().misses, s1.misses, "dirfan warm rerun allocated");
    assert_eq!(first.data, second.data);
}

/// RAII under unwinding: a phase-1 piece job that panics while
/// holding leased scratch (the injection fires *after* the piece
/// lease is acquired) must return every lease to the workspace —
/// nothing stays out on lease, and the buffers parked in the
/// abandoned hand-off slots come back when the engine's slot vec
/// drops. The pool serves the next run without leaking.
#[test]
fn wavefront_panic_returns_all_leases_to_workspace() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let pool = crate::util::ThreadPool::new(2);
    let ws = BufferPool::new(usize::MAX);
    let mut rng = Rng::new(73);
    let (n, c, h, w) = (1, 2, 5, 224);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    // w=224, S=2 -> bounds (0,112),(112,224). A (plane, dir, lo, hi)
    // tuple unique to this test's geometry, so concurrently running
    // suites never trip the hook.
    *lock_unpoisoned(&test_hooks::PANIC_PIECE) = Some((0, 0, 112, 224));
    let caught = catch_unwind(AssertUnwindSafe(|| {
        fused_scan_dir_forced_ws(
            &x,
            &taps,
            &lam,
            Direction::L2R,
            0,
            ScanStrategy::Segmented { s: 2 },
            Phase2::WaveDir,
            &pool,
            &ws,
            None,
        )
    }));
    *lock_unpoisoned(&test_hooks::PANIC_PIECE) = None;
    assert!(caught.is_err(), "the injected panic must propagate");
    let s = ws.stats();
    assert_eq!(
        s.bytes_leased, 0,
        "a panicking scan leaked workspace leases: {s:?}"
    );
    assert!(s.bytes_pooled > 0, "returned buffers must be pooled for reuse");
    // The pool still serves bit-exact scans afterwards.
    let reference = scan_l2r_split(&x, &taps, &lam, 2, 1);
    let after = fused_scan_dir_forced_ws(
        &x,
        &taps,
        &lam,
        Direction::L2R,
        0,
        ScanStrategy::Segmented { s: 2 },
        Phase2::WaveDir,
        &pool,
        &ws,
        None,
    );
    assert_eq!(reference.data, after.data);
    assert_eq!(ws.stats().bytes_leased, 0);
}

/// Spin-safety of the chained engine (the look-back satellite): a
/// chunk that panics mid-chain poisons its board block, so every
/// chunk spinning on that chain unwinds through `MapError` instead
/// of deadlocking on a prefix that will never be published. Both
/// injection points matter — the chain head (everyone downstream
/// waits on it) and a mid-chain chunk (upstream already published,
/// downstream mid-wait). Afterwards every lease is back, the
/// returned buffers are pooled, and the same pool + workspace serve
/// a bit-exact rerun.
#[test]
fn chained_panic_poisons_board_and_returns_leases() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let pool = crate::util::ThreadPool::new(2);
    let ws = BufferPool::new(usize::MAX);
    let mut rng = Rng::new(75);
    let (n, c, h, w) = (1, 2, 5, 320);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    // w=320, S=2 -> bounds (0,160),(160,320), planes {0,1}. Plane
    // 1's tuples are unique to this geometry (no other suite
    // produces segment ends at 160/320), so concurrently running
    // tests never trip the hook.
    for inject in [(1, 0, 160, 320), (1, 0, 0, 160)] {
        *lock_unpoisoned(&test_hooks::PANIC_PIECE) = Some(inject);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            fused_scan_dir_forced_ws(
                &x,
                &taps,
                &lam,
                Direction::L2R,
                0,
                ScanStrategy::Chained { s: 2 },
                Phase2::Barrier,
                &pool,
                &ws,
                None,
            )
        }));
        *lock_unpoisoned(&test_hooks::PANIC_PIECE) = None;
        let payload = match caught {
            Ok(_) => panic!("{inject:?}: the chained engine must rethrow the panic"),
            Err(p) => p,
        };
        // The surfaced payload is the injected one, or a waiter's
        // secondary poisoned-chain panic when that lands in the
        // MapError first — never a deadlock or a PoisonError.
        let msg = crate::util::panic_message(&*payload);
        assert!(
            msg.contains("injected phase-1 panic") || msg.contains("chained scan"),
            "{inject:?}: unexpected payload {msg:?}"
        );
        let s = ws.stats();
        assert_eq!(s.bytes_leased, 0, "{inject:?}: leaked leases: {s:?}");
        assert!(s.bytes_pooled > 0, "{inject:?}: returned buffers must be pooled");
    }
    // The pool and workspace still serve bit-exact chained scans.
    let reference = scan_l2r_split(&x, &taps, &lam, 2, 1);
    let after = fused_scan_dir_forced_ws(
        &x,
        &taps,
        &lam,
        Direction::L2R,
        0,
        ScanStrategy::Chained { s: 2 },
        Phase2::Barrier,
        &pool,
        &ws,
        None,
    );
    assert_eq!(reference.data, after.data);
    assert_eq!(ws.stats().bytes_leased, 0);
}

/// The SIMD pin at the engine level: every vector kernel this host
/// supports produces output exactly `==` the scalar kernel's across
/// all four directions, every strategy/schedule, kchunk resets, and
/// slab-boundary / degenerate widths. (The scalar kernel itself is
/// pinned `==` the unfused reference by the suites above, so this
/// transitively pins the vector kernels to the reference.) Flipping
/// the process-global kernel override is safe even under concurrent
/// tests precisely because of this property — any kernel produces
/// the same bits.
#[test]
fn simd_kernels_pinned_bit_identical_to_scalar_across_engine_matrix() {
    let kernels: Vec<&str> = ["avx2", "neon"]
        .into_iter()
        .filter(|k| simd::set_simd_override(k).is_ok())
        .collect();
    simd::set_simd_override("auto").unwrap();
    if kernels.is_empty() {
        // Scalar-only host: the vector kernels are pinned by the
        // x86_64/aarch64 CI legs; nothing to compare here.
        return;
    }
    let pool = crate::util::ThreadPool::new(4);
    let ws = BufferPool::new(usize::MAX);
    let mut rng = Rng::new(91);
    // Slab crossings, the partial last slab, H=1 and W=1 columns.
    let geoms = [
        (1usize, 2usize, 5usize, SLAB - 1),
        (1, 2, 5, SLAB + 1),
        (1, 1, 1, 2 * SLAB + 3),
        (1, 2, 2 * SLAB + 3, 1),
        (2, 2, 9, 48),
    ];
    let cases = [
        (ScanStrategy::PlanePar, Phase2::Barrier),
        (ScanStrategy::Segmented { s: 3 }, Phase2::Barrier),
        (ScanStrategy::Segmented { s: 3 }, Phase2::WaveDir),
        (ScanStrategy::Segmented { s: 3 }, Phase2::WavePlane),
        (ScanStrategy::Chained { s: 3 }, Phase2::Barrier),
    ];
    for (n, c, h, w) in geoms {
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, 1, hc, wc);
            // Full width plus one mid-column carry reset.
            let kchunks =
                if wc >= 2 && wc % 2 == 0 { vec![0usize, wc / 2] } else { vec![0usize] };
            for &k in &kchunks {
                for (strategy, phase2) in cases {
                    simd::set_simd_override("scalar").unwrap();
                    let base = fused_scan_dir_forced_ws(
                        &x, &taps, &lam, d, k, strategy, phase2, &pool, &ws, None,
                    );
                    for kern in &kernels {
                        simd::set_simd_override(kern).unwrap();
                        let got = fused_scan_dir_forced_ws(
                            &x, &taps, &lam, d, k, strategy, phase2, &pool, &ws, None,
                        );
                        assert_eq!(
                            base.data, got.data,
                            "{kern} != scalar: n{n} c{c} {h}x{w} {d:?} k{k} \
                             {strategy:?} {phase2:?}"
                        );
                    }
                }
            }
        }
    }
    // The merged path: softmax-merge + modulation epilogue under
    // DirFan (unreachable from the single-direction matrix) and the
    // chained engine.
    let (n, c, h, w) = (1usize, 2usize, 6usize, SLAB + 5);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_rl = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let t_bt = mk_taps(&mut rng, n, 1, w, h);
    let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
    let logits = [0.4f32, -0.2, 1.1, 0.0];
    for (strategy, phase2) in [
        (ScanStrategy::DirFan, Phase2::Barrier),
        (ScanStrategy::DirFan, Phase2::WaveDir),
        (ScanStrategy::Segmented { s: 2 }, Phase2::WaveDir),
        (ScanStrategy::Chained { s: 2 }, Phase2::Barrier),
    ] {
        simd::set_simd_override("scalar").unwrap();
        let base = fused_merged_4dir_forced_ws(
            &x, mtaps, &lam, &logits, 0, strategy, phase2, &pool, &ws, None,
        );
        for kern in &kernels {
            simd::set_simd_override(kern).unwrap();
            let got = fused_merged_4dir_forced_ws(
                &x, mtaps, &lam, &logits, 0, strategy, phase2, &pool, &ws, None,
            );
            assert_eq!(
                base.data, got.data,
                "merged {kern} != scalar: {strategy:?} {phase2:?}"
            );
        }
    }
    simd::set_simd_override("auto").unwrap();
    assert_eq!(ws.stats().bytes_leased, 0);
}

/// The bf16 panel-mode pin: with taps and chained panels stored as
/// bf16 (threaded per call — never via the process-global override,
/// which concurrently running `==` suites would observe), every
/// strategy's output matches the f32 run elementwise within the
/// documented tolerance `|bf16 - f32| <= (|f32| + 1) * 2^-6`, and
/// the narrowing actually engages (bits differ from f32).
#[test]
fn bf16_panels_within_documented_tolerance_of_f32() {
    let pool = crate::util::ThreadPool::new(4);
    let ws = BufferPool::new(usize::MAX);
    let mut rng = Rng::new(92);
    // 2^-6, the documented pin; the merged rows get one extra bit
    // of slack (2^-5) because the softmax merge can cancel |f32|
    // while the per-direction errors it averages do not cancel.
    let tol_ok = |f: &[f32], b: &[f32], eps: f32| {
        f.iter().zip(b).all(|(&a, &o)| (a - o).abs() <= (a.abs() + 1.0) * eps)
    };
    let (n, c, h, w) = (1usize, 2usize, 7usize, 2 * SLAB + 3);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    for d in DIRECTIONS {
        let (hc, wc) = hw_src(h, w, d);
        let taps = mk_taps(&mut rng, n, 1, hc, wc);
        for (strategy, phase2) in [
            (ScanStrategy::PlanePar, Phase2::Barrier),
            (ScanStrategy::Segmented { s: 3 }, Phase2::WaveDir),
            (ScanStrategy::Chained { s: 3 }, Phase2::Barrier),
        ] {
            let full = fused_scan_dir_forced_ws(
                &x,
                &taps,
                &lam,
                d,
                0,
                strategy,
                phase2,
                &pool,
                &ws,
                Some(Precision::F32),
            );
            let half = fused_scan_dir_forced_ws(
                &x,
                &taps,
                &lam,
                d,
                0,
                strategy,
                phase2,
                &pool,
                &ws,
                Some(Precision::Bf16),
            );
            assert!(
                tol_ok(&full.data, &half.data, 0.015_625),
                "bf16 out of tolerance: {d:?} {strategy:?} {phase2:?}"
            );
            assert_ne!(
                full.data, half.data,
                "bf16 did not engage: {d:?} {strategy:?} {phase2:?}"
            );
            // An explicit F32 equals the default (None) bits.
            let default = fused_scan_dir_forced_ws(
                &x, &taps, &lam, d, 0, strategy, phase2, &pool, &ws, None,
            );
            assert_eq!(full.data, default.data, "{d:?} {strategy:?} {phase2:?}");
        }
    }
    // The merged epilogue (softmax merge + modulation) on top of
    // bf16-staged scans, across the fan and chained engines.
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_rl = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let t_bt = mk_taps(&mut rng, n, 1, w, h);
    let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
    let logits = [0.3f32, -0.7, 0.2, 1.0];
    for (strategy, phase2) in [
        (ScanStrategy::DirFan, Phase2::WaveDir),
        (ScanStrategy::Segmented { s: 2 }, Phase2::Barrier),
        (ScanStrategy::Chained { s: 2 }, Phase2::Barrier),
    ] {
        let full = fused_merged_4dir_forced_ws(
            &x,
            mtaps,
            &lam,
            &logits,
            0,
            strategy,
            phase2,
            &pool,
            &ws,
            Some(Precision::F32),
        );
        let half = fused_merged_4dir_forced_ws(
            &x,
            mtaps,
            &lam,
            &logits,
            0,
            strategy,
            phase2,
            &pool,
            &ws,
            Some(Precision::Bf16),
        );
        assert!(
            tol_ok(&full.data, &half.data, 0.031_25),
            "merged bf16 out of tolerance: {strategy:?} {phase2:?}"
        );
        assert_ne!(full.data, half.data, "merged bf16 did not engage: {strategy:?}");
    }
    assert_eq!(ws.stats().bytes_leased, 0);
}

// =====================================================================
// Tiled streaming (bounded-memory row-band execution)
// =====================================================================

/// The tiled `==` matrix: every inner strategy × band sizes hitting
/// each grouping edge (a single column, a prime, an aligned power of
/// two, and ≥ the axis — the degenerate one-band case that IS the
/// untiled engine) × all four directions × kchunk divisors × 1- and
/// multi-thread pools. The pin is exact `==` against the untiled fused
/// engine, which the suites above pin `==` to `scan_l2r` /
/// `scan_l2r_split` — so tiled is transitively pinned to the serial
/// reference.
#[test]
fn tiled_bit_exact_across_band_matrix() {
    check("tiled == untiled across bands/dirs/inners/kchunks", |g| {
        let n = g.int_in(1, 2);
        let c = g.int_in(1, 2);
        let h = g.int_in(1, 9);
        let w = g.int_in(1, 9);
        let threads = *g.pick(&[1usize, 3]);
        let pool = crate::util::ThreadPool::new(threads);
        let mut rng = Rng::new(g.rng.next_u64());
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, 1, hc, wc);
            let k = *g.pick(&divisors(wc));
            let reference = fused_scan_dir(&x, &taps, &lam, d, k);
            let s = g.int_in(1, wc.min(4));
            for inner in [TileInner::Seq, TileInner::Segmented { s }, TileInner::Chained { s }]
            {
                for band_rows in [1usize, 3, 4, wc, wc + 5] {
                    let ws = BufferPool::new(usize::MAX);
                    let tiled = fused_scan_dir_forced_ws(
                        &x,
                        &taps,
                        &lam,
                        d,
                        k,
                        ScanStrategy::Tiled { band_rows, inner },
                        Phase2::Barrier,
                        &pool,
                        &ws,
                        None,
                    );
                    ensure(
                        tiled.data == reference.data,
                        format!(
                            "tiled != untiled: {h}x{w} {d:?} k{k} s{s} \
                             band{band_rows} {inner:?} t{threads}"
                        ),
                    )?;
                    ensure(
                        ws.stats().bytes_leased == 0,
                        format!("tiled leaked leases: {inner:?} band{band_rows}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

/// The three-way deterministic pin of the issue on a ragged (prime)
/// axis: tiled == the untiled segmented/chained engines == the
/// `scan_l2r_split` reference at the same count, across band sizes that
/// group 1, several, and all pieces — plus the `Seq` inner against the
/// plain sequential reference, with a kchunk that resets mid-band.
#[test]
fn tiled_matches_split_reference() {
    let pool = crate::util::ThreadPool::new(2);
    let mut rng = Rng::new(91);
    let (n, c, h, w) = (1, 2, 5, 97);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    for s in [2usize, 3, 5] {
        let reference = scan_l2r_split(&x, &taps, &lam, s, 1);
        for band_rows in [1usize, 7, 32, 97, 128] {
            for inner in [TileInner::Segmented { s }, TileInner::Chained { s }] {
                let ws = BufferPool::new(usize::MAX);
                let tiled = fused_scan_dir_forced_ws(
                    &x,
                    &taps,
                    &lam,
                    Direction::L2R,
                    0,
                    ScanStrategy::Tiled { band_rows, inner },
                    Phase2::Barrier,
                    &pool,
                    &ws,
                    None,
                );
                assert_eq!(
                    reference.data, tiled.data,
                    "tiled != split: s{s} band{band_rows} {inner:?}"
                );
            }
        }
    }
    // Seq inner vs the sequential reference, with chunk resets landing
    // inside and on band boundaries (band 7 vs reset every 97/97=1..).
    for kchunk in [0usize, 97] {
        let reference = scan_l2r(&x, &taps, &lam, kchunk);
        for band_rows in [1usize, 7, 32, 200] {
            let ws = BufferPool::new(usize::MAX);
            let tiled = fused_scan_dir_forced_ws(
                &x,
                &taps,
                &lam,
                Direction::L2R,
                kchunk,
                ScanStrategy::Tiled { band_rows, inner: TileInner::Seq },
                Phase2::Barrier,
                &pool,
                &ws,
                None,
            );
            assert_eq!(reference.data, tiled.data, "seq tiled != ref: band{band_rows}");
        }
    }
}

/// Tiled 4-direction merged passes: directions run serially band by
/// band, so every pixel must still receive its k = 0..4 merge ops in
/// the reference order — exact `==` with `merged_4dir_ref` for every
/// inner.
#[test]
fn tiled_merged_4dir_bit_exact() {
    let pool = crate::util::ThreadPool::new(3);
    let mut rng = Rng::new(92);
    let (n, c, h, w) = (1, 2, 7, 9);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let t_lr = mk_taps(&mut rng, n, 1, h, w);
    let t_rl = mk_taps(&mut rng, n, 1, h, w);
    let t_tb = mk_taps(&mut rng, n, 1, w, h);
    let t_bt = mk_taps(&mut rng, n, 1, w, h);
    let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
    let logits = [0.4f32, -0.2, 1.1, 0.0];
    let reference = merged_4dir_ref(&x, mtaps, &lam, &logits, 0);
    for inner in [TileInner::Seq, TileInner::Segmented { s: 2 }, TileInner::Chained { s: 2 }] {
        for band_rows in [1usize, 4, 16] {
            let ws = BufferPool::new(usize::MAX);
            let tiled = fused_merged_4dir_forced_ws(
                &x,
                mtaps,
                &lam,
                &logits,
                0,
                ScanStrategy::Tiled { band_rows, inner },
                Phase2::Barrier,
                &pool,
                &ws,
                None,
            );
            assert_eq!(
                reference.data, tiled.data,
                "tiled merged != ref: band{band_rows} {inner:?}"
            );
            assert_eq!(ws.stats().bytes_leased, 0);
        }
    }
}

/// The allocation-free steady state extends to tiling: on a 1-thread
/// pool, rerunning an identical tiled pass against a warm workspace
/// records ZERO pool misses for every inner — band leases return and
/// are re-acquired in a reproducible sequence.
#[test]
fn tiled_warm_rerun_records_zero_misses() {
    let pool1 = crate::util::ThreadPool::new(1);
    let mut rng = Rng::new(93);
    let (n, c, h, w) = (1, 2, 6, 48);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    for inner in [TileInner::Seq, TileInner::Segmented { s: 3 }, TileInner::Chained { s: 3 }] {
        let ws = BufferPool::new(usize::MAX);
        let strategy = ScanStrategy::Tiled { band_rows: 16, inner };
        let first = fused_scan_dir_forced_ws(
            &x, &taps, &lam, Direction::L2R, 0, strategy, Phase2::Barrier, &pool1, &ws, None,
        );
        let s1 = ws.stats();
        assert!(s1.misses > 0, "{inner:?}: cold run must allocate");
        assert_eq!(s1.bytes_leased, 0, "{inner:?}: leases must all return");
        let second = fused_scan_dir_forced_ws(
            &x, &taps, &lam, Direction::L2R, 0, strategy, Phase2::Barrier, &pool1, &ws, None,
        );
        let s2 = ws.stats();
        assert_eq!(s2.misses, s1.misses, "{inner:?}: warm tiled rerun allocated");
        assert!(s2.hits > s1.hits, "{inner:?}: warm tiled rerun must hit the pool");
        assert_eq!(first.data, second.data);
    }
}

/// The bounded-memory claim itself: on a wide axis, streaming in small
/// bands must hold strictly less workspace at peak than the untiled
/// engine — peak `bytes_leased`, measured on fresh pools.
#[test]
fn tiled_peak_lease_below_untiled() {
    let pool1 = crate::util::ThreadPool::new(1);
    let mut rng = Rng::new(94);
    let (n, c, h, w) = (1, 2, 8, 512);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let untiled_ws = BufferPool::new(usize::MAX);
    let untiled = fused_scan_dir_forced_ws(
        &x,
        &taps,
        &lam,
        Direction::L2R,
        0,
        ScanStrategy::Chained { s: 4 },
        Phase2::Barrier,
        &pool1,
        &untiled_ws,
        None,
    );
    let tiled_ws = BufferPool::new(usize::MAX);
    let tiled = fused_scan_dir_forced_ws(
        &x,
        &taps,
        &lam,
        Direction::L2R,
        0,
        ScanStrategy::Tiled { band_rows: 64, inner: TileInner::Chained { s: 4 } },
        Phase2::Barrier,
        &pool1,
        &tiled_ws,
        None,
    );
    assert_eq!(untiled.data, tiled.data);
    let (up, tp) = (untiled_ws.stats().peak_leased, tiled_ws.stats().peak_leased);
    assert!(
        tp * 2 <= up,
        "tiled peak {tp} must be at most half the untiled peak {up}"
    );
}

/// The planner × engine integration: a workspace whose retention cap
/// is far below the pass's untiled footprint makes the Auto path
/// stream the request (no forced strategy anywhere) — and the output
/// stays bit-identical to the uncapped run.
#[test]
fn auto_plan_tiles_over_cap_workspace() {
    let pool = crate::util::ThreadPool::new(4);
    let mut rng = Rng::new(95);
    let (n, c, h, w) = (1, 1, 8, 512);
    let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
    let taps = mk_taps(&mut rng, n, 1, h, w);
    let reference = fused_scan_dir(&x, &taps, &lam, Direction::L2R, 0);
    // 64 KiB cap: far below the staged-tap panel alone (3 * 8 * 512
    // floats per plane), so maybe_tile must wrap the auto decision.
    let geom = plan::ScanGeometry::single_dir(n * c, h, w);
    let auto = plan::plan_scan_with(&geom, 0, pool.threads(), plan::PlanOverride::Auto);
    let capped = plan::maybe_tile(auto, &geom, pool.threads(), 1, 64 * 1024, false);
    assert!(
        matches!(capped.strategy, ScanStrategy::Tiled { .. }),
        "cap must force tiling, got {:?}",
        capped.strategy
    );
    let ws = BufferPool::new(64 * 1024);
    let out = fused_scan_dir_pool_ws(&x, &taps, &lam, Direction::L2R, 0, &pool, &ws);
    assert_eq!(reference.data, out.data);
    assert_eq!(ws.stats().bytes_leased, 0);
}

/// The `ExternalCarry` wire format — the serialization seam a LASP-2
/// style multi-node split ships between ranks: `to_bytes`/`from_bytes`
/// round-trips every column bit for bit (including -0.0 and subnormal
/// values), and malformed payloads are rejected, not misread.
#[test]
fn external_carry_wire_roundtrip() {
    let mut ec = ExternalCarry::zeros(5, 3);
    let vals = [1.5f32, -0.0, f32::MIN_POSITIVE / 2.0, -7.25, 1e-38];
    for p in 0..3 {
        for (i, v) in vals.iter().enumerate() {
            ec.column_mut(p)[i] = v * (p as f32 + 1.0);
        }
    }
    let bytes = ec.to_bytes();
    let back = ExternalCarry::from_bytes(&bytes).expect("roundtrip must parse");
    assert_eq!(back.hc(), 5);
    assert_eq!(back.nplanes(), 3);
    for p in 0..3 {
        let (a, b) = (ec.column(p), back.column(p));
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "column {p} must round-trip bit-exactly"
        );
    }
    // Truncated, oversized, and garbage-header payloads all fail
    // cleanly.
    assert!(ExternalCarry::from_bytes(&bytes[..bytes.len() - 1]).is_none());
    assert!(ExternalCarry::from_bytes(&[0u8; 3]).is_none());
    let mut oversized = bytes.clone();
    oversized.extend_from_slice(&[0u8; 4]);
    assert!(ExternalCarry::from_bytes(&oversized).is_none());
}
