//! Carry resolution: the algebra that turns zero-carry pieces into the
//! true sequential scan.
//!
//! Everything cross-chunk in the line-scan recurrence is one tiny carry
//! column, and this module owns every way the engine obtains one.
//! [`CarrySource`] names the four provenances — `Zero` (the true origin
//! of the scan axis), `Resolved` (a caller-tracked column), `Lookback`
//! (a publication-board prefix), and `External` (a serialized band /
//! shard hand-off) — and [`correct_segment`] / [`correct_segment_bf16`]
//! are the one shared correction body that folds a resolved carry into
//! a zero-carry piece. The bottom half is the single-pass chained
//! engine, whose decoupled look-back resolves carries through a
//! [`BlockBoard`] with no phase barrier.
//!
//! [`ExternalCarry`] is deliberately a plain owned buffer with a
//! little-endian wire format: it is the serialization seam the tiled
//! streaming mode hands across band boundaries today, and the one a
//! LASP-2-style multi-node split would hand across processes tomorrow.

use super::chunk::{scan_piece_into, scan_piece_into_bf16, segment_bounds};
use super::drain::drain_scatter;
use super::pack::{StagedTaps, TapView, SLAB};
#[cfg(test)]
use super::test_hooks;
use super::{out_tensor, DirInput};
use crate::scan::simd::{self, bf16_narrow, bf16_widen, Precision};
use crate::tensor::Tensor;
use crate::util::workspace::{
    BlockBoard, BufferPool, Lease, BLOCK_AGG, BLOCK_POISONED, BLOCK_PREFIX,
};
use crate::util::{lock_unpoisoned, ThreadPool};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

// ---------------------------------------------------------------------
// CarrySource: where a pass's entry carry comes from
// ---------------------------------------------------------------------

/// Per-plane entry/exit carry columns of ONE direction of one band —
/// the cross-band (and, later, cross-process) hand-off of the tiled
/// streaming mode. `data` is plane-major: plane `p`'s column is
/// `data[p*hc..(p+1)*hc]`. Deliberately a plain owned `Vec` rather than
/// a pooled lease: a carry set is `nplanes * hc` floats (KiB-scale), it
/// lives *across* band executions (a lease would pin pool classes
/// across the very boundary tiling exists to bound — excluded from pool
/// accounting by design), and it is the object a multi-node LASP-2
/// split would serialize — see [`ExternalCarry::to_bytes`].
#[derive(Clone, Debug, PartialEq)]
pub struct ExternalCarry {
    hc: usize,
    nplanes: usize,
    data: Vec<f32>,
}

impl ExternalCarry {
    /// All-zero carries: the state before the first band (the full
    /// geometry's column 0 scans from zero, exactly like untiled).
    pub fn zeros(hc: usize, nplanes: usize) -> ExternalCarry {
        ExternalCarry { hc, nplanes, data: vec![0.0; hc * nplanes] }
    }

    pub fn hc(&self) -> usize {
        self.hc
    }

    pub fn nplanes(&self) -> usize {
        self.nplanes
    }

    /// Plane `p`'s carry column.
    pub fn column(&self, p: usize) -> &[f32] {
        &self.data[p * self.hc..(p + 1) * self.hc]
    }

    pub(crate) fn column_mut(&mut self, p: usize) -> &mut [f32] {
        &mut self.data[p * self.hc..(p + 1) * self.hc]
    }

    /// Per-plane columns, mutably — lets a parallel band run hand each
    /// plane job its own (disjoint) exit column.
    pub(crate) fn columns_mut(&mut self) -> impl Iterator<Item = &mut [f32]> {
        self.data.chunks_mut(self.hc.max(1))
    }

    /// Serialize as `[hc: u32 LE][nplanes: u32 LE][data: f32 LE ...]` —
    /// the wire format a cross-process band hand-off sends. f32 bits
    /// round-trip exactly, so a deserialized carry seeds a bit-identical
    /// continuation.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 4 * self.data.len());
        out.extend_from_slice(&(self.hc as u32).to_le_bytes());
        out.extend_from_slice(&(self.nplanes as u32).to_le_bytes());
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Inverse of [`ExternalCarry::to_bytes`]; `None` on a malformed
    /// buffer.
    pub fn from_bytes(bytes: &[u8]) -> Option<ExternalCarry> {
        let hc = u32::from_le_bytes(bytes.get(0..4)?.try_into().ok()?) as usize;
        let nplanes = u32::from_le_bytes(bytes.get(4..8)?.try_into().ok()?) as usize;
        let body = bytes.get(8..)?;
        if body.len() != 4 * hc * nplanes {
            return None;
        }
        let data =
            body.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        Some(ExternalCarry { hc, nplanes, data })
    }
}

/// Where a pass obtains the carry that *enters* its first column — the
/// seam every engine strategy now shares. The contract: [`seed`] writes
/// the entry carry into the caller's column (returning whether it
/// seeded at all), and the caller applies the reference decomposition's
/// all-zero *skip* afterwards — a seeded-but-zero carry must behave
/// exactly like [`CarrySource::Zero`], which keeps even -0.0 pixels
/// bit-identical to the untiled scan.
///
/// [`seed`]: CarrySource::seed
#[derive(Clone, Copy)]
pub(crate) enum CarrySource<'a> {
    /// The true origin of the scan axis: nothing precedes this pass.
    Zero,
    /// A caller-tracked, already-resolved carry column.
    Resolved(&'a [f32]),
    /// The published inclusive prefix of block `.1` on a publication
    /// board — the chained engine's decoupled hand-off. The block must
    /// have reached `BLOCK_PREFIX`; the caller owns that rendezvous.
    Lookback(&'a BlockBoard<'a>, usize),
    /// Plane `.1`'s column of a (de)serialized band/shard hand-off.
    External(&'a ExternalCarry, usize),
}

impl CarrySource<'_> {
    /// Seed `dst` with the entry carry. Returns `false` for
    /// [`CarrySource::Zero`] with `dst` untouched (the zero-carry fast
    /// path stays byte-identical to the pre-refactor engines), `true`
    /// otherwise.
    pub(crate) fn seed(&self, dst: &mut [f32]) -> bool {
        match *self {
            CarrySource::Zero => false,
            CarrySource::Resolved(col) => {
                let n = dst.len();
                dst.copy_from_slice(&col[..n]);
                true
            }
            CarrySource::Lookback(board, bidx) => {
                board.read_prefix(bidx, dst);
                true
            }
            CarrySource::External(ec, p) => {
                let n = dst.len();
                dst.copy_from_slice(&ec.column(p)[..n]);
                true
            }
        }
    }
}

// ---------------------------------------------------------------------
// The shared correction body
// ---------------------------------------------------------------------

/// The one shared carry-correction body: add the linear correction scan
/// seeded by `cin` onto segment columns `[lo, hi)` held in `seg`
/// (column-major within the segment), dying at chunk resets. Callers
/// own the zero-carry skip (the reference decomposition elides all-zero
/// corrections, which keeps even -0.0 pixels bit-identical).
#[allow(clippy::too_many_arguments)]
pub(crate) fn correct_segment<'w>(
    hc: usize,
    chunk: usize,
    lo: usize,
    hi: usize,
    taps: TapView<'_>,
    cin: &[f32],
    corr: &mut Lease<'w>,
    next: &mut Lease<'w>,
    seg: &mut [f32],
) {
    corr[..hc].copy_from_slice(&cin[..hc]);
    for (j, gi) in (lo..hi).enumerate() {
        if gi % chunk == 0 {
            // Chunk reset: the carry dies here and phase 1 was already
            // exact from this column on.
            break;
        }
        simd::correct_col(&corr[..hc], taps.col(gi, hc), &mut next[..hc]);
        for (o, &v) in seg[j * hc..(j + 1) * hc].iter_mut().zip(&next[..hc]) {
            *o += v;
        }
        std::mem::swap(corr, next);
    }
}

/// [`correct_segment`] over a bf16-stored segment: the correction
/// recurrence itself runs in f32 (it never reads panel values), and
/// each corrected element decodes, adds in f32, and re-encodes with
/// round-to-nearest-even — the chained engine's reduced-precision
/// panel path. Chunk-reset and zero-carry semantics are identical to
/// the f32 body.
#[allow(clippy::too_many_arguments)]
fn correct_segment_bf16<'w>(
    hc: usize,
    chunk: usize,
    lo: usize,
    hi: usize,
    taps: TapView<'_>,
    cin: &[f32],
    corr: &mut Lease<'w>,
    next: &mut Lease<'w>,
    seg: &mut [u16],
) {
    corr[..hc].copy_from_slice(&cin[..hc]);
    for (j, gi) in (lo..hi).enumerate() {
        if gi % chunk == 0 {
            // Chunk reset: the carry dies here and phase 1 was already
            // exact from this column on.
            break;
        }
        simd::correct_col(&corr[..hc], taps.col(gi, hc), &mut next[..hc]);
        for (o, &v) in seg[j * hc..(j + 1) * hc].iter_mut().zip(&next[..hc]) {
            *o = bf16_narrow(bf16_widen(*o) + v);
        }
        std::mem::swap(corr, next);
    }
}

// ---------------------------------------------------------------------
// Single-pass chained engine (decoupled look-back)
// ---------------------------------------------------------------------

thread_local! {
    /// The chained-scan helping bound of the current thread: while a
    /// chunk job is on the stack, a wait loop inside it may only
    /// claim-and-run jobs with a *strictly lower* claim index. The
    /// nested-job stack is therefore strictly decreasing in claim
    /// index, so helping can never re-enter (or transitively depend
    /// on) the job that is waiting — the deadlock an unbounded
    /// work-steal here would hit. Fresh pool tickets start unbounded
    /// (`usize::MAX`).
    static CHAIN_BOUND: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Scoped setter for [`CHAIN_BOUND`]: restores the previous bound on
/// drop, including during unwinding (a panicking chunk must not leave
/// a stale bound on a pool worker's thread-local).
struct BoundGuard {
    prev: usize,
}

impl BoundGuard {
    fn set(j: usize) -> BoundGuard {
        BoundGuard { prev: CHAIN_BOUND.with(|b| b.replace(j)) }
    }
}

impl Drop for BoundGuard {
    fn drop(&mut self) {
        CHAIN_BOUND.with(|b| b.set(self.prev));
    }
}

/// Claim the lowest unclaimed job with index `< bound`. Lowest-first
/// matches the claim order's topology (see [`run_engine_chained`]), so
/// a fresh runner always picks a job whose predecessors are already
/// claimed or complete, and a blocked job only helps jobs it can never
/// transitively wait on.
fn chain_claim(claimed: &[AtomicBool], bound: usize) -> Option<usize> {
    let n = claimed.len().min(bound);
    (0..n).find(|&j| {
        !claimed[j].load(Ordering::Relaxed)
            && claimed[j]
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
    })
}

/// Whether a chunk reset (`gi % chunk == 0`) lands inside block columns
/// `[lo, hi)`. If so, any incoming carry dies before the block's last
/// column, its inclusive prefix equals its zero-carry aggregate no
/// matter what precedes it, and a look-back can terminate there.
fn chain_broken(lo: usize, hi: usize, chunk: usize) -> bool {
    lo.div_ceil(chunk) * chunk < hi
}

/// One (plane, direction, segment) chunk of the chained engine, plus
/// its publication-board block index.
struct ChainJob {
    p: usize,
    k: usize,
    si: usize,
    lo: usize,
    hi: usize,
    bidx: usize,
}

/// Shared state of one chained-engine call: the job table in claim
/// order, the claim flags, the publication board, the merge-order
/// drain counters, and the per-plane output slots.
struct ChainState<'e, 'w> {
    dirs: &'e [DirInput<'e>],
    staged: &'e [StagedTaps<'w>],
    wts: Option<&'e [f32; 4]>,
    gain: Option<&'e [f32]>,
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    bounds: &'e [Vec<(usize, usize)>],
    jobs: Vec<ChainJob>,
    claimed: Vec<AtomicBool>,
    /// Completed-drain counters per `(plane, direction)` — the
    /// merge-order gate of merged passes: direction k's chunks scatter
    /// only after all `bounds[k-1].len()` chunks of the same plane
    /// drained, preserving the fixed k = 0..4 accumulation order.
    drained: Vec<AtomicUsize>,
    board: BlockBoard<'e>,
    os_slots: Vec<Mutex<&'e mut [f32]>>,
    /// Call-wide abort flag: set (with the block poisoned) by any
    /// panicking chunk so every spinning waiter unwinds instead of
    /// waiting on a publication that will never come.
    poisoned: AtomicBool,
    pool: Option<&'e ThreadPool>,
    ws: &'w BufferPool,
    /// Storage precision of the job-local panels (the staged taps carry
    /// their own): [`Precision::Bf16`] halves the retained bytes while
    /// the recurrence and the publication board stay f32.
    prec: Precision,
    /// External entry carries seeding every plane's first block — the
    /// tiled mode's band hand-off ([`ChainOpts::entry`]). `None` in a
    /// whole-axis run (block 0 scans from the true zero origin).
    entry: Option<&'e ExternalCarry>,
    /// Global `(direction, last)` epilogue indices when this call runs a
    /// single direction of a larger pass ([`ChainOpts::ep`]); `None`
    /// uses the local indices.
    ep: Option<(usize, usize)>,
}

impl ChainState<'_, '_> {
    /// Wait until `pred` holds, productively: claim-and-run another
    /// chain job below the current helping bound, or assist the pool's
    /// global queue, before falling back to spin/yield. Panics
    /// (unwinding the waiting job) once any chunk of this call has
    /// poisoned the board.
    fn wait_until(&self, what: &str, pred: impl Fn(&Self) -> bool) {
        let mut spins = 0u32;
        while !pred(self) {
            if self.poisoned.load(Ordering::Acquire) {
                panic!("chained scan: waiting on {what}, but a chunk panicked");
            }
            let bound = CHAIN_BOUND.with(|b| b.get());
            if let Some(j) = chain_claim(&self.claimed, bound) {
                run_chain_job(self, j);
            } else if self.pool.map_or(false, |p| p.try_assist()) {
                spins = 0;
            } else {
                spins += 1;
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// One chained runner: claim the lowest unclaimed job under the
/// thread's current helping bound and run it, until nothing claimable
/// remains. Fresh pool tickets run unbounded; a runner ticket executed
/// from inside a blocked job's wait loop (via
/// [`ThreadPool::try_assist`]) inherits that job's bound and may exit
/// early — the caller's mop-up pass finishes the tail.
fn chain_runner(st: &ChainState<'_, '_>) {
    loop {
        let bound = CHAIN_BOUND.with(|b| b.get());
        match chain_claim(&st.claimed, bound) {
            Some(j) => run_chain_job(st, j),
            None => break,
        }
    }
}

/// Run one claimed chain job with the helping bound scoped to its claim
/// index, and panic containment: a panicking chunk poisons its board
/// block and the call-wide flag — so look-back waiters unwind through
/// the normal panic path instead of deadlocking on a publication that
/// will never arrive — then rethrows for the pool to collect as a
/// `MapError`.
fn run_chain_job(st: &ChainState<'_, '_>, j: usize) {
    let _bound = BoundGuard::set(j);
    if let Err(payload) =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| chain_job_body(st, j)))
    {
        st.board.poison(st.jobs[j].bidx);
        st.poisoned.store(true, Ordering::Release);
        std::panic::resume_unwind(payload);
    }
}

/// The single-pass chunk body: scan once from a zero carry into
/// job-local scratch, publish the aggregate, resolve the true incoming
/// carry by decoupled look-back, fold the correction into the still
/// cache-hot local panel, publish the inclusive prefix, and scatter the
/// corrected panel through the unchanged fused epilogue. No phase
/// barrier, no retained panel array, no second DRAM read of the panel.
fn chain_job_body(st: &ChainState<'_, '_>, j: usize) {
    let &ChainJob { p, k, si, lo, hi, bidx } = &st.jobs[j];
    let di = &st.dirs[k];
    let hc = di.taps.h;
    let chunk = di.chunk;
    let (h, w) = st.hw;
    let seglen = hi - lo;
    let taps = st.staged[k].panels(p / st.c, p % st.c);
    let bf16 = st.prec == Precision::Bf16;
    // Job-local panel — half-width (packed bf16 words in the f32 lease)
    // in reduced-precision mode, fully overwritten by the scan below.
    // Leased before the (test-only) fault hook so an injected panic
    // unwinds while scratch is out on lease — the leak test covers the
    // window that matters.
    let mut panel = if bf16 {
        st.ws.acquire(simd::bf16_len(seglen * hc))
    } else {
        st.ws.acquire(seglen * hc)
    };
    // The f32 aggregate column of a bf16 chunk: the recurrence runs in
    // f32 (only the *stored* panel narrows), so the board still carries
    // full-precision columns and the look-back fold loses nothing.
    let mut aggbuf = bf16.then(|| st.ws.acquire(st.hmax));
    #[cfg(test)]
    test_hooks::maybe_panic(p, k, lo, hi);
    match aggbuf.as_mut() {
        Some(agg) => {
            scan_piece_into_bf16(
                st.dirs,
                st.staged,
                st.c,
                (h, w),
                st.hmax,
                p,
                k,
                lo,
                hi,
                &mut panel.as_u16_mut()[..seglen * hc],
                &mut agg[..hc],
                st.ws,
            );
            // Publish the zero-carry aggregate (the chunk's last
            // column) immediately: successors' look-backs can fold over
            // it while this chunk is still resolving its own carry.
            st.board.publish_agg(bidx, &agg[..hc]);
        }
        None => {
            scan_piece_into(
                st.dirs, st.staged, st.c, (h, w), st.hmax, p, k, lo, hi, &mut panel, st.ws,
            );
            st.board.publish_agg(bidx, &panel[(seglen - 1) * hc..]);
        }
    }

    // Decoupled look-back: walk predecessor blocks back to the nearest
    // *final* value — a published inclusive PREFIX, block 0 (whose
    // aggregate is its prefix), or a chain-breaker — then fold forward
    // over the skipped blocks' aggregates with the exact
    // `correct_col` recurrence and zero-carry/chunk-reset skips of
    // the two-phase engine, so the resolved carry is bit-identical to
    // the sequentially chained one.
    let mut corr = st.ws.acquire_zeroed(st.hmax);
    let mut next = st.ws.acquire_zeroed(st.hmax);
    let mut carry = st.ws.acquire_zeroed(st.hmax);
    // A nonzero external entry carry means block 0's zero-carry
    // aggregate is NOT its inclusive prefix (its own job corrects it
    // from the band carry first) — look-backs reaching block 0 must
    // then wait for the published PREFIX instead of folding the AGG.
    let entry_seeded =
        st.entry.map_or(false, |ec| !ec.column(p)[..hc].iter().all(|&v| v == 0.0));
    let mut active = false;
    if si == 0 {
        if let Some(ec) = st.entry {
            // Band entry: the previous band's corrected last column
            // seeds this block exactly as an earlier segment's carry
            // would — the reference's all-zero skip applies unchanged.
            CarrySource::External(ec, p).seed(&mut carry[..hc]);
            active = !carry[..hc].iter().all(|&v| v == 0.0);
        }
    } else {
        let sbounds = &st.bounds[k];
        let base = bidx - si; // board index of (p, k, si = 0)
        let mut t = si - 1;
        loop {
            let b = base + t;
            st.wait_until("a predecessor's published column", |s| {
                s.board.state(b) >= BLOCK_AGG
            });
            let state = st.board.state(b);
            assert!(state != BLOCK_POISONED, "chained scan: predecessor chunk panicked");
            if state == BLOCK_PREFIX {
                st.board.read_prefix(b, &mut carry[..hc]);
                break;
            }
            let (tlo, thi) = sbounds[t];
            if chain_broken(tlo, thi, chunk) {
                // A chunk reset inside the block: any incoming carry
                // dies before its last column, so prefix == aggregate
                // no matter what precedes it (seeded bands included).
                st.board.read_agg(b, &mut carry[..hc]);
                break;
            }
            if t == 0 {
                if entry_seeded {
                    st.wait_until("the first block's band-corrected prefix", |s| {
                        s.board.state(b) >= BLOCK_PREFIX
                    });
                    assert!(
                        st.board.state(b) != BLOCK_POISONED,
                        "chained scan: predecessor chunk panicked"
                    );
                    st.board.read_prefix(b, &mut carry[..hc]);
                } else {
                    // No entry carry: block 0's aggregate IS its prefix.
                    st.board.read_agg(b, &mut carry[..hc]);
                }
                break;
            }
            t -= 1;
        }
        let mut agg = st.ws.acquire(st.hmax);
        for u in t + 1..si {
            let (ulo, uhi) = sbounds[u];
            let b = base + u;
            assert!(
                st.board.state(b) != BLOCK_POISONED,
                "chained scan: predecessor chunk panicked"
            );
            st.board.read_agg(b, &mut agg[..hc]);
            if carry[..hc].iter().all(|&v| v == 0.0) {
                // Zero incoming carry: block u needed no correction, so
                // its prefix is its aggregate (the reference
                // decomposition's skip — keeps even -0.0 pixels
                // bit-identical).
                carry[..hc].copy_from_slice(&agg[..hc]);
                continue;
            }
            // The carry is the full corrected value of column ulo - 1
            // (phase 1 scanned from zero there), so it seeds the linear
            // correction directly — the same association
            // [`correct_segment`] walks, minus the panel adds.
            corr[..hc].copy_from_slice(&carry[..hc]);
            let mut died = false;
            for gi in ulo..uhi {
                if gi % chunk == 0 {
                    died = true;
                    break;
                }
                simd::correct_col(&corr[..hc], taps.col(gi, hc), &mut next[..hc]);
                std::mem::swap(&mut corr, &mut next);
            }
            if died {
                carry[..hc].copy_from_slice(&agg[..hc]);
            } else {
                // prefix_u = agg_u + corr(last column): the identical
                // f32 add [`drain_dir_fused`] performs on the panel's
                // last column.
                for ((cv, &av), &co) in
                    carry[..hc].iter_mut().zip(&agg[..hc]).zip(&corr[..hc])
                {
                    *cv = av + co;
                }
            }
        }
        active = !carry[..hc].iter().all(|&v| v == 0.0);
    }

    // Fold the resolved carry into the job-local panel while it is
    // still cache-hot — exactly the two-pass correction arithmetic
    // (`phase1 + corr`, dying at chunk resets; bf16 panels decode, add
    // in f32, and re-encode per element).
    if active {
        match aggbuf.as_mut() {
            Some(_) => correct_segment_bf16(
                hc,
                chunk,
                lo,
                hi,
                taps,
                &carry,
                &mut corr,
                &mut next,
                &mut panel.as_u16_mut()[..seglen * hc],
            ),
            None => correct_segment(
                hc, chunk, lo, hi, taps, &carry, &mut corr, &mut next, &mut panel,
            ),
        }
    }

    // Publish the inclusive prefix (the corrected last column) BEFORE
    // the merge-order gate: successors' look-backs terminate here even
    // while this chunk is queued behind the previous direction's
    // drains.
    match aggbuf.as_mut() {
        Some(agg) => {
            if active {
                // Decode the corrected bf16 last column; an uncorrected
                // chunk republishes its exact f32 aggregate instead
                // (prefix == aggregate, bit for bit, as in f32 mode).
                let last = &panel.as_u16()[(seglen - 1) * hc..seglen * hc];
                for (o, &v) in agg[..hc].iter_mut().zip(last) {
                    *o = bf16_widen(v);
                }
            }
            st.board.publish_prefix(bidx, &agg[..hc]);
        }
        None => st.board.publish_prefix(bidx, &panel[(seglen - 1) * hc..]),
    }

    // Merged passes: direction k's contributions land on the shared
    // output plane only after every direction-(k-1) chunk of the same
    // plane has drained — the fixed k = 0..4 merge order the serial
    // reference accumulates in.
    let ndirs = st.dirs.len();
    if k > 0 {
        let want = st.bounds[k - 1].len();
        let gate = p * ndirs + (k - 1);
        st.wait_until("the previous direction's drains", |s| {
            s.drained[gate].load(Ordering::Acquire) >= want
        });
    }

    // Pure scatter of the already-corrected panel through the shared
    // epilogue op — no correction work happens under the plane lock.
    // bf16 panels decode slab-by-slab into an f32 staging slab (leased
    // before the lock) so the scatter arms stay f32-only.
    {
        let mut dec = bf16.then(|| st.ws.acquire(SLAB * hc.max(1)));
        let gain = st.gain.map(|g| g[p % st.c]);
        // Epilogue indices: a band call runs ONE direction of a larger
        // merged pass, so the op selection (assign vs merge vs
        // merge+gain) must use the pass-global (k, last), not this
        // call's local ones.
        let (gk, glast) = st.ep.unwrap_or((k, ndirs - 1));
        let mut guard = lock_unpoisoned(&st.os_slots[p]);
        let os: &mut [f32] = &mut guard;
        let mut j0 = 0;
        while j0 < seglen {
            let sw = SLAB.min(seglen - j0);
            let hs: &[f32] = match dec.as_mut() {
                Some(dec) => {
                    let words = &panel.as_u16()[j0 * hc..(j0 + sw) * hc];
                    for (o, &v) in dec[..sw * hc].iter_mut().zip(words) {
                        *o = bf16_widen(v);
                    }
                    &dec[..sw * hc]
                }
                None => &panel[j0 * hc..(j0 + sw) * hc],
            };
            drain_scatter(hs, h, w, di.d, lo + j0, sw, hc, os, st.wts, gk, glast, gain);
            j0 += sw;
        }
    }
    st.drained[p * ndirs + k].fetch_add(1, Ordering::Release);
}

/// The single-pass chained engine ([`ScanStrategy::Chained`]): the same
/// (plane, direction, segment) decomposition as the segmented engine,
/// but each chunk is ONE self-contained job — scan from a zero carry,
/// publish the aggregate, resolve the true carry by decoupled look-back
/// over a publication board ([`BlockBoard`]), correct in place while
/// the panel is L2-hot, publish the inclusive prefix, drain through the
/// unchanged fused epilogue. What the two-phase engines pay and this
/// one does not: the global phase rendezvous (barrier) or dependency-
/// graph machinery (wavefront), the retained-panel array and its extra
/// DRAM round trip, and the per-piece lease hand-offs.
///
/// Bit-exactness: chunk bounds come from the same [`segment_bounds`],
/// phase-1 arithmetic is the shared [`scan_piece_into`], and the
/// look-back fold replays the exact `correct_col` recurrence order
/// with the reference's zero-carry and chunk-reset skips — so the
/// resolved carry, the corrected panel, and hence every output bit
/// match `scan_l2r_split` and the segmented engine exactly (validated
/// bitwise against a two-phase mirror over ~9.4k randomized
/// geometry/chunk/zero-carry cases before porting, and pinned `==` by
/// the chained property suite).
///
/// Scheduling: jobs are claimed lowest-index-first from a direction-
/// major (k, p, si) order — a valid topological order of the chain's
/// dependencies, since block (p, k, si) waits only on (p, k, < si)
/// (look-back) and (p, k-1, *) (merge-order gate). A blocked chunk
/// helps by claiming jobs strictly below its own index
/// ([`CHAIN_BOUND`]), assists the pool's global queue, or spins;
/// deadlock-freedom follows by induction on the lowest incomplete
/// index. On a serial pool the claim order degrades to the plain
/// sequential two-phase order, every wait instantly satisfied.
/// Band/hand-off options for [`run_engine_chained`] — all `None` for a
/// whole-axis call (the plain `ScanStrategy::Chained` path). The Tiled
/// engine sets them to run one direction's band of pieces between two
/// [`ExternalCarry`] hand-offs; `band`/`entry`/`exit`/`ep` are only
/// meaningful on a single-direction call (`dirs.len() == 1`).
#[derive(Default)]
pub(crate) struct ChainOpts<'a> {
    /// Run only pieces `[b0, b1)` of the direction's segment list.
    pub(crate) band: Option<(usize, usize)>,
    /// Entry carries seeding each plane's first piece (si = 0).
    pub(crate) entry: Option<&'a ExternalCarry>,
    /// Receives each plane's corrected last column on return — the next
    /// band's `entry`.
    pub(crate) exit: Option<&'a mut ExternalCarry>,
    /// Pass-global `(direction, last)` epilogue indices.
    pub(crate) ep: Option<(usize, usize)>,
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine_chained(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    segments: usize,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
    prec: Precision,
    opts: ChainOpts<'_>,
) -> Tensor {
    let mut out = out_tensor(out_shape, out_buf);
    run_engine_chained_into(
        dirs, staged, wts, gain, out_shape, pool, segments, ws, prec, opts, &mut out.data,
    );
    out
}

/// [`run_engine_chained`] writing into a caller-owned output slice — the
/// Tiled engine's per-band entry (bands accumulate into ONE shared
/// output tensor across calls, so the engine cannot own it).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_engine_chained_into(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    segments: usize,
    ws: &BufferPool,
    prec: Precision,
    opts: ChainOpts<'_>,
    out_data: &mut [f32],
) {
    debug_assert!(
        opts.band.is_none() && opts.entry.is_none() && opts.exit.is_none() && opts.ep.is_none()
            || dirs.len() == 1,
        "chained band options require a single-direction call"
    );
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = out_shape[0] * c;
    let hmax = h.max(w);
    let bounds: Vec<Vec<(usize, usize)>> = dirs
        .iter()
        .map(|di| {
            let b = segment_bounds(di.taps.w, segments);
            match opts.band {
                Some((b0, b1)) => b[b0.min(b.len())..b1.min(b.len())].to_vec(),
                None => b,
            }
        })
        .collect();
    let seg_off: Vec<usize> = bounds
        .iter()
        .scan(0usize, |acc, b| {
            let o = *acc;
            *acc += b.len();
            Some(o)
        })
        .collect();
    let per_plane: usize = bounds.iter().map(|b| b.len()).sum();
    let total_blocks = nplanes * per_plane;
    // Publication board payload: one pooled lease holding an
    // [aggregate | prefix] column pair per block. Every slot range is
    // fully written before its state permits a read, so the lease is
    // not zero-reset.
    let mut board_payload = ws.acquire(2 * hmax * total_blocks);
    let board = BlockBoard::new(&mut board_payload, total_blocks, hmax);
    // Claim order (k, p, si), direction-major: dependencies of every
    // job sit at strictly lower indices, and ordering directions
    // outermost keeps every plane's direction-k chain moving instead of
    // camping all workers on one plane's serial look-back chain.
    let mut jobs = Vec::with_capacity(total_blocks);
    for (k, b) in bounds.iter().enumerate() {
        for p in 0..nplanes {
            for (si, &(lo, hi)) in b.iter().enumerate() {
                jobs.push(ChainJob { p, k, si, lo, hi, bidx: p * per_plane + seg_off[k] + si });
            }
        }
    }
    let njobs = jobs.len();
    let st = ChainState {
        dirs,
        staged,
        wts,
        gain,
        c,
        hw: (h, w),
        hmax,
        bounds: &bounds,
        jobs,
        claimed: (0..njobs).map(|_| AtomicBool::new(false)).collect(),
        drained: (0..nplanes * dirs.len()).map(|_| AtomicUsize::new(0)).collect(),
        board,
        os_slots: out_data.chunks_mut(plane).map(Mutex::new).collect(),
        poisoned: AtomicBool::new(false),
        pool: pool.filter(|p| p.threads() > 1 && njobs > 1),
        ws,
        prec,
        entry: opts.entry,
        ep: opts.ep,
    };
    match st.pool {
        Some(pool) => {
            // min(threads, jobs) self-scheduling runner tickets; the
            // caller participates through `try_map`'s own-call helping.
            let runners: Vec<usize> = (0..pool.threads().min(njobs)).collect();
            if let Err(e) = pool.try_map(runners, |_| chain_runner(&st)) {
                std::panic::resume_unwind(e.into_payload());
            }
            // A runner ticket drained from inside a blocked job's wait
            // loop inherits that job's helping bound and may have
            // exited early; one unbounded mop-up pass completes any
            // unclaimed tail.
            chain_runner(&st);
        }
        // Serial path: claim in order on the caller thread — every
        // wait's predecessor has already completed, so the chain
        // degrades to the plain sequential two-phase order, bit for
        // bit and with a deterministic lease sequence.
        None => chain_runner(&st),
    }
    if let Some(exit) = opts.exit {
        // The band's outgoing carry: each plane's corrected last column
        // — the inclusive prefix of its last block, read through the
        // same [`CarrySource`] plumbing a successor band seeds from.
        // Every block reached `BLOCK_PREFIX` above (a panic resumed
        // before this point), so the reads are immediate.
        let hc = dirs[0].taps.h;
        for p in 0..nplanes {
            CarrySource::Lookback(&st.board, p * per_plane + (per_plane - 1))
                .seed(&mut exit.column_mut(p)[..hc]);
        }
    }
    drop(st);
}
