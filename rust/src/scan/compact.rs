//! Compact channel propagation (§4.2): channel-shared weights + the
//! compressive proxy dimension, as a pure-Rust unit.
//!
//! Pipeline (mirroring `python/compile/model.py::gspn_unit`):
//!
//!   x (N,C,H,W) --1x1--> proxy (N,Cp,H,W)
//!     --taps/lam from 1x1 convs--> 4 directional scans (shared w_i)
//!     --softmax merge--> u ⊙ · --1x1--> back to (N,C,H,W)
//!
//! This is the CPU-reference twin of the L2 unit: integration tests check
//! it behaves like the JAX path structurally (receptive field, proxy-dim
//! ablation trends), and the param accounting in `crate::model` uses its
//! shapes. It is also what the quickstart example runs without artifacts.

use super::direction::{from_canonical, to_canonical, DIRECTIONS};
use super::taps::Taps;
use crate::tensor::Tensor;
use crate::util::{Rng, ThreadPool};

/// Pointwise (1x1) channel projection: weight (Cout, Cin), bias (Cout).
#[derive(Clone, Debug)]
pub struct Proj {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub cin: usize,
    pub cout: usize,
}

impl Proj {
    pub fn init(rng: &mut Rng, cin: usize, cout: usize) -> Proj {
        let std = (2.0 / cin as f32).sqrt();
        Proj { w: rng.normal_vec(cin * cout, std), b: vec![0.0; cout], cin, cout }
    }

    pub fn params(&self) -> usize {
        self.w.len() + self.b.len()
    }

    /// Apply to (N, Cin, H, W) -> (N, Cout, H, W).
    ///
    /// The (n, cout) output-plane loop fans out over the shared
    /// [`ThreadPool`] in block-granular jobs (serial below a small work
    /// floor where pool dispatch would dominate), and the spatial axis is
    /// cache-blocked so each output tile stays L1-resident across the
    /// whole `cin` accumulation instead of streaming `cin` full planes
    /// through it. Accumulation order per element (bias, then `ci`
    /// ascending) is unchanged, so results are bit-identical to the old
    /// serial triple loop.
    pub fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.cin, "channel mismatch");
        let (n, _, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let plane = h * w;
        let mut out = Tensor::zeros(&[n, self.cout, h, w]);
        let nplanes = n * self.cout;
        if nplanes == 0 || plane == 0 {
            return out;
        }
        let pool = ThreadPool::global();
        // Pool fan-out pays only when there is real work to split.
        const MIN_PAR_MADDS: usize = 1 << 15;
        let nblocks = super::fused::plane_blocks(nplanes, pool.threads());
        if nblocks <= 1
            || pool.threads() <= 1
            || nplanes * plane * self.cin.max(1) < MIN_PAR_MADDS
        {
            for (p, os) in out.data.chunks_mut(plane).enumerate() {
                self.apply_plane(x, p / self.cout, p % self.cout, plane, os);
            }
            return out;
        }
        let per_block = nplanes.div_ceil(nblocks);
        let jobs: Vec<(usize, &mut [f32])> =
            out.data.chunks_mut(per_block * plane).enumerate().collect();
        pool.map(jobs, |(b, block)| {
            for (j, os) in block.chunks_mut(plane).enumerate() {
                let p = b * per_block + j;
                self.apply_plane(x, p / self.cout, p % self.cout, plane, os);
            }
        });
        out
    }

    /// One (ni, co) output plane: bias fill, then the `cin` reduction
    /// over cache-blocked spatial tiles.
    fn apply_plane(&self, x: &Tensor, ni: usize, co: usize, plane: usize, os: &mut [f32]) {
        // Spatial tile (f32 elements) kept hot across the cin loop:
        // 16 KB out-tile + one 16 KB in-tile per step fits L1/L2 with
        // room for the weight row.
        const KTILE: usize = 4096;
        os.iter_mut().for_each(|v| *v = self.b[co]);
        let wrow = &self.w[co * self.cin..(co + 1) * self.cin];
        let mut k0 = 0;
        while k0 < plane {
            let k1 = (k0 + KTILE).min(plane);
            for (ci, &wv) in wrow.iter().enumerate() {
                if wv == 0.0 {
                    continue;
                }
                let ibase = (ni * self.cin + ci) * plane;
                let xt = &x.data[ibase + k0..ibase + k1];
                for (o, &xv) in os[k0..k1].iter_mut().zip(xt) {
                    *o += wv * xv;
                }
            }
            k0 = k1;
        }
    }
}

/// The compact GSPN unit with owned parameters.
#[derive(Clone, Debug)]
pub struct CompactGspnUnit {
    pub c: usize,
    pub c_proxy: usize,
    pub kchunk: usize,
    /// Per-channel taps (GSPN-1 semantics) instead of shared (GSPN-2).
    pub per_channel: bool,
    pub down: Proj,
    pub up: Proj,
    /// One taps-producing and one lam-producing projection per direction.
    pub taps_proj: Vec<Proj>,
    pub lam_proj: Vec<Proj>,
    pub u: Vec<f32>,
    pub merge: [f32; 4],
}

impl CompactGspnUnit {
    pub fn init(rng: &mut Rng, c: usize, c_proxy: usize, kchunk: usize, per_channel: bool) -> Self {
        let cw = if per_channel { c_proxy } else { 1 };
        CompactGspnUnit {
            c,
            c_proxy,
            kchunk,
            per_channel,
            down: Proj::init(rng, c, c_proxy),
            up: Proj::init(rng, c_proxy, c),
            taps_proj: (0..4).map(|_| Proj::init(rng, c_proxy, 3 * cw)).collect(),
            lam_proj: (0..4).map(|_| Proj::init(rng, c_proxy, c_proxy)).collect(),
            u: vec![1.0; c_proxy],
            merge: [0.0; 4],
        }
    }

    pub fn param_count(&self) -> usize {
        self.down.params()
            + self.up.params()
            + self.taps_proj.iter().map(|p| p.params()).sum::<usize>()
            + self.lam_proj.iter().map(|p| p.params()).sum::<usize>()
            + self.u.len()
            + self.merge.len()
    }

    /// Per-direction canonical activations + normalized taps — the stage
    /// shared by the fused forward and the reference composition. Lambda
    /// per direction must follow canonical orientation: the projections
    /// operate on the reoriented feature map, so taps and lam come out in
    /// canonical layout per direction (lam differs per direction).
    fn project_directions(&self, xp: &Tensor) -> Vec<(Tensor, Taps, Tensor)> {
        let cw = if self.per_channel { self.c_proxy } else { 1 };
        ThreadPool::global().map((0..4usize).collect(), |k| {
            let d = DIRECTIONS[k];
            let xc = to_canonical(xp, d);
            let raw = self.taps_proj[k].apply(&xc); // (N, 3*cw, Hc, Wc)
            let (n, _, hc, wc) = (raw.shape[0], raw.shape[1], raw.shape[2], raw.shape[3]);
            let taps = Taps::normalize(&raw.reshape(&[n, cw, 3, hc, wc]));
            let lamc = self.lam_proj[k].apply(&xc);
            (xc, taps, lamc)
        })
    }

    /// Forward through the column-staged fused engine: after the
    /// per-direction projections, the pack → 4-direction scan → softmax
    /// merge → `u ⊙ h` modulation all run as one fused pass
    /// ([`super::fused::fused_merged_canonical`]) — no directional scan
    /// output, `from_canonical` copy, merged intermediate, or modulation
    /// clone is ever materialized. How the pass decomposes over the pool
    /// is the execution planner's call ([`super::plan::plan_scan`]):
    /// bit-identical to [`Self::forward_ref`] (pinned by tests) under
    /// both bit-exact strategies — plane-parallel, and the mid-occupancy
    /// per-direction fan (`DirFan`, wavefront-scheduled with one drain
    /// continuation per direction). Only a low-occupancy forward wide
    /// enough to segment (canonical widths ≥ 2 ·
    /// [`super::plan::MIN_SEG_COLS`] = 128) follows the
    /// `scan_l2r_split` reference arithmetic instead
    /// (±1e-4-equivalent).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_ws(x, crate::util::BufferPool::global())
    }

    /// [`Self::forward`] drawing all fused-pass scratch from an explicit
    /// workspace instead of the process-global pool. The serving
    /// coordinator calls this with its per-instance pool so the
    /// allocation-free invariant (and its miss counters) stay isolated
    /// per coordinator; results are bit-identical to [`Self::forward`].
    pub fn forward_ws(&self, x: &Tensor, ws: &crate::util::BufferPool) -> Tensor {
        assert_eq!(x.shape[1], self.c);
        let xp = self.down.apply(x);
        let dirs = self.project_directions(&xp);
        let merged = super::fused::fused_merged_canonical_ws(
            [&dirs[0].0, &dirs[1].0, &dirs[2].0, &dirs[3].0],
            [&dirs[0].1, &dirs[1].1, &dirs[2].1, &dirs[3].1],
            [&dirs[0].2, &dirs[1].2, &dirs[2].2, &dirs[3].2],
            &self.merge,
            &self.u,
            self.kchunk,
            &xp.shape,
            ThreadPool::global(),
            ws,
        );
        self.up.apply(&merged)
    }

    /// The pre-fusion reference composition (directional scans through
    /// `scan_l2r_pool`, explicit `from_canonical`, separate merge and
    /// modulation passes). Kept as the bit-exact ground truth
    /// [`Self::forward`] is pinned against.
    pub fn forward_ref(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.c);
        let xp = self.down.apply(x);
        let pool = ThreadPool::global();
        let dirs = self.project_directions(&xp);
        let ys = pool.map((0..4usize).collect(), |k| {
            let d = DIRECTIONS[k];
            let (xc, taps, lamc) = &dirs[k];
            let hc = super::core::scan_l2r_pool(xc, taps, lamc, self.kchunk, pool);
            from_canonical(&hc, d)
        });

        let wts = super::direction::merge_weights(&self.merge);
        let mut merged = Tensor::zeros(&xp.shape);
        for (k, y) in ys.iter().enumerate() {
            for (o, v) in merged.data.iter_mut().zip(&y.data) {
                *o += wts[k] * v;
            }
        }

        let modulated = super::core::output_modulation_owned(merged, &self.u);
        self.up.apply(&modulated)
    }
}

// Re-export so `merged_4dir` is exercised by the public API too.
pub use super::direction::merged_4dir as merge_directions;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proj_identity() {
        let mut p = Proj::init(&mut Rng::new(0), 3, 3);
        p.w = vec![1., 0., 0., 0., 1., 0., 0., 0., 1.];
        p.b = vec![0.0; 3];
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[2, 3, 4, 4], &mut rng, 1.0);
        assert!(p.apply(&x).allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn proj_shapes_and_bias() {
        let mut p = Proj::init(&mut Rng::new(0), 4, 2);
        p.w = vec![0.0; 8];
        p.b = vec![1.5, -2.0];
        let x = Tensor::zeros(&[1, 4, 3, 3]);
        let y = p.apply(&x);
        assert_eq!(y.shape, vec![1, 2, 3, 3]);
        assert!((y.at(&[0, 0, 1, 1]) - 1.5).abs() < 1e-6);
        assert!((y.at(&[0, 1, 2, 2]) + 2.0).abs() < 1e-6);
    }

    #[test]
    fn unit_preserves_shape() {
        let mut rng = Rng::new(2);
        let unit = CompactGspnUnit::init(&mut rng, 16, 4, 0, false);
        let x = Tensor::randn(&[2, 16, 8, 8], &mut rng, 1.0);
        let y = unit.forward(&x);
        assert_eq!(y.shape, x.shape);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn global_receptive_field() {
        let mut rng = Rng::new(3);
        let unit = CompactGspnUnit::init(&mut rng, 8, 2, 0, false);
        let x = Tensor::randn(&[1, 8, 8, 8], &mut rng, 1.0);
        let mut x2 = x.clone();
        for c in 0..8 {
            *x2.at_mut(&[0, c, 0, 0]) += 5.0;
        }
        let y1 = unit.forward(&x);
        let y2 = unit.forward(&x2);
        let corner_diff: f32 =
            (0..8).map(|c| (y1.at(&[0, c, 7, 7]) - y2.at(&[0, c, 7, 7])).abs()).sum();
        assert!(corner_diff > 1e-6, "corner unaffected: {corner_diff}");
    }

    #[test]
    fn param_count_shrinks_with_proxy() {
        // The §4.2 claim: compact propagation trims parameters.
        let mut rng = Rng::new(4);
        let small = CompactGspnUnit::init(&mut rng, 64, 2, 0, false);
        let big = CompactGspnUnit::init(&mut rng, 64, 32, 0, false);
        assert!(small.param_count() < big.param_count());
    }

    #[test]
    fn per_channel_has_more_params_than_shared() {
        let mut rng = Rng::new(5);
        let shared = CompactGspnUnit::init(&mut rng, 32, 8, 0, false);
        let perch = CompactGspnUnit::init(&mut rng, 32, 8, 0, true);
        assert!(perch.param_count() > shared.param_count());
    }

    #[test]
    fn chunked_unit_runs() {
        let mut rng = Rng::new(6);
        let unit = CompactGspnUnit::init(&mut rng, 8, 2, 4, false);
        let x = Tensor::randn(&[1, 8, 8, 8], &mut rng, 1.0);
        let y = unit.forward(&x);
        assert_eq!(y.shape, x.shape);
    }

    #[test]
    fn fused_forward_bit_identical_to_reference() {
        // The fused scan+merge+modulate path must not change a single
        // bit vs the reference composition — per-channel and shared
        // taps, chunked and global.
        let mut rng = Rng::new(7);
        for (c, cp, kchunk, per_channel) in
            [(16, 4, 0, false), (8, 2, 4, false), (8, 4, 0, true)]
        {
            let unit = CompactGspnUnit::init(&mut rng, c, cp, kchunk, per_channel);
            let x = Tensor::randn(&[2, c, 8, 8], &mut rng, 1.0);
            let fused = unit.forward(&x);
            let reference = unit.forward_ref(&x);
            assert_eq!(fused.data, reference.data, "c{c} p{cp} k{kchunk} pc{per_channel}");
        }
    }

    #[test]
    fn forward_ws_matches_forward_and_reuses_workspace() {
        // An explicit (private) workspace must not change a bit vs the
        // global-pool path, and a warm rerun must lease nothing new.
        let mut rng = Rng::new(9);
        let unit = CompactGspnUnit::init(&mut rng, 8, 4, 0, false);
        let x = Tensor::randn(&[2, 8, 8, 8], &mut rng, 1.0);
        let ws = crate::util::BufferPool::new(usize::MAX);
        let want = unit.forward(&x);
        let cold = unit.forward_ws(&x, &ws);
        assert_eq!(cold.data, want.data);
        let s1 = ws.stats();
        assert_eq!(s1.bytes_leased, 0, "all leases must return");
        let warm = unit.forward_ws(&x, &ws);
        assert_eq!(warm.data, want.data);
        let s2 = ws.stats();
        assert!(s2.hits > s1.hits, "warm pass must reuse pooled buffers");
    }

    #[test]
    fn parallel_proj_bit_identical_to_serial_loop() {
        // Proj::apply fans out over the pool above a work floor; the
        // result must be bit-identical to the naive triple loop.
        let mut rng = Rng::new(8);
        let p = Proj::init(&mut rng, 7, 5);
        let x = Tensor::randn(&[2, 7, 33, 41], &mut rng, 1.0);
        let got = p.apply(&x);
        let mut want = Tensor::zeros(&[2, 5, 33, 41]);
        let plane = 33 * 41;
        for ni in 0..2 {
            for co in 0..5 {
                let obase = (ni * 5 + co) * plane;
                for k in 0..plane {
                    want.data[obase + k] = p.b[co];
                }
                for ci in 0..7 {
                    let wv = p.w[co * 7 + ci];
                    let ibase = (ni * 7 + ci) * plane;
                    for k in 0..plane {
                        want.data[obase + k] += wv * x.data[ibase + k];
                    }
                }
            }
        }
        assert_eq!(got.data, want.data);
    }
}
