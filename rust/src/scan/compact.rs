//! Compact channel propagation (§4.2): channel-shared weights + the
//! compressive proxy dimension, as a pure-Rust unit.
//!
//! Pipeline (mirroring `python/compile/model.py::gspn_unit`):
//!
//!   x (N,C,H,W) --1x1--> proxy (N,Cp,H,W)
//!     --taps/lam from 1x1 convs--> 4 directional scans (shared w_i)
//!     --softmax merge--> u ⊙ · --1x1--> back to (N,C,H,W)
//!
//! This is the CPU-reference twin of the L2 unit: integration tests check
//! it behaves like the JAX path structurally (receptive field, proxy-dim
//! ablation trends), and the param accounting in `crate::model` uses its
//! shapes. It is also what the quickstart example runs without artifacts.

use super::direction::{from_canonical, to_canonical, DIRECTIONS};
use super::taps::Taps;
use crate::tensor::Tensor;
use crate::util::{Rng, ThreadPool};

/// Pointwise (1x1) channel projection: weight (Cout, Cin), bias (Cout).
#[derive(Clone, Debug)]
pub struct Proj {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub cin: usize,
    pub cout: usize,
}

impl Proj {
    pub fn init(rng: &mut Rng, cin: usize, cout: usize) -> Proj {
        let std = (2.0 / cin as f32).sqrt();
        Proj { w: rng.normal_vec(cin * cout, std), b: vec![0.0; cout], cin, cout }
    }

    pub fn params(&self) -> usize {
        self.w.len() + self.b.len()
    }

    /// Apply to (N, Cin, H, W) -> (N, Cout, H, W).
    pub fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.cin, "channel mismatch");
        let (n, _, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let plane = h * w;
        let mut out = Tensor::zeros(&[n, self.cout, h, w]);
        for ni in 0..n {
            for co in 0..self.cout {
                let obase = (ni * self.cout + co) * plane;
                for k in 0..plane {
                    out.data[obase + k] = self.b[co];
                }
                for ci in 0..self.cin {
                    let wv = self.w[co * self.cin + ci];
                    if wv == 0.0 {
                        continue;
                    }
                    let ibase = (ni * self.cin + ci) * plane;
                    for k in 0..plane {
                        out.data[obase + k] += wv * x.data[ibase + k];
                    }
                }
            }
        }
        out
    }
}

/// The compact GSPN unit with owned parameters.
#[derive(Clone, Debug)]
pub struct CompactGspnUnit {
    pub c: usize,
    pub c_proxy: usize,
    pub kchunk: usize,
    /// Per-channel taps (GSPN-1 semantics) instead of shared (GSPN-2).
    pub per_channel: bool,
    pub down: Proj,
    pub up: Proj,
    /// One taps-producing and one lam-producing projection per direction.
    pub taps_proj: Vec<Proj>,
    pub lam_proj: Vec<Proj>,
    pub u: Vec<f32>,
    pub merge: [f32; 4],
}

impl CompactGspnUnit {
    pub fn init(rng: &mut Rng, c: usize, c_proxy: usize, kchunk: usize, per_channel: bool) -> Self {
        let cw = if per_channel { c_proxy } else { 1 };
        CompactGspnUnit {
            c,
            c_proxy,
            kchunk,
            per_channel,
            down: Proj::init(rng, c, c_proxy),
            up: Proj::init(rng, c_proxy, c),
            taps_proj: (0..4).map(|_| Proj::init(rng, c_proxy, 3 * cw)).collect(),
            lam_proj: (0..4).map(|_| Proj::init(rng, c_proxy, c_proxy)).collect(),
            u: vec![1.0; c_proxy],
            merge: [0.0; 4],
        }
    }

    pub fn param_count(&self) -> usize {
        self.down.params()
            + self.up.params()
            + self.taps_proj.iter().map(|p| p.params()).sum::<usize>()
            + self.lam_proj.iter().map(|p| p.params()).sum::<usize>()
            + self.u.len()
            + self.merge.len()
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.c);
        let xp = self.down.apply(x);
        let cw = if self.per_channel { self.c_proxy } else { 1 };
        let pool = ThreadPool::global();

        // The four directional passes are independent end to end (taps
        // projection, lam projection, scan): run each as a job on the
        // shared pool, with the scan's plane loop nested into the same
        // pool. Per-direction arithmetic is untouched and the merge below
        // accumulates in direction order, so this is bit-identical to the
        // old serial loop.
        //
        // Lambda per direction must follow canonical orientation: the
        // merged_4dir helper reorients lam internally from the *spatial*
        // layout, so we produce lam in canonical layout per direction and
        // run each direction separately here (lam differs per direction).
        let ys = pool.map((0..4usize).collect(), |k| {
            let d = DIRECTIONS[k];
            let xc = to_canonical(&xp, d);
            let raw = self.taps_proj[k].apply(&xc); // (N, 3*cw, Hc, Wc)
            let (n, _, hc, wc) = (raw.shape[0], raw.shape[1], raw.shape[2], raw.shape[3]);
            let taps = Taps::normalize(&raw.reshape(&[n, cw, 3, hc, wc]));
            let lamc = self.lam_proj[k].apply(&xc);
            let hc = super::core::scan_l2r_pool(&xc, &taps, &lamc, self.kchunk, pool);
            from_canonical(&hc, d)
        });

        let wts = super::direction::merge_weights(&self.merge);
        let mut merged = Tensor::zeros(&xp.shape);
        for (k, y) in ys.iter().enumerate() {
            for (o, v) in merged.data.iter_mut().zip(&y.data) {
                *o += wts[k] * v;
            }
        }

        let modulated = super::core::output_modulation(&merged, &self.u);
        self.up.apply(&modulated)
    }
}

// Re-export so `merged_4dir` is exercised by the public API too.
pub use super::direction::merged_4dir as merge_directions;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proj_identity() {
        let mut p = Proj::init(&mut Rng::new(0), 3, 3);
        p.w = vec![1., 0., 0., 0., 1., 0., 0., 0., 1.];
        p.b = vec![0.0; 3];
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[2, 3, 4, 4], &mut rng, 1.0);
        assert!(p.apply(&x).allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn proj_shapes_and_bias() {
        let mut p = Proj::init(&mut Rng::new(0), 4, 2);
        p.w = vec![0.0; 8];
        p.b = vec![1.5, -2.0];
        let x = Tensor::zeros(&[1, 4, 3, 3]);
        let y = p.apply(&x);
        assert_eq!(y.shape, vec![1, 2, 3, 3]);
        assert!((y.at(&[0, 0, 1, 1]) - 1.5).abs() < 1e-6);
        assert!((y.at(&[0, 1, 2, 2]) + 2.0).abs() < 1e-6);
    }

    #[test]
    fn unit_preserves_shape() {
        let mut rng = Rng::new(2);
        let unit = CompactGspnUnit::init(&mut rng, 16, 4, 0, false);
        let x = Tensor::randn(&[2, 16, 8, 8], &mut rng, 1.0);
        let y = unit.forward(&x);
        assert_eq!(y.shape, x.shape);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn global_receptive_field() {
        let mut rng = Rng::new(3);
        let unit = CompactGspnUnit::init(&mut rng, 8, 2, 0, false);
        let x = Tensor::randn(&[1, 8, 8, 8], &mut rng, 1.0);
        let mut x2 = x.clone();
        for c in 0..8 {
            *x2.at_mut(&[0, c, 0, 0]) += 5.0;
        }
        let y1 = unit.forward(&x);
        let y2 = unit.forward(&x2);
        let corner_diff: f32 =
            (0..8).map(|c| (y1.at(&[0, c, 7, 7]) - y2.at(&[0, c, 7, 7])).abs()).sum();
        assert!(corner_diff > 1e-6, "corner unaffected: {corner_diff}");
    }

    #[test]
    fn param_count_shrinks_with_proxy() {
        // The §4.2 claim: compact propagation trims parameters.
        let mut rng = Rng::new(4);
        let small = CompactGspnUnit::init(&mut rng, 64, 2, 0, false);
        let big = CompactGspnUnit::init(&mut rng, 64, 32, 0, false);
        assert!(small.param_count() < big.param_count());
    }

    #[test]
    fn per_channel_has_more_params_than_shared() {
        let mut rng = Rng::new(5);
        let shared = CompactGspnUnit::init(&mut rng, 32, 8, 0, false);
        let perch = CompactGspnUnit::init(&mut rng, 32, 8, 0, true);
        assert!(perch.param_count() > shared.param_count());
    }

    #[test]
    fn chunked_unit_runs() {
        let mut rng = Rng::new(6);
        let unit = CompactGspnUnit::init(&mut rng, 8, 2, 4, false);
        let x = Tensor::randn(&[1, 8, 8, 8], &mut rng, 1.0);
        let y = unit.forward(&x);
        assert_eq!(y.shape, x.shape);
    }
}
