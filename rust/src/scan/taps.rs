//! Propagation taps and the Stability-Context normalisation.
//!
//! A tap tensor holds, for each (batch, weight-channel, row, column), the
//! three coefficients connecting a pixel in column `i` to its three
//! neighbours in column `i-1` (up / center / down — the tridiagonal
//! structure of Eq. 1). `Cw == C` gives per-channel weights (GSPN-1);
//! `Cw == 1` gives the channel-shared compact weights of GSPN-2 §4.2.
//!
//! `normalize` applies the Stability-Context Condition of [1]: sigmoid on
//! the raw logits, zeroing of out-of-range taps at the top/bottom rows,
//! then per-row renormalisation so every row of the tridiagonal matrix
//! w_i sums to exactly 1 (row-stochastic => ||h||_inf never amplifies).

use crate::tensor::Tensor;

pub const TAP_UP: usize = 0;
pub const TAP_CENTER: usize = 1;
pub const TAP_DOWN: usize = 2;

/// Normalised taps, layout (N, Cw, 3, H, W).
#[derive(Clone, Debug)]
pub struct Taps {
    pub t: Tensor,
    pub n: usize,
    pub cw: usize,
    pub h: usize,
    pub w: usize,
}

impl Taps {
    /// Normalise raw logits (N, Cw, 3, H, W) into row-stochastic taps.
    pub fn normalize(raw: &Tensor) -> Taps {
        assert_eq!(raw.rank(), 5, "taps must be (N, Cw, 3, H, W)");
        assert_eq!(raw.shape[2], 3, "tap axis must have size 3");
        let (n, cw, h, w) = (raw.shape[0], raw.shape[1], raw.shape[3], raw.shape[4]);
        let mut out = raw.map(|x| 1.0 / (1.0 + (-x).exp()));
        let plane = h * w;
        if plane == 0 {
            return Taps { t: out, n, cw, h, w };
        }
        // Row-slice iteration: split each (n, cw) block once into its
        // three tap planes and walk matching row slices, instead of
        // re-deriving three flat indices per element (3 mul + 3 add per
        // pixel of pure address arithmetic in the old loop). Arithmetic
        // per element is unchanged, so results are bit-identical.
        for block in out.data.chunks_mut(3 * plane) {
            let (up_plane, rest) = block.split_at_mut(plane);
            let (ct_plane, dn_plane) = rest.split_at_mut(plane);
            for r in 0..h {
                let row = r * w..r * w + w;
                let (up_row, ct_row, dn_row) = (
                    &mut up_plane[row.clone()],
                    &mut ct_plane[row.clone()],
                    &mut dn_plane[row],
                );
                if r == 0 {
                    up_row.iter_mut().for_each(|v| *v = 0.0);
                }
                if r == h - 1 {
                    dn_row.iter_mut().for_each(|v| *v = 0.0);
                }
                for i in 0..w {
                    let s = up_row[i] + ct_row[i] + dn_row[i];
                    up_row[i] /= s;
                    ct_row[i] /= s;
                    dn_row[i] /= s;
                }
            }
        }
        Taps { t: out, n, cw, h, w }
    }

    /// Tap value at (n, cw, tap, row, col). `cw` is clamped for shared mode.
    #[inline]
    pub fn at(&self, n: usize, cw: usize, tap: usize, r: usize, i: usize) -> f32 {
        let c = if self.cw == 1 { 0 } else { cw };
        let plane = self.h * self.w;
        self.t.data[((n * self.cw + c) * 3 + tap) * plane + r * self.w + i]
    }

    /// Verify the Stability-Context Condition; returns max |row_sum - 1|.
    pub fn row_sum_error(&self) -> f32 {
        let mut err = 0.0f32;
        for n in 0..self.n {
            for c in 0..self.cw {
                for r in 0..self.h {
                    for i in 0..self.w {
                        let s = self.at(n, c, TAP_UP, r, i)
                            + self.at(n, c, TAP_CENTER, r, i)
                            + self.at(n, c, TAP_DOWN, r, i);
                        err = err.max((s - 1.0).abs());
                    }
                }
            }
        }
        err
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure};
    use crate::util::Rng;

    #[test]
    fn rows_sum_to_one() {
        let mut rng = Rng::new(0);
        let raw = Tensor::randn(&[2, 3, 3, 5, 4], &mut rng, 1.5);
        let taps = Taps::normalize(&raw);
        assert!(taps.row_sum_error() < 1e-6);
    }

    #[test]
    fn boundary_taps_are_zero() {
        let mut rng = Rng::new(1);
        let raw = Tensor::randn(&[1, 1, 3, 6, 4], &mut rng, 1.0);
        let taps = Taps::normalize(&raw);
        for i in 0..4 {
            assert_eq!(taps.at(0, 0, TAP_UP, 0, i), 0.0);
            assert_eq!(taps.at(0, 0, TAP_DOWN, 5, i), 0.0);
        }
    }

    #[test]
    fn all_taps_nonnegative_property() {
        check("taps nonnegative + stochastic", |g| {
            let n = g.int_in(1, 2);
            let cw = g.int_in(1, 3);
            let h = g.int_in(1, 8);
            let w = g.int_in(1, 8);
            let raw = Tensor::from_vec(
                &[n, cw, 3, h, w],
                g.normal_vec(n * cw * 3 * h * w).iter().map(|x| x * 3.0).collect(),
            );
            let taps = Taps::normalize(&raw);
            ensure(taps.t.data.iter().all(|&x| x >= 0.0), "nonnegative")?;
            ensure(taps.row_sum_error() < 1e-5, "row-stochastic")
        });
    }

    #[test]
    fn shared_taps_broadcast() {
        let mut rng = Rng::new(2);
        let raw = Tensor::randn(&[1, 1, 3, 4, 4], &mut rng, 1.0);
        let taps = Taps::normalize(&raw);
        // Asking for any channel returns the shared channel-0 values.
        assert_eq!(taps.at(0, 5, TAP_CENTER, 2, 2), taps.at(0, 0, TAP_CENTER, 2, 2));
    }

    #[test]
    fn h_equals_one_center_only() {
        let mut rng = Rng::new(3);
        let raw = Tensor::randn(&[1, 1, 3, 1, 3], &mut rng, 1.0);
        let taps = Taps::normalize(&raw);
        for i in 0..3 {
            assert_eq!(taps.at(0, 0, TAP_UP, 0, i), 0.0);
            assert_eq!(taps.at(0, 0, TAP_DOWN, 0, i), 0.0);
            assert!((taps.at(0, 0, TAP_CENTER, 0, i) - 1.0).abs() < 1e-6);
        }
    }
}
