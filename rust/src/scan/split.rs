//! Segment-parallel decomposition of the line scan.
//!
//! The paper's §5.1 profiling notes that for small batch x channel counts
//! SM occupancy drops to 20-30% because one block per (chunk, n, c) slice
//! is the only parallelism, and names "further decompos[ing] the problem
//! to increase parallelism across SMs" as future work. This module
//! implements that decomposition as a two-phase segmented scan over the
//! linear recurrence h_i = w_i h_{i-1} + b_i:
//!
//!   phase 1 (parallel over segments x planes): scan each segment from a
//!     zero incoming carry.
//!   phase 2 (parallel over planes, sequential over a plane's segments):
//!     propagate the true carry through each segment as a *correction
//!     scan* (x ≡ 0, initial state = incoming carry) added onto the
//!     phase-1 output — exact by linearity of Eq. 1. The corrected last
//!     column of segment k is, definitionally, segment k+1's carry, so
//!     the carry chain and the correction pass are one and the same.
//!
//! Work: phase 1 is 7 flops/pixel (parallel), phase 2 is 3 flops/pixel
//! (sequential per plane) — a parallel speedup bounded by 7/(3 + 7/P),
//! ~1.8x at 8 threads for a single plane. The *operator* formulation
//! (composing banded transfer matrices T_k = w_last···w_first, see
//! [`Banded`] and [`segment_transfer`]) costs O(H·s) extra work per
//! column and only pays on massively parallel hardware — which is why
//! the GPU-side model ([`crate::gpusim::KernelConfig::split`], selected
//! by [`crate::gpusim::adaptive`]) charges 2.5x the per-step latency but
//! still wins in the low-occupancy regime, while the CPU path uses the
//! carry-only form (the operator form measured 4-30x *slower* on CPU).
//!
//! Role since the fused engine gained this decomposition: this module is
//! the **bit-identity reference** for the segmented arithmetic order.
//! Production callers — the pooled `fused_*` entry points, the compact
//! unit, the cpu serving backend — route through
//! [`super::fused`], whose execution planner
//! ([`super::plan::plan_scan`]) applies exactly this two-phase
//! decomposition (pinned `==` against [`scan_l2r_split`] by the fused
//! engine's tests — barrier, per-direction wavefront, and the retained
//! PR 4 two-pass schedule alike) with the pack/scan/scatter stages
//! fused and, since the fused-correction drain, [`phase2_plane`]'s
//! correction computed inside the scatter epilogue rather than as this
//! module's separate in-place pass (same adds, same order, same bits). The
//! implementation here stays deliberately unfused and simple;
//! `threads > 1` still submits its (segment × plane) and (plane) task
//! groups to the process-wide shared [`ThreadPool`] rather than
//! spawning anything per call.

use super::taps::{Taps, TAP_CENTER, TAP_DOWN, TAP_UP};
use crate::tensor::Tensor;
use crate::util::ThreadPool;

/// A square banded matrix of size `h` with half-bandwidth `hb`, stored
/// row-major as `h` rows of `2*hb + 1` in-band entries. Entry `(r, c)` is
/// stored at `row r, offset c - r + hb` when `|r - c| <= hb`, else 0.
#[derive(Clone, Debug)]
pub struct Banded {
    pub h: usize,
    pub hb: usize,
    data: Vec<f32>,
}

impl Banded {
    pub fn identity(h: usize) -> Banded {
        Banded { h, hb: 0, data: vec![1.0; h] }
    }

    fn width(&self) -> usize {
        2 * self.hb + 1
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (r_i, c_i) = (r as isize, c as isize);
        let d = c_i - r_i + self.hb as isize;
        if d < 0 || d >= self.width() as isize {
            0.0
        } else {
            self.data[r * self.width() + d as usize]
        }
    }

    #[inline]
    fn set(&mut self, r: usize, c: usize, v: f32) {
        let d = (c as isize - r as isize + self.hb as isize) as usize;
        let w = self.width();
        self.data[r * w + d] = v;
    }

    /// The tridiagonal propagation matrix w_i of Eq. 1 for column `i`:
    /// row r has (up, center, down) taps connecting to rows r-1, r, r+1.
    pub fn tridiag(taps: &Taps, n: usize, cw: usize, i: usize) -> Banded {
        let h = taps.h;
        let mut m = Banded { h, hb: 1, data: vec![0.0; h * 3] };
        for r in 0..h {
            if r > 0 {
                m.set(r, r - 1, taps.at(n, cw, TAP_UP, r, i));
            }
            m.set(r, r, taps.at(n, cw, TAP_CENTER, r, i));
            if r + 1 < h {
                m.set(r, r + 1, taps.at(n, cw, TAP_DOWN, r, i));
            }
        }
        m
    }

    /// y = self · x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.h);
        let mut y = vec![0.0f32; self.h];
        for (r, yr) in y.iter_mut().enumerate() {
            let lo = r.saturating_sub(self.hb);
            let hi = (r + self.hb).min(self.h - 1);
            let mut acc = 0.0;
            for c in lo..=hi {
                acc += self.get(r, c) * x[c];
            }
            *yr = acc;
        }
        y
    }

    /// C = a · b (matrix product). Bandwidth adds, capped at h-1.
    pub fn compose(a: &Banded, b: &Banded) -> Banded {
        assert_eq!(a.h, b.h);
        let h = a.h;
        let hb = (a.hb + b.hb).min(h.saturating_sub(1));
        let mut out = Banded { h, hb, data: vec![0.0; h * (2 * hb + 1)] };
        for r in 0..h {
            let clo = r.saturating_sub(hb);
            let chi = (r + hb).min(h - 1);
            for c in clo..=chi {
                // k must satisfy |r-k| <= a.hb and |k-c| <= b.hb.
                let klo = r.saturating_sub(a.hb).max(c.saturating_sub(b.hb));
                let khi = (r + a.hb).min(c + b.hb).min(h - 1);
                let mut acc = 0.0;
                for k in klo..=khi {
                    acc += a.get(r, k) * b.get(k, c);
                }
                out.set(r, c, acc);
            }
        }
        out
    }

    /// Dense form, for tests and introspection.
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        (0..self.h).map(|r| (0..self.h).map(|c| self.get(r, c)).collect()).collect()
    }
}

/// Per-plane, per-segment phase-1 result: the local (zero-carry) scan
/// output, h x seg_len, column-major over the segment.
struct SegScan {
    out: Vec<f32>,
}

/// Tap-plane slices for one (n, cw) pair.
fn tap_planes<'a>(taps: &'a Taps, ni: usize, cw: usize) -> (&'a [f32], &'a [f32], &'a [f32]) {
    let (h, w) = (taps.h, taps.w);
    let plane = h * w;
    let tbase = (ni * taps.cw + cw) * 3 * plane;
    (
        &taps.t.data[tbase + TAP_UP * plane..tbase + TAP_UP * plane + plane],
        &taps.t.data[tbase + TAP_CENTER * plane..tbase + TAP_CENTER * plane + plane],
        &taps.t.data[tbase + TAP_DOWN * plane..tbase + TAP_DOWN * plane + plane],
    )
}

/// Scan one segment of columns `[lo, hi)` of plane (ni, ci) from a zero
/// carry. Allocation-free inner loop (3-tap recurrence, like `scan_l2r`).
fn phase1(x: &Tensor, taps: &Taps, lam: &Tensor, ni: usize, ci: usize, lo: usize, hi: usize) -> SegScan {
    let (c, h, w) = (x.shape[1], x.shape[2], x.shape[3]);
    let cw = if taps.cw == 1 { 0 } else { ci };
    let (t_up, t_ct, t_dn) = tap_planes(taps, ni, cw);
    let xbase = (ni * c + ci) * h * w;
    let seg = hi - lo;
    let mut out = vec![0.0f32; h * seg];
    let mut hprev = vec![0.0f32; h];
    let mut hcur = vec![0.0f32; h];
    for (j, i) in (lo..hi).enumerate() {
        for r in 0..h {
            let up = if r > 0 { t_up[r * w + i] * hprev[r - 1] } else { 0.0 };
            let ct = t_ct[r * w + i] * hprev[r];
            let dn = if r + 1 < h { t_dn[r * w + i] * hprev[r + 1] } else { 0.0 };
            let idx = xbase + r * w + i;
            let v = up + ct + dn + lam.data[idx] * x.data[idx];
            out[r * seg + j] = v;
            hcur[r] = v;
        }
        std::mem::swap(&mut hprev, &mut hcur);
    }
    SegScan { out }
}

/// Phase 2 for one plane: chain the carry through the plane's segments,
/// adding the correction scan onto each segment in place. The corrected
/// last column of a segment is the next segment's incoming carry.
fn phase2_plane(
    segs: &mut [SegScan],
    bounds: &[(usize, usize)],
    taps: &Taps,
    ni: usize,
    ci: usize,
) {
    let h = taps.h;
    let w = taps.w;
    let cw = if taps.cw == 1 { 0 } else { ci };
    let (t_up, t_ct, t_dn) = tap_planes(taps, ni, cw);
    let mut corr = vec![0.0f32; h];
    let mut next = vec![0.0f32; h];
    for (k, sc) in segs.iter_mut().enumerate() {
        let (lo, hi) = bounds[k];
        let seg = hi - lo;
        if k > 0 && corr.iter().any(|&v| v != 0.0) {
            // Correction scan: corr_{i} = w_i · corr_{i-1}, added to out.
            for (j, i) in (lo..hi).enumerate() {
                for r in 0..h {
                    let up = if r > 0 { t_up[r * w + i] * corr[r - 1] } else { 0.0 };
                    let ct = t_ct[r * w + i] * corr[r];
                    let dn = if r + 1 < h { t_dn[r * w + i] * corr[r + 1] } else { 0.0 };
                    next[r] = up + ct + dn;
                    sc.out[r * seg + j] += next[r];
                }
                std::mem::swap(&mut corr, &mut next);
            }
        }
        // The (now corrected) final column is the next segment's carry.
        for r in 0..h {
            corr[r] = sc.out[r * seg + (seg - 1)];
        }
    }
}

/// The composed transfer operator T = w_{hi-1} ··· w_{lo} of a column
/// range, as a banded matrix. Not on the scan hot path (the carry-only
/// phase 2 above avoids it); exposed for introspection and validation —
/// e.g. checking that the Stability-Context Condition (row-stochasticity)
/// survives segment composition.
pub fn segment_transfer(taps: &Taps, ni: usize, cw: usize, lo: usize, hi: usize) -> Banded {
    let mut t = Banded::identity(taps.h);
    for i in lo..hi {
        t = Banded::compose(&Banded::tridiag(taps, ni, cw, i), &t);
    }
    t
}

/// Segment-parallel global scan; numerically equivalent to
/// [`super::scan_l2r`] with `kchunk = 0` (up to fp reassociation).
///
/// `segments` is the decomposition degree (clamped to W). `threads <= 1`
/// runs inline on the calling thread; `threads > 1` submits at most
/// `threads` jobs for phase 1 (segments × planes) and phase 2 (planes)
/// to the process-wide shared [`ThreadPool`] — no per-call thread
/// spawns, and `threads` still bounds this call's parallelism even when
/// the pool is wider.
pub fn scan_l2r_split(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    segments: usize,
    threads: usize,
) -> Tensor {
    let par = if threads > 1 { Some((ThreadPool::global(), threads)) } else { None };
    scan_l2r_split_impl(x, taps, lam, segments, par)
}

/// [`scan_l2r_split`] over an explicit pool handle (tests and callers
/// that manage their own pool); fans out one job per task, so the
/// pool's worker count is the parallelism bound.
pub fn scan_l2r_split_pool(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    scan_l2r_split_impl(x, taps, lam, segments, Some((pool, usize::MAX)))
}

fn scan_l2r_split_impl(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    segments: usize,
    par: Option<(&ThreadPool, usize)>,
) -> Tensor {
    assert_eq!(x.rank(), 4, "x must be (N, C, H, W)");
    assert_eq!(x.shape, lam.shape, "lam shape must match x");
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!((taps.n, taps.h, taps.w), (n, h, w), "taps geometry mismatch");
    assert!(taps.cw == 1 || taps.cw == c, "Cw must be 1 or C");
    let segments = segments.clamp(1, w);
    let seg_len = w.div_ceil(segments);
    let bounds: Vec<(usize, usize)> =
        (0..w).step_by(seg_len).map(|lo| (lo, (lo + seg_len).min(w))).collect();
    let n_segs = bounds.len();

    // Phase 1: all (plane, segment) tasks are independent. Task t covers
    // plane t / n_segs, segment t % n_segs; the pooled path groups the
    // task range into at most `cap` contiguous jobs so the caller's
    // thread budget is respected.
    let n_tasks = n * c * n_segs;
    let run_task = |t: usize| {
        let (p, s) = (t / n_segs, t % n_segs);
        let (lo, hi) = bounds[s];
        phase1(x, taps, lam, p / c, p % c, lo, hi)
    };
    let mut scans: Vec<SegScan> = match par {
        Some((pool, cap)) if n_tasks > 1 && cap > 1 => {
            let per = n_tasks.div_ceil(cap.min(n_tasks));
            let ranges: Vec<(usize, usize)> = (0..n_tasks)
                .step_by(per)
                .map(|lo| (lo, (lo + per).min(n_tasks)))
                .collect();
            pool.map(ranges, |(lo, hi)| (lo..hi).map(run_task).collect::<Vec<_>>())
                .into_iter()
                .flatten()
                .collect()
        }
        _ => (0..n_tasks).map(run_task).collect(),
    };

    // Phase 2: per-plane carry + correction pass (planes independent),
    // again grouped into at most `cap` jobs.
    match par {
        Some((pool, cap)) if n * c > 1 && cap > 1 => {
            let per = (n * c).div_ceil(cap.min(n * c));
            let groups: Vec<(usize, &mut [SegScan])> =
                scans.chunks_mut(per * n_segs).enumerate().collect();
            pool.map(groups, |(g, group)| {
                for (j, segs) in group.chunks_mut(n_segs).enumerate() {
                    let p = g * per + j;
                    phase2_plane(segs, &bounds, taps, p / c, p % c);
                }
            });
        }
        _ => {
            for (p, segs) in scans.chunks_mut(n_segs).enumerate() {
                phase2_plane(segs, &bounds, taps, p / c, p % c);
            }
        }
    }

    // Assemble (N, C, H, W). Task t covered plane t / n_segs, segment
    // t % n_segs (the phase-1 task order).
    let mut out = Tensor::zeros(&x.shape);
    for (t, sc) in scans.iter().enumerate() {
        let (p, s) = (t / n_segs, t % n_segs);
        let (ni, ci) = (p / c, p % c);
        let (lo, hi) = bounds[s];
        let seg = hi - lo;
        let obase = (ni * c + ci) * h * w;
        for r in 0..h {
            let src = &sc.out[r * seg..(r + 1) * seg];
            out.data[obase + r * w + lo..obase + r * w + hi].copy_from_slice(src);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan_l2r;
    use crate::util::proptest::{check, ensure_close};
    use crate::util::Rng;

    fn case(seed: u64, n: usize, c: usize, h: usize, w: usize, cw: usize) -> (Tensor, Taps, Tensor) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let raw = Tensor::randn(&[n, cw, 3, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        (x, Taps::normalize(&raw), lam)
    }

    #[test]
    fn banded_identity_matvec() {
        let i = Banded::identity(5);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(i.matvec(&x), x);
    }

    #[test]
    fn banded_tridiag_matches_scan_step() {
        let (x, taps, lam) = case(3, 1, 1, 6, 4, 1);
        // One scan step == tridiag matvec + lam*x.
        let seq = scan_l2r(&x, &taps, &lam, 0);
        let h0: Vec<f32> = (0..6).map(|r| seq.at(&[0, 0, r, 0])).collect();
        let w1 = Banded::tridiag(&taps, 0, 0, 1);
        let prop = w1.matvec(&h0);
        for r in 0..6 {
            let want = prop[r] + lam.at(&[0, 0, r, 1]) * x.at(&[0, 0, r, 1]);
            assert!((seq.at(&[0, 0, r, 1]) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn compose_matches_dense_product() {
        let (_, taps, _) = case(4, 1, 1, 5, 3, 1);
        let a = Banded::tridiag(&taps, 0, 0, 0);
        let b = Banded::tridiag(&taps, 0, 0, 1);
        let c = Banded::compose(&a, &b);
        assert_eq!(c.hb, 2);
        let (da, db, dc) = (a.to_dense(), b.to_dense(), c.to_dense());
        for r in 0..5 {
            for cc in 0..5 {
                let want: f32 = (0..5).map(|k| da[r][k] * db[k][cc]).sum();
                assert!((dc[r][cc] - want).abs() < 1e-6, "({r},{cc})");
            }
        }
    }

    #[test]
    fn compose_band_caps_at_h_minus_one() {
        let (_, taps, _) = case(5, 1, 1, 3, 8, 1);
        let t = segment_transfer(&taps, 0, 0, 0, 8);
        assert_eq!(t.hb, 2); // capped at h-1, not 8
    }

    #[test]
    fn transfer_is_row_stochastic() {
        // Product of row-stochastic matrices is row-stochastic — the
        // Stability-Context Condition survives segment composition.
        let (_, taps, _) = case(6, 1, 1, 7, 6, 1);
        let t = segment_transfer(&taps, 0, 0, 0, 6);
        for r in 0..7 {
            let s: f32 = (0..7).map(|c| t.get(r, c)).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
        }
    }

    #[test]
    fn transfer_matches_chained_scan() {
        // T · h0 must equal scanning h0 through the segment with x = 0.
        let (x, taps, lam) = case(11, 1, 1, 6, 5, 1);
        let t = segment_transfer(&taps, 0, 0, 0, 5);
        let h0: Vec<f32> = (0..6).map(|r| 0.3 * r as f32 - 0.7).collect();
        let via_op = t.matvec(&h0);
        // Chain through the recurrence directly.
        let mut corr = h0.clone();
        for i in 0..5 {
            corr = Banded::tridiag(&taps, 0, 0, i).matvec(&corr);
        }
        for r in 0..6 {
            assert!((via_op[r] - corr[r]).abs() < 1e-5);
        }
        let _ = (x, lam);
    }

    #[test]
    fn split_equals_sequential_basic() {
        let (x, taps, lam) = case(0, 2, 3, 8, 12, 3);
        let seq = scan_l2r(&x, &taps, &lam, 0);
        for segments in [1, 2, 3, 4, 6, 12] {
            let par = scan_l2r_split(&x, &taps, &lam, segments, 1);
            assert!(
                seq.allclose(&par, 1e-4, 1e-4),
                "segments={segments}: max diff {}",
                seq.max_abs_diff(&par)
            );
        }
    }

    #[test]
    fn split_uneven_segments() {
        // W=10 into 3 segments -> lengths 4,4,2.
        let (x, taps, lam) = case(1, 1, 2, 5, 10, 1);
        let seq = scan_l2r(&x, &taps, &lam, 0);
        let par = scan_l2r_split(&x, &taps, &lam, 3, 1);
        assert!(seq.allclose(&par, 1e-4, 1e-4), "diff {}", seq.max_abs_diff(&par));
    }

    #[test]
    fn split_threaded_matches_inline() {
        // threads > 1 now routes through the shared global pool.
        let (x, taps, lam) = case(2, 2, 2, 16, 32, 1);
        let a = scan_l2r_split(&x, &taps, &lam, 8, 4);
        let b = scan_l2r_split(&x, &taps, &lam, 8, 1);
        assert!(a.allclose(&b, 1e-6, 1e-6));
    }

    #[test]
    fn split_explicit_pool_is_bit_identical_to_inline() {
        // Same segmentation, pooled vs inline: the per-task arithmetic is
        // identical, so this is exact equality (not just allclose).
        let pool = crate::util::ThreadPool::new(3);
        let (x, taps, lam) = case(12, 2, 3, 8, 24, 1);
        let inline = scan_l2r_split(&x, &taps, &lam, 6, 1);
        let pooled = scan_l2r_split_pool(&x, &taps, &lam, 6, &pool);
        assert_eq!(inline.data, pooled.data);
    }

    #[test]
    fn split_more_segments_than_columns_clamps() {
        let (x, taps, lam) = case(7, 1, 1, 4, 5, 1);
        let seq = scan_l2r(&x, &taps, &lam, 0);
        let par = scan_l2r_split(&x, &taps, &lam, 64, 1);
        assert!(seq.allclose(&par, 1e-4, 1e-4));
    }

    #[test]
    fn split_property_random_shapes() {
        check("segmented scan == sequential scan", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 3);
            let h = g.int_in(1, 9);
            let w = g.int_in(1, 17);
            let segments = g.int_in(1, 6);
            let shared = g.int_in(0, 1) == 0;
            let cw = if shared { 1 } else { c };
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.5);
            let raw = Tensor::randn(&[n, cw, 3, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = Taps::normalize(&raw);
            let seq = scan_l2r(&x, &taps, &lam, 0);
            let par = scan_l2r_split(&x, &taps, &lam, segments, 1);
            ensure_close(seq.max_abs_diff(&par) as f64, 0.0, 1e-3, "split residual")
        });
    }
}
