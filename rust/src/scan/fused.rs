//! Column-staged fused scan engine: one pass for pack → 4-direction
//! scan → merge → modulate.
//!
//! GSPN-2's system contribution is three fixes to the same hot path, and
//! this module is their CPU analog — the reference path in [`super::core`] /
//! [`super::direction`] reproduces all three sins, the engine here removes
//! them while staying **bit-identical** (exact `==` on `data`, pinned by
//! property tests) to that reference:
//!
//! 1. **Micro-launches → block-granular work.** The reference submits one
//!    pool job per (N·C) plane (the CPU twin of the paper's thousands of
//!    per-column kernel launches). The fused engine submits one job per
//!    *block* of planes, the block count sized off
//!    [`ThreadPool::threads`] (§ "fusing the column loop into a single
//!    kernel launch"), so dispatch overhead is O(threads), not O(planes).
//!
//! 2. **Shared-memory column staging → L1-resident column slabs.** The
//!    reference walks columns over a row-major layout: every inner-loop
//!    access strides by `W` floats and nothing vectorizes. The engine
//!    processes each plane in slabs of [`SLAB`] canonical columns: the
//!    pack step gathers the input term `b = lam ⊙ x` (one fused product,
//!    exactly the `ls[p] * xs[p]` unit of the reference expression) into
//!    a column-major slab — row index contiguous, the CPU analog of the
//!    paper's shared-memory column staging — with the direction's
//!    orientation folded into the gather, so no
//!    `to_canonical`/`from_canonical`/`flip_last` tensor is ever
//!    materialized. The previous column is read straight out of the slab
//!    (a carry column crosses slab boundaries), and the scan inner loop
//!    is unit-stride over four L1-resident columns and runs in explicit
//!    SIMD lanes ([`super::simd`]) with a scalar fallback pinned
//!    bit-identical.
//!    Taps are staged once per (batch, weight-channel) and — with the
//!    §4.2 channel-shared weights — reused by every channel plane.
//!
//! 3. **Global-memory round trips → fused epilogue.** The reference
//!    materializes two canonical copies per direction, a full scan
//!    output per direction, a `from_canonical` copy of each, a separate
//!    merge-accumulate pass, and `output_modulation`'s clone + second
//!    traversal — four full intermediate tensors and change. The
//!    scatter-back epilogue here folds the inverse orientation, the
//!    softmax-weighted 4-direction merge, *and* the `u ⊙ h` output
//!    modulation into the per-slab drain; no directional intermediate
//!    ever exists in memory, and scratch is O(SLAB·max(H, W)) per job
//!    instead of O(H·W) panels.
//!
//! 4. **Low-occupancy geometries → planned decompositions.** Plane
//!    blocks are the only parallelism above, so a single
//!    large-resolution request (few N·C planes, huge H·W — the §5.1
//!    occupancy collapse) runs nearly serial. Strategy selection lives
//!    in the execution planner ([`super::plan::plan_scan`]) — this
//!    module only *executes* whichever plan it is handed:
//!
//!    * `Segmented { s }` — the two-phase decomposition of
//!      [`super::split`], fused end to end: phase 1 scans every (plane,
//!      direction, segment) from a zero incoming carry in parallel —
//!      the same pack/unit-stride-scan slab pipeline, retaining the
//!      canonical columns instead of scattering them — and phase 2
//!      chains the true carries across segment boundaries as a linear
//!      correction scan (`correct_col` in [`super::simd`]) **computed
//!      on the fly inside
//!      the scatter drain** ([`drain_dir_fused`]): each panel element
//!      is read exactly once, the per-column correction is added in
//!      registers, and the corrected value goes straight through the
//!      inverse-orientation + merge + modulation epilogue. The retained
//!      panel is never re-written — the separate in-place correction
//!      pass of the PR 3/4 engines (kept as
//!      [`correct_and_drain_pieces`], the two-pass bench/bit reference)
//!      re-touched the whole panel between phase 1 and the drain, the
//!      exact global-memory round trip §5 eliminates on the GPU.
//!      Segmented arithmetic is exactly `scan_l2r_split`'s two-phase
//!      order (pinned `==` by tests): `phase1 + corr` is the same f32
//!      add whether it lands in the panel or in the drain.
//!    * `DirFan` — for merged passes: one phase-1 job per (plane,
//!      direction) scanning its *full* width from the true zero carry
//!      (already exact, no correction), then a fixed-k-order merge
//!      drain per plane. Bit-identical to the plane path; executed as
//!      the `s = 1` degenerate case of the segmented engine.
//!    * `Chained { s }` — the single-pass decoupled-look-back engine
//!      ([`run_engine_chained`]): the same (plane, direction, segment)
//!      decomposition, but each chunk is ONE job that scans from a
//!      zero carry, publishes its aggregate on a [`BlockBoard`],
//!      resolves its true incoming carry by looking back over
//!      predecessors' published prefixes/aggregates (helping with
//!      other chunks or assisting the pool while it waits), corrects
//!      its own panel while still cache-hot, publishes its inclusive
//!      prefix, and drains through the same fused epilogue. No phase
//!      barrier, no retained-panel array, no second panel read —
//!      two-phase engine overhead retired, bits unchanged (the fold
//!      replays the exact `correct_col` recurrence + skip rules of the
//!      two-phase order; pinned `==` against `scan_l2r_split` and the
//!      segmented engine by the chained property suite).
//!    * The **wavefront** flag replaces the global barrier between the
//!      phases with dependency-aware pool submission
//!      ([`crate::util::ThreadPool::run_graph`]). The drain of each
//!      (plane, direction) is its own continuation — chained after the
//!      same plane's previous direction to preserve the k = 0..4 merge
//!      order, depending only on its *own* direction's phase-1 pieces —
//!      so direction k's drain overlaps both other planes' phase 1 and
//!      the same plane's direction-(k+1) scans (4 continuations per
//!      plane instead of PR 4's 1). Scheduling only — the arithmetic
//!      (and every bit) matches the barrier path.
//!
//!    The plane-parallel regime is untouched and stays bit-identical to
//!    the serial reference.
//!
//! Bit-exactness: per element the engine evaluates exactly the reference
//! expression `up + ct + dn + (lam·x)` in the same association,
//! accumulates directions in the same `k = 0..4` order, and multiplies
//! the modulation gain after the full accumulation — memory layout
//! changes, arithmetic does not (Rust never reassociates or contracts
//! float ops, and the explicit SIMD kernels of [`super::simd`] evaluate
//! the same association per lane with no FMA, so vectorization cannot
//! perturb results). The segmented path reassociates only where the
//! reference decomposition (`scan_l2r_split`) does, and reproduces *its*
//! bits exactly. The opt-in `scan.precision = bf16` mode (see
//! [`super::simd`]) narrows staged taps and chained panels to bf16
//! storage and is the one deliberate exception: tolerance-pinned, never
//! the default.
//!
//! **Workspace pooling.** Every per-call scratch buffer — staged-tap
//! panels, pack/scan slabs, retained phase-1 panels (`hbufs`), wavefront
//! piece buffers, and the correction columns — is leased from a
//! [`BufferPool`] workspace instead of `vec!`-allocated, so steady-state
//! serving of a warm bucket performs zero heap allocations in the scan
//! hot path (pinned by the pool-miss counter tests). Leases return on
//! drop, *including during unwinding*, so a panicking piece job cannot
//! leak scratch. Buffers the old code relied on being zeroed (carry and
//! `zeros` columns, correction ping-pong, retained panels) are
//! re-acquired through [`BufferPool::acquire_zeroed`]; fully-overwritten
//! buffers (pack/scan slabs, staged taps, staging columns) skip the
//! reset — bit-exactness is unchanged either way, pinned by the
//! pooled-vs-fresh property tests. The one deliberate non-pooled
//! allocation is the output tensor itself: it escapes to the caller (the
//! serving reply), so its storage cannot return to the pool.

use super::direction::{merge_weights, Direction, DIRECTIONS};
use super::plan::{self, ScanGeometry, ScanStrategy};
use super::simd::{self, bf16_narrow, bf16_widen, EpOp, Precision, TapPanels};
use super::taps::{Taps, TAP_CENTER, TAP_DOWN, TAP_UP};
use crate::tensor::Tensor;
use crate::util::workspace::{
    BlockBoard, BufferPool, Lease, BLOCK_AGG, BLOCK_POISONED, BLOCK_PREFIX,
};
use crate::util::{lock_unpoisoned, GraphBuilder, NodeId, ThreadPool};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Canonical columns staged per slab. 32 columns keep the b/h slabs
/// L1-resident up to H = 256 while amortizing the slab loop overhead;
/// measured best among {8, 16, 32} at both acceptance geometries.
/// Crate-visible so the planner's workspace-footprint model
/// ([`plan::workspace_footprint`]) sizes slab leases with the engine's
/// real constant.
pub(crate) const SLAB: usize = 32;

// ---------------------------------------------------------------------
// Taps staging: full column-major panels, shared across channel planes
// ---------------------------------------------------------------------

/// Transpose an `h x w` row-major plane into a `w`-columns-of-`h` panel
/// (`dst[i*h + r] = src[r*w + i]`) through an 8x8 tile buffer, so reads
/// are contiguous and writes flush in contiguous 8-float runs.
fn transpose_plane(src: &[f32], h: usize, w: usize, dst: &mut [f32]) {
    const T: usize = 8;
    let mut tmp = [0.0f32; T * T];
    let mut r0 = 0;
    while r0 + T <= h {
        let mut i0 = 0;
        while i0 + T <= w {
            for r in 0..T {
                let row = &src[(r0 + r) * w + i0..(r0 + r) * w + i0 + T];
                for i in 0..T {
                    tmp[i * T + r] = row[i];
                }
            }
            for i in 0..T {
                dst[(i0 + i) * h + r0..(i0 + i) * h + r0 + T]
                    .copy_from_slice(&tmp[i * T..i * T + T]);
            }
            i0 += T;
        }
        while i0 < w {
            for r in r0..r0 + T {
                dst[i0 * h + r] = src[r * w + i0];
            }
            i0 += 1;
        }
        r0 += T;
    }
    while r0 < h {
        for i in 0..w {
            dst[i * h + r0] = src[r0 * w + i];
        }
        r0 += 1;
    }
}

/// Narrowing twin of [`transpose_plane`]: the same 8x8 tile walk, but
/// each store rounds to bf16 through the tile buffer, so the
/// reduced-precision mode writes its staged panels directly at half
/// width — no full-width f32 staging temporary ever exists, which is
/// what actually halves the staged footprint.
fn transpose_plane_bf16(src: &[f32], h: usize, w: usize, dst: &mut [u16]) {
    const T: usize = 8;
    let mut tmp = [0.0f32; T * T];
    let mut r0 = 0;
    while r0 + T <= h {
        let mut i0 = 0;
        while i0 + T <= w {
            for r in 0..T {
                let row = &src[(r0 + r) * w + i0..(r0 + r) * w + i0 + T];
                for i in 0..T {
                    tmp[i * T + r] = row[i];
                }
            }
            for i in 0..T {
                let col = &mut dst[(i0 + i) * h + r0..(i0 + i) * h + r0 + T];
                for (o, &v) in col.iter_mut().zip(&tmp[i * T..i * T + T]) {
                    *o = bf16_narrow(v);
                }
            }
            i0 += T;
        }
        while i0 < w {
            for r in r0..r0 + T {
                dst[i0 * h + r] = bf16_narrow(src[r * w + i0]);
            }
            i0 += 1;
        }
        r0 += T;
    }
    while r0 < h {
        for i in 0..w {
            dst[i * h + r0] = bf16_narrow(src[r0 * w + i]);
        }
        r0 += 1;
    }
}

/// Taps of one direction re-staged into column-major panels, shared
/// read-only across all plane jobs. With the channel-shared weights of
/// §4.2 (`Cw == 1`) each tap plane is staged once per batch item and
/// every channel plane reuses it.
struct StagedTaps<'w> {
    /// Layout: per (ni*cw + ci), three `hc x wc` column-major panels in
    /// tap order (up, center, down). Leased from the workspace; every
    /// element is written by the staging transpose before any read, so
    /// the lease is not zero-reset. At `Precision::Bf16` the panels are
    /// bf16 words packed two-per-f32-slot ([`Lease::as_u16`]) and the
    /// lease is `bf16_len` of the f32 size — half the bytes.
    data: Lease<'w>,
    cw: usize,
    plane: usize,
    prec: Precision,
}

impl<'w> StagedTaps<'w> {
    fn build(
        taps: &Taps,
        pool: Option<&ThreadPool>,
        ws: &'w BufferPool,
        prec: Precision,
    ) -> StagedTaps<'w> {
        let (hc, wc) = (taps.h, taps.w);
        let plane = hc * wc;
        let blocks = taps.n * taps.cw;
        match prec {
            Precision::F32 => {
                let mut data = ws.acquire(blocks * 3 * plane);
                let stage_block = |(b, dst): (usize, &mut [f32])| {
                    let src = &taps.t.data[b * 3 * plane..(b + 1) * 3 * plane];
                    for tap in [TAP_UP, TAP_CENTER, TAP_DOWN] {
                        transpose_plane(
                            &src[tap * plane..(tap + 1) * plane],
                            hc,
                            wc,
                            &mut dst[tap * plane..(tap + 1) * plane],
                        );
                    }
                };
                match pool {
                    Some(pool) if blocks > 1 && plane >= 1 << 12 => {
                        let jobs: Vec<(usize, &mut [f32])> =
                            data.chunks_mut(3 * plane).enumerate().collect();
                        pool.map(jobs, stage_block);
                    }
                    _ => {
                        for job in data.chunks_mut(3 * plane).enumerate() {
                            stage_block(job);
                        }
                    }
                }
                StagedTaps { data, cw: taps.cw, plane, prec }
            }
            Precision::Bf16 => {
                let mut data = ws.acquire(simd::bf16_len(blocks * 3 * plane));
                let stage_block = |(b, dst): (usize, &mut [u16])| {
                    let src = &taps.t.data[b * 3 * plane..(b + 1) * 3 * plane];
                    for tap in [TAP_UP, TAP_CENTER, TAP_DOWN] {
                        transpose_plane_bf16(
                            &src[tap * plane..(tap + 1) * plane],
                            hc,
                            wc,
                            &mut dst[tap * plane..(tap + 1) * plane],
                        );
                    }
                };
                let words = &mut data.as_u16_mut()[..blocks * 3 * plane];
                match pool {
                    Some(pool) if blocks > 1 && plane >= 1 << 12 => {
                        let jobs: Vec<(usize, &mut [u16])> =
                            words.chunks_mut(3 * plane).enumerate().collect();
                        pool.map(jobs, stage_block);
                    }
                    _ => {
                        for job in words.chunks_mut(3 * plane).enumerate() {
                            stage_block(job);
                        }
                    }
                }
                StagedTaps { data, cw: taps.cw, plane, prec }
            }
        }
    }

    /// The three staged panels for channel `ci` of batch item `ni`
    /// (clamped for shared mode), at the staging precision.
    #[inline]
    fn panels(&self, ni: usize, ci: usize) -> TapPanels<'_> {
        let c = if self.cw == 1 { 0 } else { ci };
        let base = (ni * self.cw + c) * 3 * self.plane;
        match self.prec {
            Precision::F32 => {
                let s = &self.data[base..base + 3 * self.plane];
                TapPanels::F32 {
                    tu: &s[TAP_UP * self.plane..(TAP_UP + 1) * self.plane],
                    tc: &s[TAP_CENTER * self.plane..(TAP_CENTER + 1) * self.plane],
                    td: &s[TAP_DOWN * self.plane..(TAP_DOWN + 1) * self.plane],
                }
            }
            Precision::Bf16 => {
                let s = &self.data.as_u16()[base..base + 3 * self.plane];
                TapPanels::Bf16 {
                    tu: &s[TAP_UP * self.plane..(TAP_UP + 1) * self.plane],
                    tc: &s[TAP_CENTER * self.plane..(TAP_CENTER + 1) * self.plane],
                    td: &s[TAP_DOWN * self.plane..(TAP_DOWN + 1) * self.plane],
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Pack: gather b = lam ⊙ x column slabs with orientation folded in
// ---------------------------------------------------------------------

/// How a direction's activations are laid out: shared spatial tensors
/// (orientation folded into the gather) or per-direction canonical
/// row-major tensors (the compact unit's case — its 1x1 projections
/// already produced canonical layouts, so the gather is a straight
/// transpose).
#[derive(Clone, Copy)]
enum Orientation {
    Spatial,
    Canonical,
}

/// Pack canonical columns `i0..i0+sw` of `b = lam ⊙ x` into the
/// column-major slab (`b[i*hc + r]` = canonical column `i0+i`, row `r`).
/// The product is the exact `ls[p] * xs[p]` unit of the reference
/// expression, computed during the gather so `x` and `lam` are each read
/// once and no staged copy of either exists.
#[allow(clippy::too_many_arguments)]
fn pack_slab(
    xs: &[f32],
    ls: &[f32],
    h: usize,
    w: usize,
    d: Direction,
    layout: Orientation,
    i0: usize,
    sw: usize,
    hc: usize,
    b: &mut [f32],
) {
    match (layout, d) {
        // Spatial L2R and every canonical layout: canonical (r, i) is
        // row-major (r, i) of the source with dims (hc, wc) — for
        // spatial L2R those are just (H, W), so one transposing gather
        // covers both.
        (Orientation::Canonical, _) | (Orientation::Spatial, Direction::L2R) => {
            let wr = hw_src(h, w, d).1;
            for r in 0..hc {
                let base = r * wr + i0;
                let (xr, lr) = (&xs[base..base + sw], &ls[base..base + sw]);
                for i in 0..sw {
                    b[i * hc + r] = lr[i] * xr[i];
                }
            }
        }
        (Orientation::Spatial, Direction::R2L) => {
            // canonical (r, i) = spatial (r, W-1-i).
            for r in 0..h {
                let row = r * w;
                for i in 0..sw {
                    let p = row + w - 1 - (i0 + i);
                    b[i * hc + r] = ls[p] * xs[p];
                }
            }
        }
        (Orientation::Spatial, Direction::T2B) => {
            // canonical column i0+i is spatial row i0+i: contiguous on
            // both sides.
            for i in 0..sw {
                let row = (i0 + i) * w;
                let (xr, lr) = (&xs[row..row + w], &ls[row..row + w]);
                let bc = &mut b[i * hc..i * hc + hc];
                for r in 0..hc {
                    bc[r] = lr[r] * xr[r];
                }
            }
        }
        (Orientation::Spatial, Direction::B2T) => {
            // canonical column i0+i is spatial row H-1-(i0+i).
            for i in 0..sw {
                let row = (h - 1 - (i0 + i)) * w;
                let (xr, lr) = (&xs[row..row + w], &ls[row..row + w]);
                let bc = &mut b[i * hc..i * hc + hc];
                for r in 0..hc {
                    bc[r] = lr[r] * xr[r];
                }
            }
        }
    }
}

/// Source row-major dims for a direction/layout pair: spatial tensors
/// keep (H, W); canonical tensors are stored as (hc, wc).
#[inline]
fn hw_src(h: usize, w: usize, d: Direction) -> (usize, usize) {
    match d {
        Direction::L2R | Direction::R2L => (h, w),
        Direction::T2B | Direction::B2T => (w, h),
    }
}

// ---------------------------------------------------------------------
// Scan: the unit-stride staged kernel
// ---------------------------------------------------------------------

// The per-column kernels — the scan recurrence (`up + ct + dn + b` with
// literal `0.0` boundary terms, exactly `core::scan_plane`'s expression)
// and the carry-correction fold (the same recurrence without the `b`
// term, exactly `split::phase2_plane`'s association) — live in
// [`super::simd`] as `scan_col` / `correct_col`: a pinned scalar
// reference plus runtime-dispatched AVX2/NEON lane kernels that are
// bit-identical to it. The engine calls them through the dispatcher so
// every strategy path picks up the active kernel and tap precision.

/// Scan one slab of canonical columns. `carry` holds the previous
/// slab's last column on entry and this slab's last column on return —
/// the "shared-memory" column handed across slab boundaries. Chunk
/// resets (`gi % chunk == 0`) substitute the zero column, exactly like
/// the reference's `hprev` reset.
#[allow(clippy::too_many_arguments)]
fn scan_slab(
    hc: usize,
    i0: usize,
    sw: usize,
    chunk: usize,
    b: &[f32],
    taps: TapPanels,
    zeros: &[f32],
    carry: &mut [f32],
    hs: &mut [f32],
) {
    for i in 0..sw {
        let gi = i0 + i;
        let col = i * hc;
        let (done, rest) = hs.split_at_mut(col);
        let cur = &mut rest[..hc];
        let prev: &[f32] = if gi % chunk == 0 {
            &zeros[..hc]
        } else if i == 0 {
            &carry[..hc]
        } else {
            &done[col - hc..]
        };
        simd::scan_col(prev, &b[col..col + hc], taps.col(gi, hc), cur);
    }
    carry[..hc].copy_from_slice(&hs[(sw - 1) * hc..sw * hc]);
}

// ---------------------------------------------------------------------
// Scatter-back epilogue: inverse orientation + merge + modulation
// ---------------------------------------------------------------------

/// Drain a scanned slab back to the spatial plane, mapping canonical
/// (r, i0+i) to its spatial position and applying the epilogue op
/// (assign, weighted merge, or merge + modulation) per element. This is
/// the step that deletes the directional intermediates, the separate
/// accumulation loop, and `output_modulation`'s clone.
///
/// The op is a [`EpOp`] value, not a closure: the T2B/B2T arms drain in
/// contiguous `w`-length runs on *both* sides and dispatch to the batch
/// lane kernels ([`simd::ep_apply`]), while the L2R/R2L arms read the
/// slab with stride `hc` and apply the same pinned per-element
/// expression ([`EpOp::apply`]) scalar — bit-identical either way (a
/// strided gather was measured not worth the complexity on the row
/// arms; the column arms are where the epilogue bytes move).
fn scatter_slab(
    hs: &[f32],
    h: usize,
    w: usize,
    d: Direction,
    i0: usize,
    sw: usize,
    hc: usize,
    out: &mut [f32],
    op: EpOp,
) {
    match d {
        Direction::L2R => {
            for r in 0..h {
                let orow = &mut out[r * w + i0..r * w + i0 + sw];
                for (i, o) in orow.iter_mut().enumerate() {
                    *o = op.apply(*o, hs[i * hc + r]);
                }
            }
        }
        Direction::R2L => {
            for r in 0..h {
                let row = r * w;
                for i in 0..sw {
                    let p = row + w - 1 - (i0 + i);
                    out[p] = op.apply(out[p], hs[i * hc + r]);
                }
            }
        }
        Direction::T2B => {
            for i in 0..sw {
                let row = (i0 + i) * w;
                let orow = &mut out[row..row + w];
                let hcol = &hs[i * hc..i * hc + hc];
                simd::ep_apply(op, orow, &hcol[..w]);
            }
        }
        Direction::B2T => {
            for i in 0..sw {
                let row = (h - 1 - (i0 + i)) * w;
                let orow = &mut out[row..row + w];
                let hcol = &hs[i * hc..i * hc + hc];
                simd::ep_apply(op, orow, &hcol[..w]);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Per-job scratch + block sizing
// ---------------------------------------------------------------------

/// Per-job scratch: the b and h column slabs, the carry column, and the
/// zero column used at chunk resets. One per pool job, reused across
/// every plane (and direction) the job owns. Leased from the workspace:
/// the slabs are fully overwritten before every read, the carry/zeros
/// columns must start zero (the reference semantics), so only those two
/// are zero-reset.
struct FusedScratch<'w> {
    b: Lease<'w>,
    h: Lease<'w>,
    carry: Lease<'w>,
    zeros: Lease<'w>,
}

impl<'w> FusedScratch<'w> {
    fn new(hmax: usize, ws: &'w BufferPool) -> FusedScratch<'w> {
        FusedScratch {
            b: ws.acquire(SLAB * hmax),
            h: ws.acquire(SLAB * hmax),
            carry: ws.acquire_zeroed(hmax),
            zeros: ws.acquire_zeroed(hmax),
        }
    }
}

/// Number of plane-blocks to submit for `nplanes` planes: about two
/// blocks per worker for load balance, never more blocks than planes.
/// This is the "one kernel launch" fix: job count scales with the pool,
/// not with N·C. Shared with `Proj::apply`'s block dispatch so the
/// blocks-per-worker policy has one source of truth.
pub(crate) fn plane_blocks(nplanes: usize, threads: usize) -> usize {
    nplanes.min((2 * threads).max(1))
}

// ---------------------------------------------------------------------
// Segment-parallel decomposition (strategy selection lives in plan.rs)
// ---------------------------------------------------------------------

/// Segment bounds over `wc` canonical columns — the same decomposition
/// formula as `scan_l2r_split`, so for equal counts the segmented
/// arithmetic (and therefore every bit) matches the reference.
fn segment_bounds(wc: usize, segments: usize) -> Vec<(usize, usize)> {
    let segments = segments.clamp(1, wc.max(1));
    let seg_len = wc.div_ceil(segments).max(1);
    (0..wc).step_by(seg_len).map(|lo| (lo, (lo + seg_len).min(wc))).collect()
}

/// How a segmented run's phase 2 (carry correction + epilogue drain) is
/// scheduled and expressed. All three produce identical bits (pinned by
/// tests); they differ in memory traffic and overlap.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Phase2 {
    /// Global two-`map` barrier between the phases; correction fused
    /// into the drain.
    Barrier,
    /// The PR 4 schedule: one continuation per plane running the
    /// *two-pass* correct-then-drain ([`correct_and_drain_pieces`]) —
    /// it re-touches the retained panel in place before the drain
    /// re-reads it. Kept as the bit/bench reference the fused drain is
    /// measured against (`BENCH_scan`'s "two-pass" rows).
    WavePlane,
    /// Per-direction wavefront continuations (4 per plane) with the
    /// correction fused into the scatter drain — the production
    /// schedule behind every `wavefront` plan.
    WaveDir,
}

/// How an engine run decomposes its work across the pool. The engine
/// holds no selection heuristics of its own: `Auto` defers to the
/// planner ([`plan::plan_scan`]), `Forced` carries a caller- or
/// test-chosen plan verbatim.
#[derive(Clone, Copy)]
enum ExecSpec {
    /// Consult [`plan::plan_scan`] from the pass geometry + pool state.
    Auto,
    /// Execute exactly this strategy (segment counts clamped per
    /// direction to its canonical width) with the given phase-2
    /// schedule — the bit-identity testing / bench / plan-carrying
    /// hook.
    Forced(ScanStrategy, Phase2),
}

// ---------------------------------------------------------------------
// Input descriptors + engine core
// ---------------------------------------------------------------------

/// One direction's inputs to the fused engine.
struct DirInput<'a> {
    d: Direction,
    taps: &'a Taps,
    x: &'a Tensor,
    lam: &'a Tensor,
    layout: Orientation,
    /// Effective chunk width in canonical columns.
    chunk: usize,
}

fn effective_chunk(wc: usize, kchunk: usize) -> usize {
    let chunk = if kchunk == 0 { wc } else { kchunk };
    assert!(wc % chunk == 0, "kchunk={chunk} must divide W={wc}");
    chunk
}

fn validate_dir(x: &Tensor, taps: &Taps, lam: &Tensor, d: Direction) {
    assert_eq!(x.rank(), 4, "x must be (N, C, H, W)");
    assert_eq!(x.shape, lam.shape, "lam shape must match x");
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (hc, wc) = hw_src(h, w, d);
    assert_eq!((taps.n, taps.h, taps.w), (n, hc, wc), "taps geometry mismatch");
    assert!(taps.cw == 1 || taps.cw == c, "Cw must be 1 or C");
}

/// The fused per-plane pipeline: for each direction in order, walk the
/// plane in column slabs — pack `b = lam ⊙ x`, scan, scatter with the
/// epilogue op (assign / weighted merge / merge + modulate) — so every
/// staged value is consumed while still L1-hot.
#[allow(clippy::too_many_arguments)]
fn run_plane(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<f32>,
    ni: usize,
    ci: usize,
    c: usize,
    hw: (usize, usize),
    os: &mut [f32],
    scratch: &mut FusedScratch<'_>,
) {
    let (h, w) = hw;
    let plane = h * w;
    let last = dirs.len() - 1;
    for (k, di) in dirs.iter().enumerate() {
        let (hc, wc) = (di.taps.h, di.taps.w);
        let base = (ni * c + ci) * plane;
        let xs = &di.x.data[base..base + plane];
        let ls = &di.lam.data[base..base + plane];
        let taps = staged[k].panels(ni, ci);
        let mut i0 = 0;
        while i0 < wc {
            let sw = SLAB.min(wc - i0);
            pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut scratch.b);
            scan_slab(
                hc,
                i0,
                sw,
                di.chunk,
                &scratch.b,
                taps,
                &scratch.zeros,
                &mut scratch.carry,
                &mut scratch.h,
            );
            drain_scatter(&scratch.h, h, w, di.d, i0, sw, hc, os, wts, k, last, gain);
            i0 += sw;
        }
    }
}

/// The one epilogue-op dispatch every drain site shares: scatter `hs`
/// back to the spatial plane with the per-element op the pass calls for
/// — assign (single direction), weighted merge accumulate, or, on the
/// last direction of a modulated pass, merge + `u ⊙ h` gain. Keeping
/// this in one place is what keeps the plane, barrier-segmented,
/// wavefront, and dirfan drains bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn drain_scatter(
    hs: &[f32],
    h: usize,
    w: usize,
    d: Direction,
    i0: usize,
    sw: usize,
    hc: usize,
    os: &mut [f32],
    wts: Option<&[f32; 4]>,
    k: usize,
    last: usize,
    gain: Option<f32>,
) {
    let op = match wts {
        None => EpOp::Assign,
        Some(wts) => {
            let wt = wts[k];
            match gain.filter(|_| k == last) {
                None => EpOp::Merge(wt),
                Some(g) => EpOp::MergeGain(wt, g),
            }
        }
    };
    scatter_slab(hs, h, w, d, i0, sw, hc, os, op);
}

/// Materialize the engine's output tensor: the caller-recycled buffer
/// (must be zeroed and exactly `numel` long — the coordinator's
/// reply-recycling path, see [`fused_scan_l2r_pool_ws_into`]) or a
/// fresh zeroed allocation. The recycled buffer only replaces
/// `Tensor::zeros`, so every drain writes the same bits either way.
fn out_tensor(shape: &[usize], recycled: Option<Vec<f32>>) -> Tensor {
    match recycled {
        Some(buf) => {
            debug_assert!(buf.iter().all(|&v| v == 0.0), "recycled output must be zeroed");
            Tensor::from_vec(shape, buf)
        }
        None => Tensor::zeros(shape),
    }
}

/// Drive the fused pipeline over all (N·C) planes — serially, in
/// block-granular plane jobs on the pool, or (when the plan asks for
/// it) through the segment-parallel / direction-fan decompositions,
/// with or without wavefront continuations. `out_buf`, when given, is a
/// recycled zeroed buffer the output tensor is built over instead of a
/// fresh allocation.
#[allow(clippy::too_many_arguments)]
fn run_engine(
    dirs: &[DirInput<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    exec: ExecSpec,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
    prec: Option<Precision>,
) -> Tensor {
    let (n, c) = (out_shape[0], out_shape[1]);
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = n * c;
    if nplanes == 0 || plane == 0 {
        return out_tensor(out_shape, out_buf);
    }
    let hmax = h.max(w);
    let prec = prec.unwrap_or_else(simd::precision);
    let staged: Vec<StagedTaps<'_>> =
        dirs.iter().map(|d| StagedTaps::build(d.taps, pool, ws, prec)).collect();
    let (strategy, phase2) = match exec {
        ExecSpec::Forced(s, p2) => (s, p2),
        ExecSpec::Auto => match pool {
            Some(pool) => {
                let geom = ScanGeometry {
                    nplanes,
                    ndirs: dirs.len(),
                    wc_min: dirs.iter().map(|di| di.taps.w).min().unwrap_or(0),
                    plane_px: plane,
                    hmax,
                };
                let p = plan::plan_scan(&geom, pool.load(), pool.threads());
                // A wavefront plan means the per-direction continuation
                // schedule; the PR 4 per-plane two-pass schedule is
                // test/bench-only.
                let p2 = if p.wavefront { Phase2::WaveDir } else { Phase2::Barrier };
                (p.strategy, p2)
            }
            None => (ScanStrategy::PlanePar, Phase2::Barrier),
        },
    };
    let segments = match strategy {
        ScanStrategy::PlanePar => None,
        ScanStrategy::Segmented { s } => Some(s.max(1)),
        // The chained strategy runs its own single-pass engine: there
        // are no phases, so the phase-2 schedule does not apply.
        ScanStrategy::Chained { s } => {
            return run_engine_chained(
                dirs, &staged, wts, gain, out_shape, pool, s.max(1), ws, out_buf, prec,
            );
        }
        // The direction fan is the s = 1 degenerate segmented run: one
        // full-width zero-carry (i.e. exact) phase-1 job per (plane,
        // direction), no correction, fixed-order merge drain. A
        // single-direction pass has nothing to fan: plane path.
        ScanStrategy::DirFan => (dirs.len() > 1).then_some(1),
    };
    if let Some(segments) = segments {
        return run_engine_segmented(
            dirs, &staged, wts, gain, out_shape, pool, segments, phase2, ws, out_buf,
        );
    }
    let mut out = out_tensor(out_shape, out_buf);
    let gain_for = |ci: usize| gain.map(|g| g[ci]);

    match pool {
        Some(pool) if nplanes > 1 && pool.threads() > 1 => {
            let nblocks = plane_blocks(nplanes, pool.threads());
            let per_block = nplanes.div_ceil(nblocks);
            let jobs: Vec<(usize, &mut [f32])> =
                out.data.chunks_mut(per_block * plane).enumerate().collect();
            pool.map(jobs, |(bi, block)| {
                let mut scratch = FusedScratch::new(hmax, ws);
                for (j, os) in block.chunks_mut(plane).enumerate() {
                    let p = bi * per_block + j;
                    run_plane(
                        dirs,
                        &staged,
                        wts,
                        gain_for(p % c),
                        p / c,
                        p % c,
                        c,
                        (h, w),
                        os,
                        &mut scratch,
                    );
                }
            });
        }
        _ => {
            let mut scratch = FusedScratch::new(hmax, ws);
            for (p, os) in out.data.chunks_mut(plane).enumerate() {
                run_plane(
                    dirs,
                    &staged,
                    wts,
                    gain_for(p % c),
                    p / c,
                    p % c,
                    c,
                    (h, w),
                    os,
                    &mut scratch,
                );
            }
        }
    }
    out
}

/// The segment-parallel engine (the fused §5.1 decomposition).
///
/// Phase 1 fans one job per (plane, direction, segment) — each packs and
/// unit-stride-scans its column range from a zero incoming carry with
/// the very same slab pipeline as the plane path, but retains the
/// canonical columns in a per-plane panel instead of scattering them
/// (chunk resets still fire on global column indices inside
/// [`scan_slab`]). Phase 2 fans one job per plane: for each direction it
/// chains the true carry across segment boundaries — the corrected last
/// column of segment k *is* segment k+1's carry — with the linear
/// correction scan (`correct_col` in [`super::simd`]) computed **on the fly inside the
/// scatter drain** ([`drain_dir_fused`]): the retained panel is read
/// once and never re-written, and the corrected values flow straight
/// through the fused scatter epilogue (inverse orientation + weighted
/// merge + modulation), so the directional output, merge, and
/// modulation intermediates still never exist — and neither does a
/// corrected copy of the panel.
///
/// Arithmetic per element is exactly `scan_l2r_split`'s two-phase order
/// (pinned `==` by tests); only the memory layout and the epilogue
/// fusion differ. The retained panels cost
/// O(nplanes · Σ_dirs hc·wc) floats — bounded in practice because the
/// planner only picks this path when `nplanes < threads`.
///
/// `phase2` selects the schedule: the two-`map` barrier below, or one
/// of the dependency-graph schedules of
/// [`run_engine_segmented_wave`] — same jobs, same bits, no global
/// rendezvous between phases.
#[allow(clippy::too_many_arguments)]
fn run_engine_segmented(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    segments: usize,
    phase2: Phase2,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
) -> Tensor {
    if phase2 != Phase2::Barrier {
        if let Some(pool) = pool {
            return run_engine_segmented_wave(
                dirs,
                staged,
                wts,
                gain,
                out_shape,
                pool,
                segments,
                phase2 == Phase2::WaveDir,
                ws,
                out_buf,
            );
        }
    }
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = out_shape[0] * c;
    let hmax = h.max(w);
    let bounds: Vec<Vec<(usize, usize)>> =
        dirs.iter().map(|di| segment_bounds(di.taps.w, segments)).collect();

    // Retained phase-1 canonical columns: per plane, the directions'
    // hc x wc column-major panels concatenated in direction order.
    let dir_off: Vec<usize> = dirs
        .iter()
        .scan(0usize, |acc, di| {
            let o = *acc;
            *acc += di.taps.h * di.taps.w;
            Some(o)
        })
        .collect();
    let per_plane: usize = dirs.iter().map(|di| di.taps.h * di.taps.w).sum();
    // Zero-reset like the fresh `vec!` it replaces: phase 1 overwrites
    // every panel element, but keeping the fresh-allocation semantics
    // makes the panels' contents independent of pool history by
    // construction (bit-exactness needs no full-coverage argument).
    let mut hbufs = ws.acquire_zeroed(nplanes * per_plane);

    // Phase 1: every (plane, direction, segment) scans independently
    // from a zero carry into its disjoint panel range.
    {
        let mut jobs: Vec<(usize, usize, usize, usize, &mut [f32])> = Vec::new();
        let mut rest: &mut [f32] = &mut hbufs;
        for p in 0..nplanes {
            for (k, di) in dirs.iter().enumerate() {
                for &(lo, hi) in &bounds[k] {
                    let (buf, tail) =
                        std::mem::take(&mut rest).split_at_mut((hi - lo) * di.taps.h);
                    rest = tail;
                    jobs.push((p, k, lo, hi, buf));
                }
            }
        }
        let scan_piece = |(p, k, lo, hi, buf): (usize, usize, usize, usize, &mut [f32])| {
            scan_piece_into(dirs, staged, c, (h, w), hmax, p, k, lo, hi, buf, ws);
        };
        match pool {
            Some(pool) if pool.threads() > 1 && jobs.len() > 1 => {
                pool.map(jobs, scan_piece);
            }
            _ => jobs.into_iter().for_each(scan_piece),
        }
    }

    // Phase 2: per plane, drain each direction's retained panel through
    // the fused correction + scatter epilogue in the same k = 0..dirs
    // order as the plane path. The panel is read-only from here on —
    // the correction never lands back in it.
    let mut out = out_tensor(out_shape, out_buf);
    let gain_for = |ci: usize| gain.map(|g| g[ci]);
    let last = dirs.len() - 1;
    let planes: Vec<(usize, &mut [f32], &[f32])> = out
        .data
        .chunks_mut(plane)
        .zip(hbufs.chunks(per_plane))
        .enumerate()
        .map(|(p, (os, pb))| (p, os, pb))
        .collect();
    let correct_and_drain = |(p, os, pb): (usize, &mut [f32], &[f32])| {
        let mut scratch = DrainScratch::new(hmax, ws);
        for (k, di) in dirs.iter().enumerate() {
            let (hc, wc) = (di.taps.h, di.taps.w);
            let taps = staged[k].panels(p / c, p % c);
            let panel = &pb[dir_off[k]..dir_off[k] + hc * wc];
            let pieces: Vec<&[f32]> =
                bounds[k].iter().map(|&(lo, hi)| &panel[lo * hc..hi * hc]).collect();
            drain_dir_fused(
                &pieces,
                &bounds[k],
                hc,
                di.chunk,
                taps,
                (h, w),
                di.d,
                os,
                wts,
                k,
                last,
                gain_for(p % c),
                &mut scratch,
            );
        }
    };
    match pool {
        Some(pool) if pool.threads() > 1 && planes.len() > 1 => {
            pool.map(planes, correct_and_drain);
        }
        _ => planes.into_iter().for_each(correct_and_drain),
    }
    out
}

// ---------------------------------------------------------------------
// Shared phase bodies + wavefront scheduling (phase 2 as a per-plane
// continuation)
// ---------------------------------------------------------------------

/// Phase 1 of one (plane, direction, segment) piece: pack and
/// unit-stride-scan columns `[lo, hi)` from a zero incoming carry into
/// `buf` (column-major, `(hi - lo) * hc`). The one shared phase-1 body
/// — the barrier engine calls it on preallocated panel slices, the
/// wavefront engine on owned piece buffers — so the two schedules
/// cannot drift apart arithmetically.
#[allow(clippy::too_many_arguments)]
fn scan_piece_into(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    p: usize,
    k: usize,
    lo: usize,
    hi: usize,
    buf: &mut [f32],
    ws: &BufferPool,
) {
    let (h, w) = hw;
    let plane = h * w;
    let di = &dirs[k];
    let hc = di.taps.h;
    let base = p * plane;
    let xs = &di.x.data[base..base + plane];
    let ls = &di.lam.data[base..base + plane];
    let taps = staged[k].panels(p / c, p % c);
    // The pack slab is fully overwritten per slab; the carry must start
    // zero (a piece scans from a zero incoming carry and READS the carry
    // on its first column when `lo` is off a chunk boundary), and the
    // reset column must stay zero.
    let mut b = ws.acquire(SLAB * hmax);
    let mut carry = ws.acquire_zeroed(hmax);
    let zeros = ws.acquire_zeroed(hmax);
    let mut i0 = lo;
    while i0 < hi {
        let sw = SLAB.min(hi - i0);
        pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut b);
        let o = (i0 - lo) * hc;
        scan_slab(
            hc,
            i0,
            sw,
            di.chunk,
            &b,
            taps,
            &zeros,
            &mut carry,
            &mut buf[o..o + sw * hc],
        );
        i0 += sw;
    }
}

/// [`scan_piece_into`] retaining the piece as packed bf16 words — the
/// chained engine's reduced-precision panel path. The recurrence is
/// untouched: every slab scans in f32 through the very same
/// [`scan_slab`] (the f32 carry column crosses slab boundaries exactly
/// as in f32 mode), and only the *store* into the retained panel
/// narrows, via round-to-nearest-even. `agg` receives the piece's last
/// column at full f32 precision — the publication-board aggregate, so
/// look-back folds lose nothing to the panel narrowing.
#[allow(clippy::too_many_arguments)]
fn scan_piece_into_bf16(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    p: usize,
    k: usize,
    lo: usize,
    hi: usize,
    panel: &mut [u16],
    agg: &mut [f32],
    ws: &BufferPool,
) {
    let (h, w) = hw;
    let plane = h * w;
    let di = &dirs[k];
    let hc = di.taps.h;
    let base = p * plane;
    let xs = &di.x.data[base..base + plane];
    let ls = &di.lam.data[base..base + plane];
    let taps = staged[k].panels(p / c, p % c);
    let mut b = ws.acquire(SLAB * hmax);
    // f32 staging slab the scan lands in before narrowing; fully
    // overwritten per slab.
    let mut hslab = ws.acquire(SLAB * hmax);
    let mut carry = ws.acquire_zeroed(hmax);
    let zeros = ws.acquire_zeroed(hmax);
    let mut i0 = lo;
    while i0 < hi {
        let sw = SLAB.min(hi - i0);
        pack_slab(xs, ls, h, w, di.d, di.layout, i0, sw, hc, &mut b);
        scan_slab(
            hc,
            i0,
            sw,
            di.chunk,
            &b,
            taps,
            &zeros,
            &mut carry,
            &mut hslab[..sw * hc],
        );
        let o = (i0 - lo) * hc;
        for (dst, &v) in panel[o..o + sw * hc].iter_mut().zip(&hslab[..sw * hc]) {
            *dst = bf16_narrow(v);
        }
        i0 += sw;
    }
    agg.copy_from_slice(&carry[..agg.len()]);
}

/// The one shared carry-correction body: add the linear correction scan
/// seeded by `cin` onto segment columns `[lo, hi)` held in `seg`
/// (column-major within the segment), dying at chunk resets. Callers
/// own the zero-carry skip (the reference decomposition elides all-zero
/// corrections, which keeps even -0.0 pixels bit-identical).
#[allow(clippy::too_many_arguments)]
fn correct_segment<'w>(
    hc: usize,
    chunk: usize,
    lo: usize,
    hi: usize,
    taps: TapPanels<'_>,
    cin: &[f32],
    corr: &mut Lease<'w>,
    next: &mut Lease<'w>,
    seg: &mut [f32],
) {
    corr[..hc].copy_from_slice(&cin[..hc]);
    for (j, gi) in (lo..hi).enumerate() {
        if gi % chunk == 0 {
            // Chunk reset: the carry dies here and phase 1 was already
            // exact from this column on.
            break;
        }
        simd::correct_col(&corr[..hc], taps.col(gi, hc), &mut next[..hc]);
        for (o, &v) in seg[j * hc..(j + 1) * hc].iter_mut().zip(&next[..hc]) {
            *o += v;
        }
        std::mem::swap(corr, next);
    }
}

/// [`correct_segment`] over a bf16-stored segment: the correction
/// recurrence itself runs in f32 (it never reads panel values), and
/// each corrected element decodes, adds in f32, and re-encodes with
/// round-to-nearest-even — the chained engine's reduced-precision
/// panel path. Chunk-reset and zero-carry semantics are identical to
/// the f32 body.
#[allow(clippy::too_many_arguments)]
fn correct_segment_bf16<'w>(
    hc: usize,
    chunk: usize,
    lo: usize,
    hi: usize,
    taps: TapPanels<'_>,
    cin: &[f32],
    corr: &mut Lease<'w>,
    next: &mut Lease<'w>,
    seg: &mut [u16],
) {
    corr[..hc].copy_from_slice(&cin[..hc]);
    for (j, gi) in (lo..hi).enumerate() {
        if gi % chunk == 0 {
            // Chunk reset: the carry dies here and phase 1 was already
            // exact from this column on.
            break;
        }
        simd::correct_col(&corr[..hc], taps.col(gi, hc), &mut next[..hc]);
        for (o, &v) in seg[j * hc..(j + 1) * hc].iter_mut().zip(&next[..hc]) {
            *o = bf16_narrow(bf16_widen(*o) + v);
        }
        std::mem::swap(corr, next);
    }
}

/// Per-drain scratch: the correction ping-pong columns, the tracked
/// inter-segment carry, and the slab used to stage corrected columns
/// before they scatter. O(SLAB·max(H, W)) — the correction never needs
/// panel-sized scratch. The staging slab is leased lazily on the first
/// corrected column, so drains that never stage (DirFan's s = 1 runs,
/// zero-carry planes) pay only the three small columns. The three
/// columns are zero-reset (the zero-carry skip reads them); the staging
/// slab is fully overwritten before every read, so it is not.
struct DrainScratch<'w> {
    ws: &'w BufferPool,
    corr: Lease<'w>,
    next: Lease<'w>,
    carry: Lease<'w>,
    colb: Option<Lease<'w>>,
}

impl<'w> DrainScratch<'w> {
    fn new(hmax: usize, ws: &'w BufferPool) -> DrainScratch<'w> {
        DrainScratch {
            ws,
            corr: ws.acquire_zeroed(hmax),
            next: ws.acquire_zeroed(hmax),
            carry: ws.acquire_zeroed(hmax),
            colb: None,
        }
    }
}

/// The fused-correction drain for one (plane, direction): walk the
/// direction's phase-1 segment pieces in column order, computing the
/// linear carry correction *on the fly* and scattering `phase1 + corr`
/// straight through the epilogue op — the retained panel is read once
/// and written zero extra times (the two-pass reference re-touched the
/// whole corrected region in place first, then read it all again).
///
/// Bit-exactness vs the two-pass order ([`correct_segment`] +
/// [`drain_scatter`], and hence `split::phase2_plane`): the correction
/// recurrence `corr_i = w_i · corr_{i-1}` never reads panel values, so
/// fusing changes no operand of any float op — `phase1 + corr` is the
/// same f32 add whether it lands in the panel or in the drain, the
/// all-zero carry skip is identical (eliding the correction keeps even
/// -0.0 pixels bit-identical), and the carry handed to segment k+1 is
/// the same corrected last column, tracked out of band instead of
/// re-read from the panel. Chunk resets kill the correction exactly
/// where the two-pass loop `break`s (including a reset landing on the
/// segment's first column). Validated bitwise against the two-pass
/// mirror in C over ~9k randomized geometry/chunk/zero-carry cases
/// before porting, and pinned `==` by the schedule-matrix tests.
///
/// Corrected columns are staged through a [`SLAB`]-column buffer so the
/// scatter keeps the slab pipeline's write locality; columns with no
/// live correction (segment 0, a zero carry, or past a chunk reset —
/// once dead, a correction never revives within a segment) scatter
/// straight from the piece with no staging copy.
#[allow(clippy::too_many_arguments)]
fn drain_dir_fused(
    pieces: &[&[f32]],
    bounds: &[(usize, usize)],
    hc: usize,
    chunk: usize,
    taps: TapPanels<'_>,
    hw: (usize, usize),
    d: Direction,
    os: &mut [f32],
    wts: Option<&[f32; 4]>,
    k: usize,
    last: usize,
    gain: Option<f32>,
    s: &mut DrainScratch<'_>,
) {
    let (h, w) = hw;
    for (si, (&(lo, hi), piece)) in bounds.iter().zip(pieces).enumerate() {
        let seglen = hi - lo;
        // Incoming carry: the previous segment's (corrected) last
        // column. The reference decomposition skips all-zero carries;
        // matching the skip keeps even -0.0 pixels bit-identical.
        let mut active = si > 0 && !s.carry[..hc].iter().all(|&v| v == 0.0);
        if active {
            s.corr[..hc].copy_from_slice(&s.carry[..hc]);
        }
        let mut j = 0;
        while j < seglen {
            if !active {
                // Everything from here to the segment end is already
                // exact (zero incoming carry, or a chunk reset killed
                // the correction — it can never re-activate within a
                // segment): scatter straight from the piece, no
                // staging copy at all.
                drain_scatter(
                    &piece[j * hc..seglen * hc],
                    h,
                    w,
                    d,
                    lo + j,
                    seglen - j,
                    hc,
                    os,
                    wts,
                    k,
                    last,
                    gain,
                );
                s.carry[..hc].copy_from_slice(&piece[(seglen - 1) * hc..seglen * hc]);
                break;
            }
            let sw = SLAB.min(seglen - j);
            if s.colb.as_ref().map_or(true, |cb| cb.len() < SLAB * hc) {
                // Staging slab: every column is fully written before the
                // scatter reads it, so a plain (non-zeroed) lease.
                s.colb = Some(s.ws.acquire(SLAB * hc));
            }
            let colb = s.colb.as_mut().unwrap();
            for i in 0..sw {
                let gi = lo + j + i;
                let src = &piece[(j + i) * hc..(j + i + 1) * hc];
                if active && gi % chunk == 0 {
                    // Chunk reset: the carry dies here and phase 1 was
                    // already exact from this column on.
                    active = false;
                }
                let dst = &mut colb[i * hc..(i + 1) * hc];
                if active {
                    simd::correct_col(&s.corr[..hc], taps.col(gi, hc), &mut s.next[..hc]);
                    for ((o, &p1), &cv) in dst.iter_mut().zip(src).zip(&s.next[..hc]) {
                        *o = p1 + cv;
                    }
                    std::mem::swap(&mut s.corr, &mut s.next);
                } else {
                    dst.copy_from_slice(src);
                }
            }
            drain_scatter(&colb[..], h, w, d, lo + j, sw, hc, os, wts, k, last, gain);
            if j + sw == seglen {
                // The corrected last column *is* segment k+1's carry.
                s.carry[..hc].copy_from_slice(&colb[(sw - 1) * hc..sw * hc]);
            }
            j += sw;
        }
    }
}

/// [`drain_dir_fused`] over the wavefront engine's per-segment piece
/// slots: the body of one per-direction drain continuation. Takes the
/// direction's pieces out of their hand-off slots (the graph's
/// dependency edges ordered the accesses, so the locks are uncontended;
/// poisoned slots are recovered — see the module notes on panic
/// hygiene) and runs the fused-correction drain for direction `k` of
/// plane `p`.
#[allow(clippy::too_many_arguments)]
fn drain_dir_pieces_fused(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    bounds: &[Vec<(usize, usize)>],
    wts: Option<&[f32; 4]>,
    gain: Option<f32>,
    p: usize,
    k: usize,
    c: usize,
    hw: (usize, usize),
    slots: &[Mutex<Option<Lease<'_>>>],
    os: &mut [f32],
    scratch: &mut DrainScratch<'_>,
) {
    let di = &dirs[k];
    let hc = di.taps.h;
    let taps = staged[k].panels(p / c, p % c);
    // Taking the leases out of the slots moves ownership here: they
    // return to the workspace pool when `bufs` drops, on every exit
    // path — including the early return below.
    let bufs: Vec<Option<Lease<'_>>> =
        slots.iter().map(|s| lock_unpoisoned(s).take()).collect();
    // A missing or wrong-size piece means its phase-1 job panicked
    // before handing the panel over; `run_graph` already holds that
    // payload — skip quietly so the caller reports the real panic, not
    // a confusing secondary index/Poison error.
    if bufs
        .iter()
        .zip(&bounds[k])
        .any(|(b, &(lo, hi))| b.as_ref().map_or(true, |b| b.len() != (hi - lo) * hc))
    {
        return;
    }
    let pieces: Vec<&[f32]> = bufs.iter().map(|b| b.as_deref().unwrap()).collect();
    drain_dir_fused(
        &pieces,
        &bounds[k],
        hc,
        di.chunk,
        taps,
        hw,
        di.d,
        os,
        wts,
        k,
        dirs.len() - 1,
        gain,
        scratch,
    );
}

/// Phase 2 of one plane off per-segment panel pieces, in the retired
/// PR 4 *two-pass* form: chain the true carry across segment boundaries
/// (the corrected last column of segment k *is* segment k+1's carry),
/// add the linear correction scan **in place** (a full read-modify-write
/// of every corrected panel column), then drain each corrected segment
/// through the fused scatter epilogue in the same k = 0..dirs order as
/// the plane path. Kept as the bit/bench reference the fused-correction
/// drain ([`drain_dir_fused`]) is pinned `==` against and measured
/// over (every element sees the same values in the same order, so the
/// bits match).
#[allow(clippy::too_many_arguments)]
fn correct_and_drain_pieces(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    bounds: &[Vec<(usize, usize)>],
    wts: Option<&[f32; 4]>,
    gain: Option<f32>,
    p: usize,
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    slots: &[Mutex<Option<Lease<'_>>>],
    os: &mut [f32],
    ws: &BufferPool,
) {
    let (h, w) = hw;
    let last = dirs.len() - 1;
    // Zero-reset: the zero-carry skip below reads `carry` before any
    // write, and the correction columns keep fresh-`vec!` semantics.
    let mut corr = ws.acquire_zeroed(hmax);
    let mut next = ws.acquire_zeroed(hmax);
    let mut carry = ws.acquire_zeroed(hmax);
    let mut slot = 0usize;
    for (k, di) in dirs.iter().enumerate() {
        let hc = di.taps.h;
        let taps = staged[k].panels(p / c, p % c);
        for (si, &(lo, hi)) in bounds[k].iter().enumerate() {
            // Taking the lease moves ownership here; it returns to the
            // pool when `buf` drops, even on the early return below.
            let taken = lock_unpoisoned(&slots[slot]).take();
            slot += 1;
            // A missing or wrong-size piece means its phase-1 job
            // panicked before handing the panel over; `run_graph`
            // already holds that payload — bail quietly so the caller
            // reports the real panic, not a secondary index/Poison
            // error.
            let Some(mut buf) = taken else { return };
            if buf.len() != (hi - lo) * hc {
                return;
            }
            // Incoming carry: the previous segment's (corrected) last
            // column. The reference decomposition skips all-zero
            // carries; matching the skip keeps even -0.0 pixels
            // bit-identical.
            if si > 0 && !carry[..hc].iter().all(|&v| v == 0.0) {
                correct_segment(
                    hc, di.chunk, lo, hi, taps, &carry, &mut corr, &mut next, &mut buf,
                );
            }
            carry[..hc].copy_from_slice(&buf[(hi - lo - 1) * hc..(hi - lo) * hc]);
            drain_scatter(&buf, h, w, di.d, lo, hi - lo, hc, os, wts, k, last, gain);
        }
    }
}

/// The wavefront-scheduled segmented engine: the same (plane,
/// direction, segment) phase-1 jobs as the barrier engine, submitted as
/// a dependency graph ([`ThreadPool::run_graph`]) so no global
/// rendezvous exists anywhere in the pass. Two continuation shapes:
///
/// * `per_dir = true` (production): **one drain continuation per
///   (plane, direction)** — 4 per plane on a merged pass — running the
///   fused-correction drain ([`drain_dir_pieces_fused`]). Direction k's
///   drain depends on its *own* phase-1 pieces plus the same plane's
///   direction-(k-1) drain (the chain preserves the k = 0..4 merge
///   accumulation order on the shared output plane), so it overlaps
///   both other planes' phase 1 and the same plane's later directions'
///   scans.
/// * `per_dir = false`: the PR 4 schedule — one continuation per plane
///   over all directions, running the two-pass correct-then-drain
///   ([`correct_and_drain_pieces`]). Kept as the bit/bench reference
///   for the fused drain.
///
/// Phase-1 pieces hand their panels to the continuations through
/// per-(plane, direction, segment) slots, and the per-direction drains
/// share their output plane through a per-plane slot; the graph's
/// dependency edges are what order the accesses, so the locks are
/// uncontended (and recovered if poisoned — a panicking job must
/// surface as the collected graph payload, not a `PoisonError`).
/// Arithmetic is untouched — output is exact `==` with the barrier
/// engine (and hence `scan_l2r_split`), pinned by tests.
#[allow(clippy::too_many_arguments)]
fn run_engine_segmented_wave(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: &ThreadPool,
    segments: usize,
    per_dir: bool,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
) -> Tensor {
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = out_shape[0] * c;
    let hmax = h.max(w);
    let bounds: Vec<Vec<(usize, usize)>> =
        dirs.iter().map(|di| segment_bounds(di.taps.w, segments)).collect();
    let per_plane_slots: usize = bounds.iter().map(|b| b.len()).sum();
    // Piece hand-off slots hold *leased* panels: whatever is still in a
    // slot when this vec drops (e.g. drains skipped after a phase-1
    // panic) returns to the workspace pool instead of leaking.
    let slots: Vec<Mutex<Option<Lease<'_>>>> =
        (0..nplanes * per_plane_slots).map(|_| Mutex::new(None)).collect();

    let mut out = out_tensor(out_shape, out_buf);
    let conts = if per_dir { dirs.len() } else { 1 };
    let mut graph = GraphBuilder::with_capacity(nplanes * (per_plane_slots + conts));
    let bounds_ref = &bounds;
    let slots_ref = &slots;
    // One phase-1 piece node per (plane, direction, segment), identical
    // under both continuation shapes (the schedules cannot drift apart
    // in what phase 1 computes).
    macro_rules! submit_pieces {
        ($ids:ident, $p:expr, $k:expr, $slot:ident) => {
            for &(lo, hi) in &bounds_ref[$k] {
                let dst = &slots_ref[$slot];
                $slot += 1;
                let (p, k) = ($p, $k);
                let hc = dirs[k].taps.h;
                $ids.push(graph.submit(move || {
                    // Lease before the (test-only) fault hook so an
                    // injected panic unwinds while scratch is out on
                    // lease — the leak test covers the window that
                    // matters. Zeroed like the fresh `vec!` it replaces.
                    let mut buf = ws.acquire_zeroed((hi - lo) * hc);
                    #[cfg(test)]
                    test_hooks::maybe_panic(p, k, lo, hi);
                    scan_piece_into(dirs, staged, c, (h, w), hmax, p, k, lo, hi, &mut buf, ws);
                    *lock_unpoisoned(dst) = Some(buf);
                }));
            }
        };
    }
    if per_dir {
        // Per-plane output + scratch hand-off slots: the per-direction
        // drain chain of a plane shares its output plane and one drain
        // scratch through a single slot, ordered by the drain-(k-1) →
        // drain-k graph edges (one scratch allocation per plane, as in
        // the barrier path).
        let os_slots: Vec<Mutex<(&mut [f32], DrainScratch<'_>)>> = out
            .data
            .chunks_mut(plane)
            .map(|os| Mutex::new((os, DrainScratch::new(hmax, ws))))
            .collect();
        for (p, os_slot) in os_slots.iter().enumerate() {
            let gv = gain.map(|g| g[p % c]);
            let mut prev_drain: Option<NodeId> = None;
            let mut slot = p * per_plane_slots;
            for (k, _) in dirs.iter().enumerate() {
                let mut deps = Vec::with_capacity(bounds[k].len() + 1);
                let dir_slot0 = slot;
                submit_pieces!(deps, p, k, slot);
                if let Some(prev) = prev_drain {
                    deps.push(prev);
                }
                let dir_slots = &slots_ref[dir_slot0..slot];
                prev_drain = Some(graph.submit_after(&deps, move || {
                    let mut guard = lock_unpoisoned(os_slot);
                    let (os, scratch) = &mut *guard;
                    drain_dir_pieces_fused(
                        dirs, staged, bounds_ref, wts, gv, p, k, c, (h, w), dir_slots,
                        os, scratch,
                    );
                }));
            }
        }
        if let Err(e) = pool.run_graph(graph) {
            std::panic::resume_unwind(e.into_payload());
        }
    } else {
        for (p, os) in out.data.chunks_mut(plane).enumerate() {
            let mut piece_ids = Vec::with_capacity(per_plane_slots);
            let mut slot = p * per_plane_slots;
            for (k, _) in dirs.iter().enumerate() {
                submit_pieces!(piece_ids, p, k, slot);
            }
            let plane_slots = &slots_ref[p * per_plane_slots..(p + 1) * per_plane_slots];
            let gv = gain.map(|g| g[p % c]);
            graph.submit_after(&piece_ids, move || {
                correct_and_drain_pieces(
                    dirs,
                    staged,
                    bounds_ref,
                    wts,
                    gv,
                    p,
                    c,
                    (h, w),
                    hmax,
                    plane_slots,
                    os,
                    ws,
                );
            });
        }
        if let Err(e) = pool.run_graph(graph) {
            std::panic::resume_unwind(e.into_payload());
        }
    }
    out
}

// ---------------------------------------------------------------------
// Single-pass chained engine (decoupled look-back)
// ---------------------------------------------------------------------

thread_local! {
    /// The chained-scan helping bound of the current thread: while a
    /// chunk job is on the stack, a wait loop inside it may only
    /// claim-and-run jobs with a *strictly lower* claim index. The
    /// nested-job stack is therefore strictly decreasing in claim
    /// index, so helping can never re-enter (or transitively depend
    /// on) the job that is waiting — the deadlock an unbounded
    /// work-steal here would hit. Fresh pool tickets start unbounded
    /// (`usize::MAX`).
    static CHAIN_BOUND: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Scoped setter for [`CHAIN_BOUND`]: restores the previous bound on
/// drop, including during unwinding (a panicking chunk must not leave
/// a stale bound on a pool worker's thread-local).
struct BoundGuard {
    prev: usize,
}

impl BoundGuard {
    fn set(j: usize) -> BoundGuard {
        BoundGuard { prev: CHAIN_BOUND.with(|b| b.replace(j)) }
    }
}

impl Drop for BoundGuard {
    fn drop(&mut self) {
        CHAIN_BOUND.with(|b| b.set(self.prev));
    }
}

/// Claim the lowest unclaimed job with index `< bound`. Lowest-first
/// matches the claim order's topology (see [`run_engine_chained`]), so
/// a fresh runner always picks a job whose predecessors are already
/// claimed or complete, and a blocked job only helps jobs it can never
/// transitively wait on.
fn chain_claim(claimed: &[AtomicBool], bound: usize) -> Option<usize> {
    let n = claimed.len().min(bound);
    (0..n).find(|&j| {
        !claimed[j].load(Ordering::Relaxed)
            && claimed[j]
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
    })
}

/// Whether a chunk reset (`gi % chunk == 0`) lands inside block columns
/// `[lo, hi)`. If so, any incoming carry dies before the block's last
/// column, its inclusive prefix equals its zero-carry aggregate no
/// matter what precedes it, and a look-back can terminate there.
fn chain_broken(lo: usize, hi: usize, chunk: usize) -> bool {
    lo.div_ceil(chunk) * chunk < hi
}

/// One (plane, direction, segment) chunk of the chained engine, plus
/// its publication-board block index.
struct ChainJob {
    p: usize,
    k: usize,
    si: usize,
    lo: usize,
    hi: usize,
    bidx: usize,
}

/// Shared state of one chained-engine call: the job table in claim
/// order, the claim flags, the publication board, the merge-order
/// drain counters, and the per-plane output slots.
struct ChainState<'e, 'w> {
    dirs: &'e [DirInput<'e>],
    staged: &'e [StagedTaps<'w>],
    wts: Option<&'e [f32; 4]>,
    gain: Option<&'e [f32]>,
    c: usize,
    hw: (usize, usize),
    hmax: usize,
    bounds: &'e [Vec<(usize, usize)>],
    jobs: Vec<ChainJob>,
    claimed: Vec<AtomicBool>,
    /// Completed-drain counters per `(plane, direction)` — the
    /// merge-order gate of merged passes: direction k's chunks scatter
    /// only after all `bounds[k-1].len()` chunks of the same plane
    /// drained, preserving the fixed k = 0..4 accumulation order.
    drained: Vec<AtomicUsize>,
    board: BlockBoard<'e>,
    os_slots: Vec<Mutex<&'e mut [f32]>>,
    /// Call-wide abort flag: set (with the block poisoned) by any
    /// panicking chunk so every spinning waiter unwinds instead of
    /// waiting on a publication that will never come.
    poisoned: AtomicBool,
    pool: Option<&'e ThreadPool>,
    ws: &'w BufferPool,
    /// Storage precision of the job-local panels (the staged taps carry
    /// their own): [`Precision::Bf16`] halves the retained bytes while
    /// the recurrence and the publication board stay f32.
    prec: Precision,
}

impl ChainState<'_, '_> {
    /// Wait until `pred` holds, productively: claim-and-run another
    /// chain job below the current helping bound, or assist the pool's
    /// global queue, before falling back to spin/yield. Panics
    /// (unwinding the waiting job) once any chunk of this call has
    /// poisoned the board.
    fn wait_until(&self, what: &str, pred: impl Fn(&Self) -> bool) {
        let mut spins = 0u32;
        while !pred(self) {
            if self.poisoned.load(Ordering::Acquire) {
                panic!("chained scan: waiting on {what}, but a chunk panicked");
            }
            let bound = CHAIN_BOUND.with(|b| b.get());
            if let Some(j) = chain_claim(&self.claimed, bound) {
                run_chain_job(self, j);
            } else if self.pool.map_or(false, |p| p.try_assist()) {
                spins = 0;
            } else {
                spins += 1;
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// One chained runner: claim the lowest unclaimed job under the
/// thread's current helping bound and run it, until nothing claimable
/// remains. Fresh pool tickets run unbounded; a runner ticket executed
/// from inside a blocked job's wait loop (via
/// [`ThreadPool::try_assist`]) inherits that job's bound and may exit
/// early — the caller's mop-up pass finishes the tail.
fn chain_runner(st: &ChainState<'_, '_>) {
    loop {
        let bound = CHAIN_BOUND.with(|b| b.get());
        match chain_claim(&st.claimed, bound) {
            Some(j) => run_chain_job(st, j),
            None => break,
        }
    }
}

/// Run one claimed chain job with the helping bound scoped to its claim
/// index, and panic containment: a panicking chunk poisons its board
/// block and the call-wide flag — so look-back waiters unwind through
/// the normal panic path instead of deadlocking on a publication that
/// will never arrive — then rethrows for the pool to collect as a
/// `MapError`.
fn run_chain_job(st: &ChainState<'_, '_>, j: usize) {
    let _bound = BoundGuard::set(j);
    if let Err(payload) =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| chain_job_body(st, j)))
    {
        st.board.poison(st.jobs[j].bidx);
        st.poisoned.store(true, Ordering::Release);
        std::panic::resume_unwind(payload);
    }
}

/// The single-pass chunk body: scan once from a zero carry into
/// job-local scratch, publish the aggregate, resolve the true incoming
/// carry by decoupled look-back, fold the correction into the still
/// cache-hot local panel, publish the inclusive prefix, and scatter the
/// corrected panel through the unchanged fused epilogue. No phase
/// barrier, no retained panel array, no second DRAM read of the panel.
fn chain_job_body(st: &ChainState<'_, '_>, j: usize) {
    let &ChainJob { p, k, si, lo, hi, bidx } = &st.jobs[j];
    let di = &st.dirs[k];
    let hc = di.taps.h;
    let chunk = di.chunk;
    let (h, w) = st.hw;
    let seglen = hi - lo;
    let taps = st.staged[k].panels(p / st.c, p % st.c);
    let bf16 = st.prec == Precision::Bf16;
    // Job-local panel — half-width (packed bf16 words in the f32 lease)
    // in reduced-precision mode, fully overwritten by the scan below.
    // Leased before the (test-only) fault hook so an injected panic
    // unwinds while scratch is out on lease — the leak test covers the
    // window that matters.
    let mut panel = if bf16 {
        st.ws.acquire(simd::bf16_len(seglen * hc))
    } else {
        st.ws.acquire(seglen * hc)
    };
    // The f32 aggregate column of a bf16 chunk: the recurrence runs in
    // f32 (only the *stored* panel narrows), so the board still carries
    // full-precision columns and the look-back fold loses nothing.
    let mut aggbuf = bf16.then(|| st.ws.acquire(st.hmax));
    #[cfg(test)]
    test_hooks::maybe_panic(p, k, lo, hi);
    match aggbuf.as_mut() {
        Some(agg) => {
            scan_piece_into_bf16(
                st.dirs,
                st.staged,
                st.c,
                (h, w),
                st.hmax,
                p,
                k,
                lo,
                hi,
                &mut panel.as_u16_mut()[..seglen * hc],
                &mut agg[..hc],
                st.ws,
            );
            // Publish the zero-carry aggregate (the chunk's last
            // column) immediately: successors' look-backs can fold over
            // it while this chunk is still resolving its own carry.
            st.board.publish_agg(bidx, &agg[..hc]);
        }
        None => {
            scan_piece_into(
                st.dirs, st.staged, st.c, (h, w), st.hmax, p, k, lo, hi, &mut panel, st.ws,
            );
            st.board.publish_agg(bidx, &panel[(seglen - 1) * hc..]);
        }
    }

    // Decoupled look-back: walk predecessor blocks back to the nearest
    // *final* value — a published inclusive PREFIX, block 0 (whose
    // aggregate is its prefix), or a chain-breaker — then fold forward
    // over the skipped blocks' aggregates with the exact
    // `correct_col` recurrence and zero-carry/chunk-reset skips of
    // the two-phase engine, so the resolved carry is bit-identical to
    // the sequentially chained one.
    let mut corr = st.ws.acquire_zeroed(st.hmax);
    let mut next = st.ws.acquire_zeroed(st.hmax);
    let mut carry = st.ws.acquire_zeroed(st.hmax);
    let mut active = false;
    if si > 0 {
        let sbounds = &st.bounds[k];
        let base = bidx - si; // board index of (p, k, si = 0)
        let mut t = si - 1;
        loop {
            let b = base + t;
            st.wait_until("a predecessor's published column", |s| {
                s.board.state(b) >= BLOCK_AGG
            });
            let state = st.board.state(b);
            assert!(state != BLOCK_POISONED, "chained scan: predecessor chunk panicked");
            if state == BLOCK_PREFIX {
                st.board.read_prefix(b, &mut carry[..hc]);
                break;
            }
            let (tlo, thi) = sbounds[t];
            if t == 0 || chain_broken(tlo, thi, chunk) {
                st.board.read_agg(b, &mut carry[..hc]);
                break;
            }
            t -= 1;
        }
        let mut agg = st.ws.acquire(st.hmax);
        for u in t + 1..si {
            let (ulo, uhi) = sbounds[u];
            let b = base + u;
            assert!(
                st.board.state(b) != BLOCK_POISONED,
                "chained scan: predecessor chunk panicked"
            );
            st.board.read_agg(b, &mut agg[..hc]);
            if carry[..hc].iter().all(|&v| v == 0.0) {
                // Zero incoming carry: block u needed no correction, so
                // its prefix is its aggregate (the reference
                // decomposition's skip — keeps even -0.0 pixels
                // bit-identical).
                carry[..hc].copy_from_slice(&agg[..hc]);
                continue;
            }
            // The carry is the full corrected value of column ulo - 1
            // (phase 1 scanned from zero there), so it seeds the linear
            // correction directly — the same association
            // [`correct_segment`] walks, minus the panel adds.
            corr[..hc].copy_from_slice(&carry[..hc]);
            let mut died = false;
            for gi in ulo..uhi {
                if gi % chunk == 0 {
                    died = true;
                    break;
                }
                simd::correct_col(&corr[..hc], taps.col(gi, hc), &mut next[..hc]);
                std::mem::swap(&mut corr, &mut next);
            }
            if died {
                carry[..hc].copy_from_slice(&agg[..hc]);
            } else {
                // prefix_u = agg_u + corr(last column): the identical
                // f32 add [`drain_dir_fused`] performs on the panel's
                // last column.
                for ((cv, &av), &co) in
                    carry[..hc].iter_mut().zip(&agg[..hc]).zip(&corr[..hc])
                {
                    *cv = av + co;
                }
            }
        }
        active = !carry[..hc].iter().all(|&v| v == 0.0);
    }

    // Fold the resolved carry into the job-local panel while it is
    // still cache-hot — exactly the two-pass correction arithmetic
    // (`phase1 + corr`, dying at chunk resets; bf16 panels decode, add
    // in f32, and re-encode per element).
    if active {
        match aggbuf.as_mut() {
            Some(_) => correct_segment_bf16(
                hc,
                chunk,
                lo,
                hi,
                taps,
                &carry,
                &mut corr,
                &mut next,
                &mut panel.as_u16_mut()[..seglen * hc],
            ),
            None => correct_segment(
                hc, chunk, lo, hi, taps, &carry, &mut corr, &mut next, &mut panel,
            ),
        }
    }

    // Publish the inclusive prefix (the corrected last column) BEFORE
    // the merge-order gate: successors' look-backs terminate here even
    // while this chunk is queued behind the previous direction's
    // drains.
    match aggbuf.as_mut() {
        Some(agg) => {
            if active {
                // Decode the corrected bf16 last column; an uncorrected
                // chunk republishes its exact f32 aggregate instead
                // (prefix == aggregate, bit for bit, as in f32 mode).
                let last = &panel.as_u16()[(seglen - 1) * hc..seglen * hc];
                for (o, &v) in agg[..hc].iter_mut().zip(last) {
                    *o = bf16_widen(v);
                }
            }
            st.board.publish_prefix(bidx, &agg[..hc]);
        }
        None => st.board.publish_prefix(bidx, &panel[(seglen - 1) * hc..]),
    }

    // Merged passes: direction k's contributions land on the shared
    // output plane only after every direction-(k-1) chunk of the same
    // plane has drained — the fixed k = 0..4 merge order the serial
    // reference accumulates in.
    let ndirs = st.dirs.len();
    if k > 0 {
        let want = st.bounds[k - 1].len();
        let gate = p * ndirs + (k - 1);
        st.wait_until("the previous direction's drains", |s| {
            s.drained[gate].load(Ordering::Acquire) >= want
        });
    }

    // Pure scatter of the already-corrected panel through the shared
    // epilogue op — no correction work happens under the plane lock.
    // bf16 panels decode slab-by-slab into an f32 staging slab (leased
    // before the lock) so the scatter arms stay f32-only.
    {
        let mut dec = bf16.then(|| st.ws.acquire(SLAB * hc.max(1)));
        let gain = st.gain.map(|g| g[p % st.c]);
        let mut guard = lock_unpoisoned(&st.os_slots[p]);
        let os: &mut [f32] = &mut guard;
        let mut j0 = 0;
        while j0 < seglen {
            let sw = SLAB.min(seglen - j0);
            let hs: &[f32] = match dec.as_mut() {
                Some(dec) => {
                    let words = &panel.as_u16()[j0 * hc..(j0 + sw) * hc];
                    for (o, &v) in dec[..sw * hc].iter_mut().zip(words) {
                        *o = bf16_widen(v);
                    }
                    &dec[..sw * hc]
                }
                None => &panel[j0 * hc..(j0 + sw) * hc],
            };
            drain_scatter(hs, h, w, di.d, lo + j0, sw, hc, os, st.wts, k, ndirs - 1, gain);
            j0 += sw;
        }
    }
    st.drained[p * ndirs + k].fetch_add(1, Ordering::Release);
}

/// The single-pass chained engine ([`ScanStrategy::Chained`]): the same
/// (plane, direction, segment) decomposition as the segmented engine,
/// but each chunk is ONE self-contained job — scan from a zero carry,
/// publish the aggregate, resolve the true carry by decoupled look-back
/// over a publication board ([`BlockBoard`]), correct in place while
/// the panel is L2-hot, publish the inclusive prefix, drain through the
/// unchanged fused epilogue. What the two-phase engines pay and this
/// one does not: the global phase rendezvous (barrier) or dependency-
/// graph machinery (wavefront), the retained-panel array and its extra
/// DRAM round trip, and the per-piece lease hand-offs.
///
/// Bit-exactness: chunk bounds come from the same [`segment_bounds`],
/// phase-1 arithmetic is the shared [`scan_piece_into`], and the
/// look-back fold replays the exact `correct_col` recurrence order
/// with the reference's zero-carry and chunk-reset skips — so the
/// resolved carry, the corrected panel, and hence every output bit
/// match `scan_l2r_split` and the segmented engine exactly (validated
/// bitwise against a two-phase mirror over ~9.4k randomized
/// geometry/chunk/zero-carry cases before porting, and pinned `==` by
/// the chained property suite).
///
/// Scheduling: jobs are claimed lowest-index-first from a direction-
/// major (k, p, si) order — a valid topological order of the chain's
/// dependencies, since block (p, k, si) waits only on (p, k, < si)
/// (look-back) and (p, k-1, *) (merge-order gate). A blocked chunk
/// helps by claiming jobs strictly below its own index
/// ([`CHAIN_BOUND`]), assists the pool's global queue, or spins;
/// deadlock-freedom follows by induction on the lowest incomplete
/// index. On a serial pool the claim order degrades to the plain
/// sequential two-phase order, every wait instantly satisfied.
#[allow(clippy::too_many_arguments)]
fn run_engine_chained(
    dirs: &[DirInput<'_>],
    staged: &[StagedTaps<'_>],
    wts: Option<&[f32; 4]>,
    gain: Option<&[f32]>,
    out_shape: &[usize],
    pool: Option<&ThreadPool>,
    segments: usize,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
    prec: Precision,
) -> Tensor {
    let c = out_shape[1];
    let (h, w) = (out_shape[2], out_shape[3]);
    let plane = h * w;
    let nplanes = out_shape[0] * c;
    let hmax = h.max(w);
    let bounds: Vec<Vec<(usize, usize)>> =
        dirs.iter().map(|di| segment_bounds(di.taps.w, segments)).collect();
    let seg_off: Vec<usize> = bounds
        .iter()
        .scan(0usize, |acc, b| {
            let o = *acc;
            *acc += b.len();
            Some(o)
        })
        .collect();
    let per_plane: usize = bounds.iter().map(|b| b.len()).sum();
    let total_blocks = nplanes * per_plane;
    // Publication board payload: one pooled lease holding an
    // [aggregate | prefix] column pair per block. Every slot range is
    // fully written before its state permits a read, so the lease is
    // not zero-reset.
    let mut board_payload = ws.acquire(2 * hmax * total_blocks);
    let board = BlockBoard::new(&mut board_payload, total_blocks, hmax);
    // Claim order (k, p, si), direction-major: dependencies of every
    // job sit at strictly lower indices, and ordering directions
    // outermost keeps every plane's direction-k chain moving instead of
    // camping all workers on one plane's serial look-back chain.
    let mut jobs = Vec::with_capacity(total_blocks);
    for (k, b) in bounds.iter().enumerate() {
        for p in 0..nplanes {
            for (si, &(lo, hi)) in b.iter().enumerate() {
                jobs.push(ChainJob { p, k, si, lo, hi, bidx: p * per_plane + seg_off[k] + si });
            }
        }
    }
    let njobs = jobs.len();
    let mut out = out_tensor(out_shape, out_buf);
    let st = ChainState {
        dirs,
        staged,
        wts,
        gain,
        c,
        hw: (h, w),
        hmax,
        bounds: &bounds,
        jobs,
        claimed: (0..njobs).map(|_| AtomicBool::new(false)).collect(),
        drained: (0..nplanes * dirs.len()).map(|_| AtomicUsize::new(0)).collect(),
        board,
        os_slots: out.data.chunks_mut(plane).map(Mutex::new).collect(),
        poisoned: AtomicBool::new(false),
        pool: pool.filter(|p| p.threads() > 1 && njobs > 1),
        ws,
        prec,
    };
    match st.pool {
        Some(pool) => {
            // min(threads, jobs) self-scheduling runner tickets; the
            // caller participates through `try_map`'s own-call helping.
            let runners: Vec<usize> = (0..pool.threads().min(njobs)).collect();
            if let Err(e) = pool.try_map(runners, |_| chain_runner(&st)) {
                std::panic::resume_unwind(e.into_payload());
            }
            // A runner ticket drained from inside a blocked job's wait
            // loop inherits that job's helping bound and may have
            // exited early; one unbounded mop-up pass completes any
            // unclaimed tail.
            chain_runner(&st);
        }
        // Serial path: claim in order on the caller thread — every
        // wait's predecessor has already completed, so the chain
        // degrades to the plain sequential two-phase order, bit for
        // bit and with a deterministic lease sequence.
        None => chain_runner(&st),
    }
    drop(st);
    out
}

/// Test-only fault injection for the wavefront phase-1 pieces and the
/// chained chunk jobs: lets the panic-propagation suites force exactly
/// one (plane, dir, lo, hi) piece to panic and assert the payload
/// surfaces as the collected graph/map error (not a `PoisonError`, a
/// secondary index panic, or a hung look-back waiter).
#[cfg(test)]
pub(crate) mod test_hooks {
    use std::sync::Mutex;

    pub(crate) static PANIC_PIECE: Mutex<Option<(usize, usize, usize, usize)>> =
        Mutex::new(None);

    pub(crate) fn maybe_panic(p: usize, k: usize, lo: usize, hi: usize) {
        let hit = crate::util::lock_unpoisoned(&PANIC_PIECE)
            .map_or(false, |t| t == (p, k, lo, hi));
        if hit {
            panic!("injected phase-1 panic at ({p},{k},{lo},{hi})");
        }
    }
}

// ---------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------

/// Fused directional scan (serial): bit-identical to
/// `scan_dir(x, taps, lam, d, kchunk)` with zero canonical copies.
pub fn fused_scan_dir(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, d, kchunk, None, BufferPool::global(), None)
}

/// [`fused_scan_dir`] with block-granular plane jobs on `pool`.
pub fn fused_scan_dir_pool(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, d, kchunk, Some(pool), BufferPool::global(), None)
}

/// [`fused_scan_dir_pool`] drawing all per-call scratch from an explicit
/// workspace pool instead of the process-global one — the serving entry:
/// the coordinator owns one pool so its hit/miss counters are isolated
/// and pre-warmable per bucket.
pub fn fused_scan_dir_pool_ws(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    pool: &ThreadPool,
    ws: &BufferPool,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, d, kchunk, Some(pool), ws, None)
}

fn fused_scan_dir_inner(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    pool: Option<&ThreadPool>,
    ws: &BufferPool,
    out_buf: Option<Vec<f32>>,
) -> Tensor {
    validate_dir(x, taps, lam, d);
    if x.data.is_empty() {
        return out_tensor(&x.shape, out_buf);
    }
    let chunk = effective_chunk(taps.w, kchunk);
    let dirs = [DirInput { d, taps, x, lam, layout: Orientation::Spatial, chunk }];
    run_engine(&dirs, None, None, &x.shape, pool, ExecSpec::Auto, ws, out_buf, None)
}

/// [`fused_scan_dir_pool`] under an explicit, caller-forced strategy +
/// phase-2 schedule. The pooled entry points normally consult the
/// planner ([`plan::plan_scan`]); this hook exists for tests, benches,
/// and plan-carrying callers that already decided.
#[allow(clippy::too_many_arguments)]
fn fused_scan_dir_forced(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_forced_ws(
        x,
        taps,
        lam,
        d,
        kchunk,
        strategy,
        phase2,
        pool,
        BufferPool::global(),
        None,
    )
}

/// [`fused_scan_dir_forced`] over an explicit workspace — the hook the
/// pooled-vs-fresh bit-exactness and zero-miss tests drive per strategy.
/// `prec` overrides the panel/tap storage precision *for this call
/// only* (tests must never flip the process-global precision override:
/// concurrently running `==` suites would observe it).
#[allow(clippy::too_many_arguments)]
fn fused_scan_dir_forced_ws(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
    ws: &BufferPool,
    prec: Option<Precision>,
) -> Tensor {
    validate_dir(x, taps, lam, d);
    if x.data.is_empty() {
        return Tensor::zeros(&x.shape);
    }
    let chunk = effective_chunk(taps.w, kchunk);
    let dirs = [DirInput { d, taps, x, lam, layout: Orientation::Spatial, chunk }];
    run_engine(
        &dirs,
        None,
        None,
        &x.shape,
        Some(pool),
        ExecSpec::Forced(strategy, phase2),
        ws,
        None,
        prec,
    )
}

/// [`fused_scan_dir_pool`] with a *forced* segment-parallel
/// decomposition: each plane's canonical columns are scanned as
/// `segments` zero-carry segments and carry-corrected — bit-identical
/// (exact `==`, pinned by tests) to running
/// [`super::split::scan_l2r_split`] on the canonically reoriented
/// tensors with the same count. Runs the barrier schedule; see
/// [`fused_scan_dir_seg_wave`] for the wavefront twin.
pub fn fused_scan_dir_seg(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_scan_dir_seg`] under per-direction wavefront scheduling:
/// each (plane, direction)'s fused correction + epilogue drain runs as
/// its own continuation of that direction's phase-1 segment jobs
/// instead of behind a global barrier. Scheduling only — exact `==`
/// with [`fused_scan_dir_seg`] (and the `scan_l2r_split` reference) at
/// the same count, pinned by tests.
pub fn fused_scan_dir_seg_wave(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::WaveDir, pool)
}

/// [`fused_scan_dir_seg_wave`] under the retired PR 4 schedule: one
/// continuation per plane running the *two-pass* correct-then-drain
/// (the retained panel is corrected in place, then re-read by the
/// drain). Exact `==` with both other schedules — kept as the bit and
/// bench reference the fused-correction drain is measured against.
pub fn fused_scan_dir_seg_wave_twopass(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::WavePlane, pool)
}

/// [`fused_scan_dir_seg`] executed by the single-pass chained engine
/// ([`ScanStrategy::Chained`], [`run_engine_chained`]): one decoupled
/// look-back job per (plane, direction, segment), no phase barrier, no
/// retained panels. Exact `==` with [`fused_scan_dir_seg`] (and hence
/// `scan_l2r_split`) at the same count, pinned by tests.
pub fn fused_scan_dir_chained(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    d: Direction,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Chained { s: segments };
    // The chained engine has no phase 2; the schedule arg is inert.
    fused_scan_dir_forced(x, taps, lam, d, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_scan_dir_chained`] for the canonical left-to-right scan.
pub fn fused_scan_l2r_chained(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_chained(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// [`fused_scan_dir_seg`] for the canonical left-to-right scan: the
/// segmented twin of [`fused_scan_l2r_pool`], exact `==` with
/// [`super::split::scan_l2r_split`] at the same count.
pub fn fused_scan_l2r_seg(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_seg(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// [`fused_scan_l2r_seg`] under wavefront scheduling (see
/// [`fused_scan_dir_seg_wave`]).
pub fn fused_scan_l2r_seg_wave(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_seg_wave(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// [`fused_scan_l2r_seg_wave`] under the PR 4 two-pass schedule (see
/// [`fused_scan_dir_seg_wave_twopass`]).
pub fn fused_scan_l2r_seg_wave_twopass(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_seg_wave_twopass(x, taps, lam, Direction::L2R, kchunk, segments, pool)
}

/// Fused canonical scan (serial): bit-identical to `scan_l2r`.
pub fn fused_scan_l2r(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> Tensor {
    fused_scan_dir(x, taps, lam, Direction::L2R, kchunk)
}

/// [`fused_scan_l2r`] with block-granular plane jobs on `pool`.
pub fn fused_scan_l2r_pool(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    fused_scan_dir_pool(x, taps, lam, Direction::L2R, kchunk, pool)
}

/// [`fused_scan_l2r_pool`] over an explicit workspace pool (see
/// [`fused_scan_dir_pool_ws`]) — what the coordinator's CPU batch path
/// calls so steady-state serving of a warm bucket allocates nothing in
/// the scan hot path.
pub fn fused_scan_l2r_pool_ws(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
    ws: &BufferPool,
) -> Tensor {
    fused_scan_dir_pool_ws(x, taps, lam, Direction::L2R, kchunk, pool, ws)
}

/// [`fused_scan_l2r_pool_ws`] writing its output into a caller-recycled
/// buffer — zeroed, exactly `x` elements long, typically
/// [`BufferPool::take_zeroed`] from the same workspace. This is the
/// coordinator's reply-recycling hook: with the output buffer taken
/// from (and, via the client's `ReplyLease` drop, donated back to) the
/// request workspace, a warm bucket's hot path performs no heap
/// allocation at all, reply tensor included. Bit-identical to the plain
/// entry — the buffer only replaces the fresh `Tensor::zeros`.
pub fn fused_scan_l2r_pool_ws_into(
    x: &Tensor,
    taps: &Taps,
    lam: &Tensor,
    kchunk: usize,
    pool: &ThreadPool,
    ws: &BufferPool,
    out_buf: Vec<f32>,
) -> Tensor {
    fused_scan_dir_inner(x, taps, lam, Direction::L2R, kchunk, Some(pool), ws, Some(out_buf))
}

/// [`fused_scan_l2r`] over the process-wide shared pool.
pub fn fused_scan_l2r_par(x: &Tensor, taps: &Taps, lam: &Tensor, kchunk: usize) -> Tensor {
    fused_scan_l2r_pool(x, taps, lam, kchunk, ThreadPool::global())
}

fn merged_dirs<'a>(
    x: &'a Tensor,
    taps: [&'a Taps; 4],
    lam: &'a Tensor,
    kchunk: usize,
) -> Vec<DirInput<'a>> {
    DIRECTIONS
        .iter()
        .enumerate()
        .map(|(k, &d)| {
            validate_dir(x, taps[k], lam, d);
            DirInput {
                d,
                taps: taps[k],
                x,
                lam,
                layout: Orientation::Spatial,
                chunk: effective_chunk(taps[k].w, kchunk),
            }
        })
        .collect()
}

/// Fused four-direction merge (serial): bit-identical to the reference
/// [`super::direction::merged_4dir_ref`], with the pack, all four scans,
/// and the weighted merge in one engine pass.
pub fn fused_merged_4dir(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    let dirs = merged_dirs(x, taps, lam, kchunk);
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        None,
        &x.shape,
        None,
        ExecSpec::Auto,
        BufferPool::global(),
        None,
        None,
    )
}

/// [`fused_merged_4dir`] with block-granular plane jobs on `pool`.
pub fn fused_merged_4dir_pool(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    pool: &ThreadPool,
) -> Tensor {
    let dirs = merged_dirs(x, taps, lam, kchunk);
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        None,
        &x.shape,
        Some(pool),
        ExecSpec::Auto,
        BufferPool::global(),
        None,
        None,
    )
}

/// [`fused_merged_4dir_pool`] under an explicit strategy + phase-2
/// schedule (the forced hook behind the seg / fan variants below).
#[allow(clippy::too_many_arguments)]
fn fused_merged_4dir_forced(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
) -> Tensor {
    fused_merged_4dir_forced_ws(
        x,
        taps,
        lam,
        merge_logits,
        kchunk,
        strategy,
        phase2,
        pool,
        BufferPool::global(),
        None,
    )
}

/// [`fused_merged_4dir_forced`] over an explicit workspace — the merged
/// twin of [`fused_scan_dir_forced_ws`] for the pooled-vs-fresh tests,
/// with the same per-call `prec` override.
#[allow(clippy::too_many_arguments)]
fn fused_merged_4dir_forced_ws(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    strategy: ScanStrategy,
    phase2: Phase2,
    pool: &ThreadPool,
    ws: &BufferPool,
    prec: Option<Precision>,
) -> Tensor {
    let dirs = merged_dirs(x, taps, lam, kchunk);
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        None,
        &x.shape,
        Some(pool),
        ExecSpec::Forced(strategy, phase2),
        ws,
        None,
        prec,
    )
}

/// [`fused_merged_4dir_pool`] with a *forced* segment count per
/// direction (clamped to each direction's canonical width) — the
/// segmented twin of the merged pass for tests and benches. Segment
/// arithmetic follows the `scan_l2r_split` decomposition per direction;
/// merge order and the epilogue fusion are unchanged. Barrier schedule;
/// [`fused_merged_4dir_seg_wave`] is the wavefront twin.
pub fn fused_merged_4dir_seg(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_merged_4dir_seg`] under per-direction wavefront scheduling:
/// 4 drain continuations per plane, each depending on its own
/// direction's phase-1 jobs plus the previous direction's drain (the
/// chain preserves the k = 0..4 merge order), with the correction fused
/// into the merge drain. Exact `==` with the barrier twin, pinned by
/// tests.
pub fn fused_merged_4dir_seg_wave(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::WaveDir, pool)
}

/// [`fused_merged_4dir_seg_wave`] under the retired PR 4 schedule: one
/// two-pass correct-then-drain continuation per plane (see
/// [`fused_scan_dir_seg_wave_twopass`]). Exact `==` with both other
/// schedules; the bench comparison row for the fused-correction drain.
pub fn fused_merged_4dir_seg_wave_twopass(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Segmented { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::WavePlane, pool)
}

/// [`fused_merged_4dir_seg`] executed by the single-pass chained engine
/// (see [`fused_scan_dir_chained`]): per-direction chunk chains with
/// decoupled look-back, the k = 0..4 merge order preserved by the
/// per-plane drain gates. Exact `==` with the barrier twin, pinned by
/// tests.
pub fn fused_merged_4dir_chained(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    segments: usize,
    pool: &ThreadPool,
) -> Tensor {
    let strategy = ScanStrategy::Chained { s: segments };
    fused_merged_4dir_forced(x, taps, lam, merge_logits, kchunk, strategy, Phase2::Barrier, pool)
}

/// [`fused_merged_4dir_pool`] with the *forced* per-direction phase-1
/// fan-out ([`ScanStrategy::DirFan`]): one zero-carry full-width scan
/// job per (plane, direction), drained through the fixed-k-order merge
/// epilogue per plane — bit-identical (exact `==`, pinned by tests) to
/// [`fused_merged_4dir`] and the serial reference, ×4 the parallel
/// width. `wavefront` runs each (plane, direction)'s drain as its own
/// continuation of that direction's scan, chained to keep the merge
/// order — direction k's drain overlaps direction k+1's scan; `false`
/// uses the two-phase barrier schedule.
pub fn fused_merged_4dir_fan(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
    wavefront: bool,
    pool: &ThreadPool,
) -> Tensor {
    let phase2 = if wavefront { Phase2::WaveDir } else { Phase2::Barrier };
    fused_merged_4dir_forced(
        x,
        taps,
        lam,
        merge_logits,
        kchunk,
        ScanStrategy::DirFan,
        phase2,
        pool,
    )
}

/// [`fused_merged_4dir`] over the process-wide shared pool.
pub fn fused_merged_4dir_par(
    x: &Tensor,
    taps: [&Taps; 4],
    lam: &Tensor,
    merge_logits: &[f32; 4],
    kchunk: usize,
) -> Tensor {
    fused_merged_4dir_pool(x, taps, lam, merge_logits, kchunk, ThreadPool::global())
}

/// The compact unit's scan stage, fused end to end: per-direction
/// activations `xcs[k]` / `lamcs[k]` are already in canonical layout
/// (they come out of the unit's 1x1 projections), taps are canonical as
/// always, and the epilogue folds the merge *and* the `u ⊙ h` output
/// modulation into the scatter — the unit never materializes a
/// directional output, the merged tensor, or the modulation clone.
/// Output is the spatial (N, Cp, H, W) modulated merge, bit-identical to
/// the reference composition in `CompactGspnUnit::forward_ref` whenever
/// the planner ([`plan::plan_scan`]) picks a bit-exact strategy —
/// `PlanePar` or, in the mid-occupancy regime, `DirFan` (the
/// per-direction fan reassociates nothing). Only a low-occupancy
/// forward wide enough to segment (canonical widths ≥ 2 ·
/// [`plan::MIN_SEG_COLS`] = 128) follows the `scan_l2r_split`
/// segmented arithmetic instead.
#[allow(clippy::too_many_arguments)]
pub fn fused_merged_canonical(
    xcs: [&Tensor; 4],
    taps: [&Taps; 4],
    lamcs: [&Tensor; 4],
    merge_logits: &[f32; 4],
    u: &[f32],
    kchunk: usize,
    out_shape: &[usize],
    pool: &ThreadPool,
) -> Tensor {
    fused_merged_canonical_ws(
        xcs,
        taps,
        lamcs,
        merge_logits,
        u,
        kchunk,
        out_shape,
        pool,
        BufferPool::global(),
    )
}

/// [`fused_merged_canonical`] over an explicit workspace pool — what
/// [`CompactGspnUnit::forward_ws`](super::compact::CompactGspnUnit::forward_ws)
/// threads through so a serving coordinator's unit forwards draw from
/// its pre-warmed per-bucket pool.
#[allow(clippy::too_many_arguments)]
pub fn fused_merged_canonical_ws(
    xcs: [&Tensor; 4],
    taps: [&Taps; 4],
    lamcs: [&Tensor; 4],
    merge_logits: &[f32; 4],
    u: &[f32],
    kchunk: usize,
    out_shape: &[usize],
    pool: &ThreadPool,
    ws: &BufferPool,
) -> Tensor {
    let dirs: Vec<DirInput<'_>> = DIRECTIONS
        .iter()
        .enumerate()
        .map(|(k, &d)| {
            let (xc, lamc) = (xcs[k], lamcs[k]);
            assert_eq!(xc.rank(), 4, "xc must be (N, C, Hc, Wc)");
            assert_eq!(xc.shape, lamc.shape, "lamc shape must match xc");
            assert_eq!(
                (taps[k].n, taps[k].h, taps[k].w),
                (xc.shape[0], xc.shape[2], xc.shape[3]),
                "taps geometry mismatch"
            );
            assert!(
                taps[k].cw == 1 || taps[k].cw == xc.shape[1],
                "Cw must be 1 or C"
            );
            DirInput {
                d,
                taps: taps[k],
                x: xc,
                lam: lamc,
                layout: Orientation::Canonical,
                chunk: effective_chunk(taps[k].w, kchunk),
            }
        })
        .collect();
    assert_eq!(u.len(), out_shape[1], "gain length must be C");
    let wts = merge_weights(merge_logits);
    run_engine(
        &dirs,
        Some(&wts),
        Some(u),
        out_shape,
        Some(pool),
        ExecSpec::Auto,
        ws,
        None,
        None,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::core::{scan_l2r, scan_l2r_pool};
    use crate::scan::direction::{merged_4dir_ref, scan_dir};
    use crate::util::proptest::{check, ensure};
    use crate::util::Rng;

    fn divisors(w: usize) -> Vec<usize> {
        (1..=w).filter(|d| w % d == 0).collect()
    }

    fn mk_taps(rng: &mut Rng, n: usize, cw: usize, h: usize, w: usize) -> Taps {
        Taps::normalize(&Tensor::randn(&[n, cw, 3, h, w], rng, 1.0))
    }

    /// The tentpole pinning property: the fused engine is exactly equal
    /// (`==` on `data`, not allclose) to the serial reference across
    /// random shapes, every kchunk divisor, shared and per-channel taps,
    /// and all four directions — including H=1 and W=1 edge geometries.
    #[test]
    fn fused_scan_pinned_bit_exact_to_reference() {
        check("fused == scan_dir reference", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 3);
            let h = g.int_in(1, 7);
            let w = g.int_in(1, 7);
            let cw = *g.pick(&[1, c]);
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            for d in DIRECTIONS {
                let (hc, wc) = hw_src(h, w, d);
                let taps = mk_taps(&mut rng, n, cw, hc, wc);
                let mut kchunks = vec![0usize];
                kchunks.extend(divisors(wc));
                for k in kchunks {
                    let reference = scan_dir(&x, &taps, &lam, d, k);
                    let fused = fused_scan_dir(&x, &taps, &lam, d, k);
                    ensure(
                        reference.shape == fused.shape && reference.data == fused.data,
                        format!("fused != ref: n{n} c{c} {h}x{w} cw{cw} {d:?} k{k}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    /// Slab-boundary coverage: widths around multiples of SLAB, so the
    /// carry column crossing and the partial last slab are both hit,
    /// including kchunk resets landing inside and on slab boundaries.
    #[test]
    fn fused_scan_exact_across_slab_boundaries() {
        let mut rng = Rng::new(39);
        for w in [SLAB - 1, SLAB, SLAB + 1, 2 * SLAB, 2 * SLAB + 3] {
            let (n, c, h) = (1, 2, 5);
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = mk_taps(&mut rng, n, 1, h, w);
            let mut kchunks = vec![0usize];
            kchunks.extend(divisors(w));
            for k in kchunks {
                let reference = scan_l2r(&x, &taps, &lam, k);
                let fused = fused_scan_l2r(&x, &taps, &lam, k);
                assert_eq!(reference.data, fused.data, "w={w} k={k}");
            }
        }
    }

    #[test]
    fn fused_merged_pinned_bit_exact_to_reference() {
        check("fused merged == merged_4dir_ref", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 3);
            let h = g.int_in(1, 6);
            let w = g.int_in(1, 6);
            let cw = *g.pick(&[1, c]);
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let t_lr = mk_taps(&mut rng, n, cw, h, w);
            let t_rl = mk_taps(&mut rng, n, cw, h, w);
            let t_tb = mk_taps(&mut rng, n, cw, w, h);
            let t_bt = mk_taps(&mut rng, n, cw, w, h);
            let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
            let logits = [
                g.f32_in(-2.0, 2.0),
                g.f32_in(-2.0, 2.0),
                g.f32_in(-2.0, 2.0),
                g.f32_in(-2.0, 2.0),
            ];
            // kchunk must divide the canonical width of every direction.
            let mut kchunks = vec![0usize];
            kchunks.extend(divisors(w).into_iter().filter(|k| h % k == 0));
            for k in kchunks {
                let reference = merged_4dir_ref(&x, taps, &lam, &logits, k);
                let fused = fused_merged_4dir(&x, taps, &lam, &logits, k);
                ensure(
                    reference.data == fused.data,
                    format!("fused merged != ref: n{n} c{c} {h}x{w} cw{cw} k{k}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn fused_pool_bit_identical_to_fused_serial_and_reference() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(40);
        for (n, c, h, w, cw) in
            [(2, 3, 8, 12, 3), (1, 1, 5, 7, 1), (3, 4, 16, 16, 1), (1, 2, 1, 6, 1), (1, 2, 6, 1, 2)]
        {
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = mk_taps(&mut rng, n, cw, h, w);
            for kchunk in [0, w] {
                let reference = scan_l2r(&x, &taps, &lam, kchunk);
                let serial = fused_scan_l2r(&x, &taps, &lam, kchunk);
                let pooled = fused_scan_l2r_pool(&x, &taps, &lam, kchunk, &pool);
                assert_eq!(reference.data, serial.data, "serial n{n} c{c} {h}x{w} k{kchunk}");
                assert_eq!(reference.data, pooled.data, "pooled n{n} c{c} {h}x{w} k{kchunk}");
            }
        }
    }

    #[test]
    fn fused_merged_pool_bit_identical_to_reference() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(41);
        let (n, c, h, w) = (2, 3, 6, 7);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let taps = [&t_lr, &t_lr, &t_tb, &t_tb];
        let logits = [0.4f32, -0.2, 1.1, 0.0];
        let reference = merged_4dir_ref(&x, taps, &lam, &logits, 0);
        let pooled = fused_merged_4dir_pool(&x, taps, &lam, &logits, 0, &pool);
        let global = fused_merged_4dir_par(&x, taps, &lam, &logits, 0);
        assert_eq!(reference.data, pooled.data);
        assert_eq!(reference.data, global.data);
    }

    #[test]
    fn fused_canonical_merge_modulate_matches_reference_composition() {
        // The compact-unit path: canonical per-direction activations,
        // fused merge + u ⊙ h modulation vs the explicit reference
        // composition (scan_l2r_pool + from_canonical + merge pass +
        // output_modulation).
        use crate::scan::direction::{from_canonical, to_canonical};
        let pool = crate::util::ThreadPool::new(2);
        let mut rng = Rng::new(42);
        let (n, c, h, w) = (2, 3, 5, 6);
        let xp = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let logits = [0.3f32, -0.7, 0.2, 1.0];
        let u: Vec<f32> = (0..c).map(|i| 0.5 + i as f32).collect();
        let mut xcs = Vec::new();
        let mut taps = Vec::new();
        let mut lamcs = Vec::new();
        for d in DIRECTIONS {
            let xc = to_canonical(&xp, d);
            let (hc, wc) = (xc.shape[2], xc.shape[3]);
            taps.push(mk_taps(&mut rng, n, 1, hc, wc));
            lamcs.push(Tensor::randn(&xc.shape, &mut rng, 1.0));
            xcs.push(xc);
        }
        let fused = fused_merged_canonical(
            [&xcs[0], &xcs[1], &xcs[2], &xcs[3]],
            [&taps[0], &taps[1], &taps[2], &taps[3]],
            [&lamcs[0], &lamcs[1], &lamcs[2], &lamcs[3]],
            &logits,
            &u,
            0,
            &xp.shape,
            &pool,
        );
        let wts = merge_weights(&logits);
        let mut merged = Tensor::zeros(&xp.shape);
        for (k, d) in DIRECTIONS.iter().enumerate() {
            let hcan = scan_l2r_pool(&xcs[k], &taps[k], &lamcs[k], 0, &pool);
            let y = from_canonical(&hcan, *d);
            for (o, v) in merged.data.iter_mut().zip(&y.data) {
                *o += wts[k] * v;
            }
        }
        let reference = crate::scan::core::output_modulation_owned(merged, &u);
        assert_eq!(reference.data, fused.data);
    }

    #[test]
    fn fused_empty_and_degenerate_geometries() {
        // N·C = 0 and H = 0 return zeros without panicking, as the
        // reference does.
        let x = Tensor::zeros(&[0, 3, 4, 5]);
        let lam = Tensor::zeros(&[0, 3, 4, 5]);
        let taps = Taps::normalize(&Tensor::zeros(&[0, 1, 3, 4, 5]));
        let out = fused_scan_l2r(&x, &taps, &lam, 0);
        assert_eq!(out.shape, vec![0, 3, 4, 5]);

        let x = Tensor::zeros(&[1, 2, 0, 5]);
        let lam = Tensor::zeros(&[1, 2, 0, 5]);
        let taps = Taps::normalize(&Tensor::zeros(&[1, 1, 3, 0, 5]));
        let out = fused_scan_l2r(&x, &taps, &lam, 0);
        assert!(out.data.is_empty());
    }

    #[test]
    fn block_count_scales_with_pool_not_planes() {
        assert_eq!(plane_blocks(1000, 4), 8);
        assert_eq!(plane_blocks(3, 4), 3);
        assert_eq!(plane_blocks(0, 4), 0);
        assert_eq!(plane_blocks(16, 1), 2);
    }

    // -----------------------------------------------------------------
    // Segment-parallel decomposition
    // -----------------------------------------------------------------

    use crate::scan::split::scan_l2r_split;

    /// The tentpole pinning property for the segmented path: exact `==`
    /// with the reference decomposition `scan_l2r_split` across segment
    /// counts and boundaries — including W = 1, more segments than
    /// columns, and a 1-thread pool (helping-wait execution).
    #[test]
    fn segmented_fused_exact_eq_scan_l2r_split() {
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(50);
        for (n, c, h, w, cw) in [
            (1, 1, 5, 12, 1),
            (1, 2, 3, 64, 2),
            (2, 3, 8, 40, 1),
            (1, 1, 1, 7, 1),
            (1, 2, 9, 1, 1),
            (1, 1, 4, 2 * SLAB + 3, 1),
        ] {
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = mk_taps(&mut rng, n, cw, h, w);
            for segments in [1usize, 2, 3, 5, 8, w, w + 9, 500] {
                let reference = scan_l2r_split(&x, &taps, &lam, segments, 1);
                let seg1 = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool1);
                let seg3 = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool3);
                assert_eq!(
                    reference.data, seg1.data,
                    "1-thread n{n} c{c} {h}x{w} cw{cw} S{segments}"
                );
                assert_eq!(
                    reference.data, seg3.data,
                    "3-thread n{n} c{c} {h}x{w} cw{cw} S{segments}"
                );
            }
        }
    }

    #[test]
    fn segmented_fused_split_identity_property() {
        let pool = crate::util::ThreadPool::new(2);
        check("fused segmented == scan_l2r_split", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 3);
            let h = g.int_in(1, 9);
            let w = g.int_in(1, 40);
            let segments = g.int_in(1, 7);
            let cw = *g.pick(&[1, c]);
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = mk_taps(&mut rng, n, cw, h, w);
            let reference = scan_l2r_split(&x, &taps, &lam, segments, 1);
            let seg = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool);
            ensure(
                reference.data == seg.data,
                format!("segmented != split: n{n} c{c} {h}x{w} cw{cw} S{segments}"),
            )
        });
    }

    /// Segment boundaries landing on chunk resets carry nothing across,
    /// so the segmented path collapses to the exact plane-path bits.
    #[test]
    fn segmented_chunk_aligned_is_exact_vs_reference() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(51);
        let (n, c, h, w) = (1, 2, 6, 64);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        // S = 4 -> seg_len = 16; kchunk = 8 divides 16, so every segment
        // starts on a reset.
        let reference = scan_l2r(&x, &taps, &lam, 8);
        let seg = fused_scan_l2r_seg(&x, &taps, &lam, 8, 4, &pool);
        assert_eq!(reference.data, seg.data);
    }

    /// Unaligned chunk resets inside segments stay numerically
    /// equivalent (the carry dies at the reset; only pre-reset columns
    /// reassociate).
    #[test]
    fn segmented_chunk_unaligned_is_close() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(52);
        let (n, c, h, w) = (1, 1, 7, 96);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let reference = scan_l2r(&x, &taps, &lam, 32);
        // S = 5 -> seg_len = 20: boundaries at 20/40/60/80 never align
        // with the resets at 32/64.
        let seg = fused_scan_l2r_seg(&x, &taps, &lam, 32, 5, &pool);
        assert!(
            reference.allclose(&seg, 1e-4, 1e-4),
            "max diff {}",
            reference.max_abs_diff(&seg)
        );
    }

    /// The merged 4-direction segmented pass: tolerance-pinned against
    /// the serial reference composition, and bit-deterministic across
    /// pool widths (scheduling never changes segmented arithmetic).
    #[test]
    fn segmented_merged_close_to_reference_and_deterministic() {
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(53);
        let (n, c, h, w) = (1, 2, 24, 40);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.4f32, -0.2, 1.1, 0.0];
        let reference = merged_4dir_ref(&x, taps, &lam, &logits, 0);
        let a = fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, 4, &pool1);
        let b = fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, 4, &pool3);
        assert_eq!(a.data, b.data, "pool width changed segmented bits");
        assert!(
            reference.allclose(&a, 1e-4, 1e-4),
            "max diff {}",
            reference.max_abs_diff(&a)
        );
    }

    /// Whenever the planner picks plane-parallel, the pooled entry
    /// points are exactly the PR 2 engine — bit-identical to the serial
    /// reference. Any geometry narrower than 2 * plan::MIN_SEG_COLS
    /// canonical columns (everything the unit/e2e suites pin) can never
    /// be segmented regardless of host pool width.
    #[test]
    fn auto_plane_regime_stays_bit_identical() {
        let pool = crate::util::ThreadPool::new(7);
        let mut rng = Rng::new(54);
        let (n, c, h, w) = (1, 2, 32, 64);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        assert_eq!(plan::auto_segments(n * c, w, pool.threads()), None);
        let reference = scan_l2r(&x, &taps, &lam, 0);
        let pooled = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
        assert_eq!(reference.data, pooled.data);
    }

    /// When the planner does segment, the pooled entry point produces
    /// exactly the scan_l2r_split bits for the count it chose.
    #[test]
    fn auto_low_occupancy_matches_split_reference() {
        let pool = crate::util::ThreadPool::new(4);
        let mut rng = Rng::new(55);
        let (n, c, h, w) = (1, 1, 8, 256);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let s = plan::auto_segments(n * c, w, pool.threads())
            .expect("low occupancy must segment");
        assert_eq!(s, 4);
        let viapool = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
        let reference = scan_l2r_split(&x, &taps, &lam, s, 1);
        assert_eq!(reference.data, viapool.data);
    }

    /// The single-direction serving band the fused-correction drain
    /// opened (128 <= wc < 256, previously fenced onto the plane path):
    /// the planner now segments it, and the pooled entry point produces
    /// exactly the scan_l2r_split bits at the planned count.
    #[test]
    fn auto_midwidth_band_segments_and_matches_split() {
        let pool = crate::util::ThreadPool::new(4);
        let mut rng = Rng::new(57);
        let (n, c, h, w) = (1, 1, 8, 192);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let s = plan::auto_segments(n * c, w, pool.threads())
            .expect("the 128..256 band must segment now");
        assert_eq!(s, 3);
        let viapool = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
        let reference = scan_l2r_split(&x, &taps, &lam, s, 1);
        assert_eq!(reference.data, viapool.data);
    }

    /// Orientation folding in the segmented path, pinned exactly: the
    /// segmented directional scan equals `scan_l2r_split` run on the
    /// canonically reoriented tensors (data movement changes no bits).
    #[test]
    fn segmented_all_directions_match_canonical_split() {
        use crate::scan::direction::{from_canonical, to_canonical};
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(56);
        let (n, c, h, w) = (1, 2, 6, 9);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, 1, hc, wc);
            let xc = to_canonical(&x, d);
            let lamc = to_canonical(&lam, d);
            for segments in [2usize, 3] {
                let want =
                    from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
                let got = fused_scan_dir_seg(&x, &taps, &lam, d, 0, segments, &pool);
                assert_eq!(want.data, got.data, "{d:?} S{segments}");
            }
        }
    }

    #[test]
    fn segmented_empty_and_degenerate_geometries() {
        let pool = crate::util::ThreadPool::new(2);
        let x = Tensor::zeros(&[0, 3, 4, 5]);
        let lam = Tensor::zeros(&[0, 3, 4, 5]);
        let taps = Taps::normalize(&Tensor::zeros(&[0, 1, 3, 4, 5]));
        let out = fused_scan_l2r_seg(&x, &taps, &lam, 0, 3, &pool);
        assert_eq!(out.shape, vec![0, 3, 4, 5]);

        let x = Tensor::zeros(&[1, 2, 0, 5]);
        let lam = Tensor::zeros(&[1, 2, 0, 5]);
        let taps = Taps::normalize(&Tensor::zeros(&[1, 1, 3, 0, 5]));
        let out = fused_scan_l2r_seg(&x, &taps, &lam, 0, 3, &pool);
        assert!(out.data.is_empty());
    }

    // -----------------------------------------------------------------
    // Wavefront scheduling + the direction fan
    // -----------------------------------------------------------------

    /// The tentpole pinning property for wavefront scheduling and the
    /// fused-correction drain: neither the dependency-graph schedule nor
    /// fusing the correction into the drain changes what is computed —
    /// exact `==` across the full schedule matrix (barrier,
    /// per-direction wavefront, PR 4 two-pass single-continuation) with
    /// the `scan_l2r_split` reference, across segment counts, chunk
    /// resets, pool widths (including the 1-thread all-helping case),
    /// and slab-boundary widths.
    #[test]
    fn wavefront_exact_eq_barrier_and_split() {
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(60);
        for (n, c, h, w, cw) in [
            (1, 1, 5, 12, 1),
            (2, 3, 8, 40, 1),
            (1, 2, 9, 1, 1),
            (1, 1, 4, 2 * SLAB + 3, 1),
            (2, 2, 6, 96, 2),
        ] {
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let taps = mk_taps(&mut rng, n, cw, h, w);
            for segments in [1usize, 2, 3, 5, w + 9] {
                let reference = scan_l2r_split(&x, &taps, &lam, segments, 1);
                let barrier = fused_scan_l2r_seg(&x, &taps, &lam, 0, segments, &pool3);
                let wave1 = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, segments, &pool1);
                let wave3 = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, segments, &pool3);
                let twopass =
                    fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, 0, segments, &pool3);
                assert_eq!(
                    reference.data, barrier.data,
                    "barrier n{n} c{c} {h}x{w} S{segments}"
                );
                assert_eq!(
                    reference.data, wave1.data,
                    "wave 1-thread n{n} c{c} {h}x{w} S{segments}"
                );
                assert_eq!(
                    reference.data, wave3.data,
                    "wave 3-thread n{n} c{c} {h}x{w} S{segments}"
                );
                assert_eq!(
                    reference.data, twopass.data,
                    "PR4 two-pass n{n} c{c} {h}x{w} S{segments}"
                );
            }
        }
    }

    /// Wavefront with chunk resets landing inside segments: the carry
    /// dies at resets exactly like the barrier path.
    #[test]
    fn wavefront_chunked_matches_barrier_bits() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(61);
        let (n, c, h, w) = (1, 2, 7, 96);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        for (kchunk, segments) in [(32usize, 5usize), (8, 4), (96, 3)] {
            let barrier = fused_scan_l2r_seg(&x, &taps, &lam, kchunk, segments, &pool);
            let wave = fused_scan_l2r_seg_wave(&x, &taps, &lam, kchunk, segments, &pool);
            let twopass =
                fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, kchunk, segments, &pool);
            assert_eq!(barrier.data, wave.data, "k{kchunk} S{segments}");
            assert_eq!(barrier.data, twopass.data, "two-pass k{kchunk} S{segments}");
        }
    }

    /// The merged 4-direction pass under wavefront scheduling: exact
    /// `==` with the barrier twin for every direction/orientation mix.
    #[test]
    fn wavefront_merged_exact_eq_barrier() {
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(62);
        let (n, c, h, w) = (1, 2, 24, 40);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.4f32, -0.2, 1.1, 0.0];
        for segments in [1usize, 4] {
            let barrier = fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, segments, &pool3);
            let wave1 = fused_merged_4dir_seg_wave(&x, taps, &lam, &logits, 0, segments, &pool1);
            let wave3 = fused_merged_4dir_seg_wave(&x, taps, &lam, &logits, 0, segments, &pool3);
            let twopass =
                fused_merged_4dir_seg_wave_twopass(&x, taps, &lam, &logits, 0, segments, &pool3);
            assert_eq!(barrier.data, wave1.data, "S{segments}");
            assert_eq!(barrier.data, wave3.data, "S{segments}");
            assert_eq!(barrier.data, twopass.data, "two-pass S{segments}");
        }
    }

    /// Directional scans under wavefront scheduling match the canonical
    /// split reference exactly, per direction (orientation folding does
    /// not interact with the schedule).
    #[test]
    fn wavefront_all_directions_match_canonical_split() {
        use crate::scan::direction::{from_canonical, to_canonical};
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(63);
        let (n, c, h, w) = (1, 2, 6, 9);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, 1, hc, wc);
            let xc = to_canonical(&x, d);
            let lamc = to_canonical(&lam, d);
            for segments in [2usize, 3] {
                let want =
                    from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
                let got = fused_scan_dir_seg_wave(&x, &taps, &lam, d, 0, segments, &pool);
                let twopass =
                    fused_scan_dir_seg_wave_twopass(&x, &taps, &lam, d, 0, segments, &pool);
                assert_eq!(want.data, got.data, "{d:?} S{segments}");
                assert_eq!(want.data, twopass.data, "two-pass {d:?} S{segments}");
            }
        }
    }

    /// The direction fan is bit-identical to the fused merge (and hence
    /// the serial reference): a full-width zero-carry scan per (plane,
    /// direction) reassociates nothing, and the drain replays the fixed
    /// k = 0..4 merge order. Both schedules, several pool widths, tiny
    /// and slab-crossing widths, H=1/W=1 edges.
    #[test]
    fn dirfan_exact_eq_fused_merge_reference() {
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(64);
        for (n, c, h, w) in [(2, 3, 6, 7), (1, 1, 1, 6), (1, 2, 6, 1), (1, 2, 24, 2 * SLAB + 3)]
        {
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let t_lr = mk_taps(&mut rng, n, 1, h, w);
            let t_rl = mk_taps(&mut rng, n, 1, h, w);
            let t_tb = mk_taps(&mut rng, n, 1, w, h);
            let t_bt = mk_taps(&mut rng, n, 1, w, h);
            let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
            let logits = [0.3f32, -0.7, 0.2, 1.0];
            let reference = merged_4dir_ref(&x, taps, &lam, &logits, 0);
            for pool in [&pool1, &pool3] {
                for wavefront in [false, true] {
                    let fan =
                        fused_merged_4dir_fan(&x, taps, &lam, &logits, 0, wavefront, pool);
                    assert_eq!(
                        reference.data, fan.data,
                        "n{n} c{c} {h}x{w} wf{wavefront}"
                    );
                }
            }
        }
    }

    /// DirFan with chunk resets: the fan scans full width with resets
    /// folded into phase 1, so chunked output equals the chunked
    /// reference exactly too.
    #[test]
    fn dirfan_chunked_exact_eq_reference() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(65);
        let (n, c, h, w) = (1, 2, 8, 8);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let taps = [&t_lr, &t_lr, &t_tb, &t_tb];
        let logits = [0.1f32, 0.5, -0.3, 0.0];
        for kchunk in [0usize, 4, 8] {
            let reference = merged_4dir_ref(&x, taps, &lam, &logits, kchunk);
            let fan = fused_merged_4dir_fan(&x, taps, &lam, &logits, kchunk, true, &pool);
            assert_eq!(reference.data, fan.data, "k{kchunk}");
        }
    }

    /// A planner-forced plan carried end to end through the forced hook
    /// equals running the plan's strategy directly (the plan-carrying
    /// path the serving/bench layers use).
    #[test]
    fn planned_execution_matches_direct_strategy_calls() {
        use crate::scan::plan::{plan_scan_with, PlanOverride};
        let pool = crate::util::ThreadPool::new(4);
        let mut rng = Rng::new(66);
        let (n, c, h, w) = (1, 1, 8, 256);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let geom = ScanGeometry::single_dir(n * c, h, w);
        let p = plan_scan_with(&geom, 0, pool.threads(), PlanOverride::Auto);
        let ScanStrategy::Chained { s } = p.strategy else {
            panic!("expected a chained plan, got {:?}", p.strategy);
        };
        assert!(!p.wavefront, "the chained engine has no phases to wavefront");
        let via_auto = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
        let direct = fused_scan_l2r_chained(&x, &taps, &lam, 0, s, &pool);
        assert_eq!(via_auto.data, direct.data);
        // The chained engine replaced the two-phase Segmented plan at
        // the same count bit-for-bit.
        let twophase = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, s, &pool);
        assert_eq!(via_auto.data, twophase.data);
    }

    // -----------------------------------------------------------------
    // The fused-correction drain
    // -----------------------------------------------------------------

    /// The fused-correction drain property: exact `==` against the
    /// `scan_l2r_split` reference across random shapes (including H=1,
    /// W=1, and slab-crossing widths), all 4 directions, segment
    /// counts, and the full schedule matrix — per-direction wavefront,
    /// barrier, and the PR 4 two-pass single-continuation. Plus, under
    /// random kchunk divisors (split has no chunk form), all three
    /// schedules stay bit-identical to each other.
    #[test]
    fn fused_correction_drain_schedule_matrix_property() {
        use crate::scan::direction::{from_canonical, to_canonical};
        let pool = crate::util::ThreadPool::new(3);
        check("fused drain == split across schedules", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 2);
            let h = g.int_in(1, 9);
            let w = g.int_in(1, 2 * SLAB + 8);
            let segments = g.int_in(1, 5);
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            for d in DIRECTIONS {
                let (hc, wc) = hw_src(h, w, d);
                let taps = mk_taps(&mut rng, n, 1, hc, wc);
                let xc = to_canonical(&x, d);
                let lamc = to_canonical(&lam, d);
                let want =
                    from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
                let barrier = fused_scan_dir_seg(&x, &taps, &lam, d, 0, segments, &pool);
                let wave = fused_scan_dir_seg_wave(&x, &taps, &lam, d, 0, segments, &pool);
                let twopass =
                    fused_scan_dir_seg_wave_twopass(&x, &taps, &lam, d, 0, segments, &pool);
                let tag = format!("n{n} c{c} {h}x{w} {d:?} S{segments}");
                ensure(want.data == barrier.data, format!("barrier != split: {tag}"))?;
                ensure(want.data == wave.data, format!("wave != split: {tag}"))?;
                ensure(want.data == twopass.data, format!("two-pass != split: {tag}"))?;
                // Chunk resets inside segments: the three schedules must
                // agree bit-for-bit (the chunked split reference is the
                // barrier engine itself).
                let kchunk = *g.pick(&divisors(wc));
                let cb = fused_scan_dir_seg(&x, &taps, &lam, d, kchunk, segments, &pool);
                let cw_ = fused_scan_dir_seg_wave(&x, &taps, &lam, d, kchunk, segments, &pool);
                let ct =
                    fused_scan_dir_seg_wave_twopass(&x, &taps, &lam, d, kchunk, segments, &pool);
                ensure(cb.data == cw_.data, format!("chunked wave != barrier: {tag} k{kchunk}"))?;
                ensure(cb.data == ct.data, format!("chunked two-pass != barrier: {tag} k{kchunk}"))?;
            }
            Ok(())
        });
    }

    // -----------------------------------------------------------------
    // The single-pass chained engine
    // -----------------------------------------------------------------

    /// The tentpole exactness property: the single-pass chained engine
    /// (decoupled look-back, no phase barrier) is exact `==` against
    /// `scan_l2r_split` across random shapes (including H=1, W=1, and
    /// slab-crossing widths), all 4 directions, chunk counts, shared
    /// and per-channel taps, and both the serial path (1-thread pool)
    /// and concurrent chains with work-assist (3-thread pool). Under
    /// random kchunk divisors (split has no chunk form) chained must
    /// equal the two-phase barrier engine bit-for-bit — the claim that
    /// retiring the barrier changed the schedule and nothing else.
    #[test]
    fn chained_engine_exact_eq_split_property() {
        use crate::scan::direction::{from_canonical, to_canonical};
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        check("chained == split across shapes", |g| {
            let n = g.int_in(1, 2);
            let c = g.int_in(1, 2);
            let h = g.int_in(1, 9);
            let w = g.int_in(1, 2 * SLAB + 8);
            let segments = g.int_in(1, 5);
            let mut rng = Rng::new(g.rng.next_u64());
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            for d in DIRECTIONS {
                let (hc, wc) = hw_src(h, w, d);
                let cw = *g.pick(&[1, c]);
                let taps = mk_taps(&mut rng, n, cw, hc, wc);
                let xc = to_canonical(&x, d);
                let lamc = to_canonical(&lam, d);
                let want =
                    from_canonical(&scan_l2r_split(&xc, &taps, &lamc, segments, 1), d);
                let tag = format!("n{n} c{c} cw{cw} {h}x{w} {d:?} S{segments}");
                for (pname, pool) in [("pool1", &pool1), ("pool3", &pool3)] {
                    let got = fused_scan_dir_chained(&x, &taps, &lam, d, 0, segments, pool);
                    ensure(want.data == got.data, format!("chained != split: {tag} {pname}"))?;
                }
                // Chunk resets inside chunks: the chunked split
                // reference is the two-phase barrier engine itself.
                let kchunk = *g.pick(&divisors(wc));
                let barrier = fused_scan_dir_seg(&x, &taps, &lam, d, kchunk, segments, &pool3);
                let chained =
                    fused_scan_dir_chained(&x, &taps, &lam, d, kchunk, segments, &pool3);
                ensure(
                    barrier.data == chained.data,
                    format!("chunked chained != barrier: {tag} k{kchunk}"),
                )?;
            }
            Ok(())
        });
    }

    /// The merged 4-direction pass under the chained engine: the
    /// per-plane drain gates preserve the k = 0..4 merge order, so
    /// chained output is exact `==` the two-phase barrier merged engine
    /// at every chunk count (and, at S = 1, the serial merged
    /// reference) — on the degenerate H=1 / W=1 geometries and a
    /// slab-crossing width too.
    #[test]
    fn chained_merged_4dir_exact_eq_segmented() {
        let pool1 = crate::util::ThreadPool::new(1);
        let pool3 = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(74);
        for (n, c, h, w) in [(2, 3, 6, 7), (1, 1, 1, 6), (1, 2, 6, 1), (1, 2, 24, 2 * SLAB + 3)]
        {
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let t_lr = mk_taps(&mut rng, n, 1, h, w);
            let t_rl = mk_taps(&mut rng, n, 1, h, w);
            let t_tb = mk_taps(&mut rng, n, 1, w, h);
            let t_bt = mk_taps(&mut rng, n, 1, w, h);
            let taps = [&t_lr, &t_rl, &t_tb, &t_bt];
            let logits = [0.3f32, -0.7, 0.2, 1.0];
            let serial = merged_4dir_ref(&x, taps, &lam, &logits, 0);
            for segments in [1usize, 2, 3] {
                let reference =
                    fused_merged_4dir_seg(&x, taps, &lam, &logits, 0, segments, &pool3);
                for (pname, pool) in [("pool1", &pool1), ("pool3", &pool3)] {
                    let got =
                        fused_merged_4dir_chained(&x, taps, &lam, &logits, 0, segments, pool);
                    assert_eq!(
                        reference.data, got.data,
                        "n{n} c{c} {h}x{w} S{segments} {pname}"
                    );
                }
                if segments == 1 {
                    assert_eq!(serial.data, reference.data, "n{n} c{c} {h}x{w} S1 serial");
                }
            }
        }
    }

    /// Satellite regression: a panicking phase-1 job in the wavefront
    /// path must surface as the original panic payload (collected
    /// MapError-style through `run_graph`), not as a `PoisonError` or a
    /// secondary index panic from a dependent drain reading a missing
    /// piece — and the engine/pool must stay healthy afterwards.
    #[test]
    fn wavefront_phase1_panic_propagates_original_payload() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let pool = crate::util::ThreadPool::new(2);
        let mut rng = Rng::new(70);
        let (n, c, h, w) = (1, 2, 5, 160);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        // w=160, S=2 -> bounds (0,80),(80,160). Inject into the second
        // piece of plane 0 — a (plane, dir, lo, hi) tuple no other
        // test's geometry produces (every other suite's segment ends
        // are < 80 or land elsewhere), so concurrently running tests
        // never trip the hook.
        for schedule in ["wave-dir", "two-pass"] {
            *lock_unpoisoned(&test_hooks::PANIC_PIECE) = Some((0, 0, 80, 160));
            let caught = catch_unwind(AssertUnwindSafe(|| match schedule {
                "wave-dir" => fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, 2, &pool),
                _ => fused_scan_l2r_seg_wave_twopass(&x, &taps, &lam, 0, 2, &pool),
            }));
            *lock_unpoisoned(&test_hooks::PANIC_PIECE) = None;
            let payload = match caught {
                Ok(_) => panic!("{schedule}: wavefront must rethrow the phase-1 panic"),
                Err(p) => p,
            };
            let msg = crate::util::panic_message(&*payload);
            assert!(
                msg.contains("injected phase-1 panic"),
                "{schedule}: expected the injected payload, got {msg:?}"
            );
        }
        // Poisoned hand-off slots are recovered; the next run is clean
        // and exact.
        let reference = scan_l2r_split(&x, &taps, &lam, 2, 1);
        let after = fused_scan_l2r_seg_wave(&x, &taps, &lam, 0, 2, &pool);
        assert_eq!(reference.data, after.data);
    }

    // -----------------------------------------------------------------
    // Workspace pooling
    // -----------------------------------------------------------------

    /// Pooled scratch changes no bits: every strategy/schedule produces
    /// the same output from a cold workspace (all misses), a warm one
    /// (reused, dirty buffers), and equals the `scan_l2r_split` /
    /// serial reference. This is the pooled-vs-fresh half of the
    /// allocation-free acceptance invariant.
    #[test]
    fn pooled_output_bit_identical_to_fresh_workspace_across_strategies() {
        let pool = crate::util::ThreadPool::new(3);
        let mut rng = Rng::new(71);
        let (n, c, h, w) = (1, 2, 7, 96);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let cases = [
            (ScanStrategy::PlanePar, Phase2::Barrier),
            (ScanStrategy::Segmented { s: 3 }, Phase2::Barrier),
            (ScanStrategy::Segmented { s: 3 }, Phase2::WaveDir),
            (ScanStrategy::Segmented { s: 3 }, Phase2::WavePlane),
            (ScanStrategy::Chained { s: 3 }, Phase2::Barrier),
        ];
        for (strategy, phase2) in cases {
            let reference = match strategy {
                ScanStrategy::Segmented { s } | ScanStrategy::Chained { s } => {
                    scan_l2r_split(&x, &taps, &lam, s, 1)
                }
                _ => scan_l2r(&x, &taps, &lam, 0),
            };
            let warm_ws = BufferPool::new(usize::MAX);
            for round in 0..3 {
                let cold_ws = BufferPool::new(usize::MAX);
                let cold = fused_scan_dir_forced_ws(
                    &x, &taps, &lam, Direction::L2R, 0, strategy, phase2, &pool, &cold_ws,
                    None,
                );
                let warm = fused_scan_dir_forced_ws(
                    &x, &taps, &lam, Direction::L2R, 0, strategy, phase2, &pool, &warm_ws,
                    None,
                );
                assert_eq!(
                    reference.data, cold.data,
                    "cold != ref: {strategy:?} {phase2:?} round {round}"
                );
                assert_eq!(
                    reference.data, warm.data,
                    "warm != ref: {strategy:?} {phase2:?} round {round}"
                );
            }
            // Everything leased came back.
            assert_eq!(warm_ws.stats().bytes_leased, 0, "{strategy:?} {phase2:?}");
        }
        // The merged direction fan (the strategy the single-direction
        // matrix above cannot reach).
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.4f32, -0.2, 1.1, 0.0];
        let reference = merged_4dir_ref(&x, mtaps, &lam, &logits, 0);
        let warm_ws = BufferPool::new(usize::MAX);
        for phase2 in [Phase2::Barrier, Phase2::WaveDir] {
            for round in 0..2 {
                let fan = fused_merged_4dir_forced_ws(
                    &x,
                    mtaps,
                    &lam,
                    &logits,
                    0,
                    ScanStrategy::DirFan,
                    phase2,
                    &pool,
                    &warm_ws,
                    None,
                );
                assert_eq!(reference.data, fan.data, "dirfan {phase2:?} round {round}");
            }
        }
        assert_eq!(warm_ws.stats().bytes_leased, 0);
    }

    /// The reply-recycling entry: an output buffer taken from the
    /// workspace produces bit-identical results to the fresh-allocating
    /// entry, and donating the result's storage back makes the next
    /// take a pool hit — the coordinator's whole-request
    /// allocation-free loop, exercised at the engine level.
    #[test]
    fn recycled_output_buffer_bit_identical_and_donated() {
        // 1 thread: the serial lease sequence makes the zero-miss
        // assertion deterministic (the 2+-thread schedules are covered
        // by the bit-exactness suites).
        let pool = crate::util::ThreadPool::new(1);
        let mut rng = Rng::new(77);
        let (n, c, h, w) = (1, 3, 7, 40);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        let want = fused_scan_l2r_pool(&x, &taps, &lam, 0, &pool);
        let ws = BufferPool::new(usize::MAX);
        let out = fused_scan_l2r_pool_ws_into(
            &x,
            &taps,
            &lam,
            0,
            &pool,
            &ws,
            ws.take_zeroed(x.data.len()),
        );
        assert_eq!(out.data, want.data);
        assert_eq!(ws.stats().bytes_leased, 0);
        // Donate the reply storage back; the rerun's take must hit.
        ws.donate(out.data);
        let before = ws.stats();
        let out = fused_scan_l2r_pool_ws_into(
            &x,
            &taps,
            &lam,
            0,
            &pool,
            &ws,
            ws.take_zeroed(x.data.len()),
        );
        let after = ws.stats();
        assert_eq!(out.data, want.data);
        assert!(after.hits > before.hits, "recycled take must be served from the pool");
        assert_eq!(
            after.misses, before.misses,
            "a donated reply buffer must make the next take allocation-free"
        );
    }

    /// The allocation-free invariant at the engine level: on the
    /// deterministic (serial-execution) paths, repeating an identical
    /// call against a warm workspace records ZERO pool misses — the
    /// second run's every acquire is served from buffers the first run
    /// returned. A 1-thread pool takes the serial branches of every
    /// barrier strategy, so the lease sequence is reproducible.
    #[test]
    fn warm_workspace_rerun_records_zero_misses() {
        let pool1 = crate::util::ThreadPool::new(1);
        let mut rng = Rng::new(72);
        let (n, c, h, w) = (1, 2, 6, 48);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        for strategy in [
            ScanStrategy::PlanePar,
            ScanStrategy::Segmented { s: 3 },
            ScanStrategy::Chained { s: 3 },
        ] {
            let ws = BufferPool::new(usize::MAX);
            let first = fused_scan_dir_forced_ws(
                &x, &taps, &lam, Direction::L2R, 0, strategy, Phase2::Barrier, &pool1, &ws,
                None,
            );
            let s1 = ws.stats();
            assert!(s1.misses > 0, "{strategy:?}: cold run must allocate");
            assert_eq!(s1.bytes_leased, 0, "{strategy:?}: leases must all return");
            let second = fused_scan_dir_forced_ws(
                &x, &taps, &lam, Direction::L2R, 0, strategy, Phase2::Barrier, &pool1, &ws,
                None,
            );
            let s2 = ws.stats();
            assert_eq!(
                s2.misses, s1.misses,
                "{strategy:?}: warm rerun allocated from the heap"
            );
            assert!(s2.hits > s1.hits, "{strategy:?}: warm rerun must hit the pool");
            assert_eq!(first.data, second.data);
        }
        // The merged fan on the barrier schedule is serial on a 1-thread
        // pool too.
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let mtaps = [&t_lr, &t_lr, &t_tb, &t_tb];
        let logits = [0.3f32, -0.7, 0.2, 1.0];
        let ws = BufferPool::new(usize::MAX);
        let first = fused_merged_4dir_forced_ws(
            &x,
            mtaps,
            &lam,
            &logits,
            0,
            ScanStrategy::DirFan,
            Phase2::Barrier,
            &pool1,
            &ws,
            None,
        );
        let s1 = ws.stats();
        let second = fused_merged_4dir_forced_ws(
            &x,
            mtaps,
            &lam,
            &logits,
            0,
            ScanStrategy::DirFan,
            Phase2::Barrier,
            &pool1,
            &ws,
            None,
        );
        assert_eq!(ws.stats().misses, s1.misses, "dirfan warm rerun allocated");
        assert_eq!(first.data, second.data);
    }

    /// RAII under unwinding: a phase-1 piece job that panics while
    /// holding leased scratch (the injection fires *after* the piece
    /// lease is acquired) must return every lease to the workspace —
    /// nothing stays out on lease, and the buffers parked in the
    /// abandoned hand-off slots come back when the engine's slot vec
    /// drops. The pool serves the next run without leaking.
    #[test]
    fn wavefront_panic_returns_all_leases_to_workspace() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let pool = crate::util::ThreadPool::new(2);
        let ws = BufferPool::new(usize::MAX);
        let mut rng = Rng::new(73);
        let (n, c, h, w) = (1, 2, 5, 224);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        // w=224, S=2 -> bounds (0,112),(112,224). A (plane, dir, lo, hi)
        // tuple unique to this test's geometry, so concurrently running
        // suites never trip the hook.
        *lock_unpoisoned(&test_hooks::PANIC_PIECE) = Some((0, 0, 112, 224));
        let caught = catch_unwind(AssertUnwindSafe(|| {
            fused_scan_dir_forced_ws(
                &x,
                &taps,
                &lam,
                Direction::L2R,
                0,
                ScanStrategy::Segmented { s: 2 },
                Phase2::WaveDir,
                &pool,
                &ws,
                None,
            )
        }));
        *lock_unpoisoned(&test_hooks::PANIC_PIECE) = None;
        assert!(caught.is_err(), "the injected panic must propagate");
        let s = ws.stats();
        assert_eq!(
            s.bytes_leased, 0,
            "a panicking scan leaked workspace leases: {s:?}"
        );
        assert!(s.bytes_pooled > 0, "returned buffers must be pooled for reuse");
        // The pool still serves bit-exact scans afterwards.
        let reference = scan_l2r_split(&x, &taps, &lam, 2, 1);
        let after = fused_scan_dir_forced_ws(
            &x,
            &taps,
            &lam,
            Direction::L2R,
            0,
            ScanStrategy::Segmented { s: 2 },
            Phase2::WaveDir,
            &pool,
            &ws,
            None,
        );
        assert_eq!(reference.data, after.data);
        assert_eq!(ws.stats().bytes_leased, 0);
    }

    /// Spin-safety of the chained engine (the look-back satellite): a
    /// chunk that panics mid-chain poisons its board block, so every
    /// chunk spinning on that chain unwinds through `MapError` instead
    /// of deadlocking on a prefix that will never be published. Both
    /// injection points matter — the chain head (everyone downstream
    /// waits on it) and a mid-chain chunk (upstream already published,
    /// downstream mid-wait). Afterwards every lease is back, the
    /// returned buffers are pooled, and the same pool + workspace serve
    /// a bit-exact rerun.
    #[test]
    fn chained_panic_poisons_board_and_returns_leases() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let pool = crate::util::ThreadPool::new(2);
        let ws = BufferPool::new(usize::MAX);
        let mut rng = Rng::new(75);
        let (n, c, h, w) = (1, 2, 5, 320);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let taps = mk_taps(&mut rng, n, 1, h, w);
        // w=320, S=2 -> bounds (0,160),(160,320), planes {0,1}. Plane
        // 1's tuples are unique to this geometry (no other suite
        // produces segment ends at 160/320), so concurrently running
        // tests never trip the hook.
        for inject in [(1, 0, 160, 320), (1, 0, 0, 160)] {
            *lock_unpoisoned(&test_hooks::PANIC_PIECE) = Some(inject);
            let caught = catch_unwind(AssertUnwindSafe(|| {
                fused_scan_dir_forced_ws(
                    &x,
                    &taps,
                    &lam,
                    Direction::L2R,
                    0,
                    ScanStrategy::Chained { s: 2 },
                    Phase2::Barrier,
                    &pool,
                    &ws,
                    None,
                )
            }));
            *lock_unpoisoned(&test_hooks::PANIC_PIECE) = None;
            let payload = match caught {
                Ok(_) => panic!("{inject:?}: the chained engine must rethrow the panic"),
                Err(p) => p,
            };
            // The surfaced payload is the injected one, or a waiter's
            // secondary poisoned-chain panic when that lands in the
            // MapError first — never a deadlock or a PoisonError.
            let msg = crate::util::panic_message(&*payload);
            assert!(
                msg.contains("injected phase-1 panic") || msg.contains("chained scan"),
                "{inject:?}: unexpected payload {msg:?}"
            );
            let s = ws.stats();
            assert_eq!(s.bytes_leased, 0, "{inject:?}: leaked leases: {s:?}");
            assert!(s.bytes_pooled > 0, "{inject:?}: returned buffers must be pooled");
        }
        // The pool and workspace still serve bit-exact chained scans.
        let reference = scan_l2r_split(&x, &taps, &lam, 2, 1);
        let after = fused_scan_dir_forced_ws(
            &x,
            &taps,
            &lam,
            Direction::L2R,
            0,
            ScanStrategy::Chained { s: 2 },
            Phase2::Barrier,
            &pool,
            &ws,
            None,
        );
        assert_eq!(reference.data, after.data);
        assert_eq!(ws.stats().bytes_leased, 0);
    }

    /// The SIMD pin at the engine level: every vector kernel this host
    /// supports produces output exactly `==` the scalar kernel's across
    /// all four directions, every strategy/schedule, kchunk resets, and
    /// slab-boundary / degenerate widths. (The scalar kernel itself is
    /// pinned `==` the unfused reference by the suites above, so this
    /// transitively pins the vector kernels to the reference.) Flipping
    /// the process-global kernel override is safe even under concurrent
    /// tests precisely because of this property — any kernel produces
    /// the same bits.
    #[test]
    fn simd_kernels_pinned_bit_identical_to_scalar_across_engine_matrix() {
        let kernels: Vec<&str> = ["avx2", "neon"]
            .into_iter()
            .filter(|k| simd::set_simd_override(k).is_ok())
            .collect();
        simd::set_simd_override("auto").unwrap();
        if kernels.is_empty() {
            // Scalar-only host: the vector kernels are pinned by the
            // x86_64/aarch64 CI legs; nothing to compare here.
            return;
        }
        let pool = crate::util::ThreadPool::new(4);
        let ws = BufferPool::new(usize::MAX);
        let mut rng = Rng::new(91);
        // Slab crossings, the partial last slab, H=1 and W=1 columns.
        let geoms = [
            (1usize, 2usize, 5usize, SLAB - 1),
            (1, 2, 5, SLAB + 1),
            (1, 1, 1, 2 * SLAB + 3),
            (1, 2, 2 * SLAB + 3, 1),
            (2, 2, 9, 48),
        ];
        let cases = [
            (ScanStrategy::PlanePar, Phase2::Barrier),
            (ScanStrategy::Segmented { s: 3 }, Phase2::Barrier),
            (ScanStrategy::Segmented { s: 3 }, Phase2::WaveDir),
            (ScanStrategy::Segmented { s: 3 }, Phase2::WavePlane),
            (ScanStrategy::Chained { s: 3 }, Phase2::Barrier),
        ];
        for (n, c, h, w) in geoms {
            let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
            for d in DIRECTIONS {
                let (hc, wc) = hw_src(h, w, d);
                let taps = mk_taps(&mut rng, n, 1, hc, wc);
                // Full width plus one mid-column carry reset.
                let kchunks =
                    if wc >= 2 && wc % 2 == 0 { vec![0usize, wc / 2] } else { vec![0usize] };
                for &k in &kchunks {
                    for (strategy, phase2) in cases {
                        simd::set_simd_override("scalar").unwrap();
                        let base = fused_scan_dir_forced_ws(
                            &x, &taps, &lam, d, k, strategy, phase2, &pool, &ws, None,
                        );
                        for kern in &kernels {
                            simd::set_simd_override(kern).unwrap();
                            let got = fused_scan_dir_forced_ws(
                                &x, &taps, &lam, d, k, strategy, phase2, &pool, &ws, None,
                            );
                            assert_eq!(
                                base.data, got.data,
                                "{kern} != scalar: n{n} c{c} {h}x{w} {d:?} k{k} \
                                 {strategy:?} {phase2:?}"
                            );
                        }
                    }
                }
            }
        }
        // The merged path: softmax-merge + modulation epilogue under
        // DirFan (unreachable from the single-direction matrix) and the
        // chained engine.
        let (n, c, h, w) = (1usize, 2usize, 6usize, SLAB + 5);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.4f32, -0.2, 1.1, 0.0];
        for (strategy, phase2) in [
            (ScanStrategy::DirFan, Phase2::Barrier),
            (ScanStrategy::DirFan, Phase2::WaveDir),
            (ScanStrategy::Segmented { s: 2 }, Phase2::WaveDir),
            (ScanStrategy::Chained { s: 2 }, Phase2::Barrier),
        ] {
            simd::set_simd_override("scalar").unwrap();
            let base = fused_merged_4dir_forced_ws(
                &x, mtaps, &lam, &logits, 0, strategy, phase2, &pool, &ws, None,
            );
            for kern in &kernels {
                simd::set_simd_override(kern).unwrap();
                let got = fused_merged_4dir_forced_ws(
                    &x, mtaps, &lam, &logits, 0, strategy, phase2, &pool, &ws, None,
                );
                assert_eq!(
                    base.data, got.data,
                    "merged {kern} != scalar: {strategy:?} {phase2:?}"
                );
            }
        }
        simd::set_simd_override("auto").unwrap();
        assert_eq!(ws.stats().bytes_leased, 0);
    }

    /// The bf16 panel-mode pin: with taps and chained panels stored as
    /// bf16 (threaded per call — never via the process-global override,
    /// which concurrently running `==` suites would observe), every
    /// strategy's output matches the f32 run elementwise within the
    /// documented tolerance `|bf16 - f32| <= (|f32| + 1) * 2^-6`, and
    /// the narrowing actually engages (bits differ from f32).
    #[test]
    fn bf16_panels_within_documented_tolerance_of_f32() {
        let pool = crate::util::ThreadPool::new(4);
        let ws = BufferPool::new(usize::MAX);
        let mut rng = Rng::new(92);
        // 2^-6, the documented pin; the merged rows get one extra bit
        // of slack (2^-5) because the softmax merge can cancel |f32|
        // while the per-direction errors it averages do not cancel.
        let tol_ok = |f: &[f32], b: &[f32], eps: f32| {
            f.iter().zip(b).all(|(&a, &o)| (a - o).abs() <= (a.abs() + 1.0) * eps)
        };
        let (n, c, h, w) = (1usize, 2usize, 7usize, 2 * SLAB + 3);
        let x = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        let lam = Tensor::randn(&[n, c, h, w], &mut rng, 1.0);
        for d in DIRECTIONS {
            let (hc, wc) = hw_src(h, w, d);
            let taps = mk_taps(&mut rng, n, 1, hc, wc);
            for (strategy, phase2) in [
                (ScanStrategy::PlanePar, Phase2::Barrier),
                (ScanStrategy::Segmented { s: 3 }, Phase2::WaveDir),
                (ScanStrategy::Chained { s: 3 }, Phase2::Barrier),
            ] {
                let full = fused_scan_dir_forced_ws(
                    &x,
                    &taps,
                    &lam,
                    d,
                    0,
                    strategy,
                    phase2,
                    &pool,
                    &ws,
                    Some(Precision::F32),
                );
                let half = fused_scan_dir_forced_ws(
                    &x,
                    &taps,
                    &lam,
                    d,
                    0,
                    strategy,
                    phase2,
                    &pool,
                    &ws,
                    Some(Precision::Bf16),
                );
                assert!(
                    tol_ok(&full.data, &half.data, 0.015_625),
                    "bf16 out of tolerance: {d:?} {strategy:?} {phase2:?}"
                );
                assert_ne!(
                    full.data, half.data,
                    "bf16 did not engage: {d:?} {strategy:?} {phase2:?}"
                );
                // An explicit F32 equals the default (None) bits.
                let default = fused_scan_dir_forced_ws(
                    &x, &taps, &lam, d, 0, strategy, phase2, &pool, &ws, None,
                );
                assert_eq!(full.data, default.data, "{d:?} {strategy:?} {phase2:?}");
            }
        }
        // The merged epilogue (softmax merge + modulation) on top of
        // bf16-staged scans, across the fan and chained engines.
        let t_lr = mk_taps(&mut rng, n, 1, h, w);
        let t_rl = mk_taps(&mut rng, n, 1, h, w);
        let t_tb = mk_taps(&mut rng, n, 1, w, h);
        let t_bt = mk_taps(&mut rng, n, 1, w, h);
        let mtaps = [&t_lr, &t_rl, &t_tb, &t_bt];
        let logits = [0.3f32, -0.7, 0.2, 1.0];
        for (strategy, phase2) in [
            (ScanStrategy::DirFan, Phase2::WaveDir),
            (ScanStrategy::Segmented { s: 2 }, Phase2::Barrier),
            (ScanStrategy::Chained { s: 2 }, Phase2::Barrier),
        ] {
            let full = fused_merged_4dir_forced_ws(
                &x,
                mtaps,
                &lam,
                &logits,
                0,
                strategy,
                phase2,
                &pool,
                &ws,
                Some(Precision::F32),
            );
            let half = fused_merged_4dir_forced_ws(
                &x,
                mtaps,
                &lam,
                &logits,
                0,
                strategy,
                phase2,
                &pool,
                &ws,
                Some(Precision::Bf16),
            );
            assert!(
                tol_ok(&full.data, &half.data, 0.031_25),
                "merged bf16 out of tolerance: {strategy:?} {phase2:?}"
            );
            assert_ne!(full.data, half.data, "merged bf16 did not engage: {strategy:?}");
        }
        assert_eq!(ws.stats().bytes_leased, 0);
    }
}
