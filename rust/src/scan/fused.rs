//! Compatibility facade over the fused scan engine.
//!
//! This file *was* the 4,246-line fused-engine monolith; the
//! implementation now lives in the [`super::engine`] module tree,
//! split along the carry algebra — `engine/pack.rs` (canonical
//! staging), `engine/chunk.rs` (chunk execution), `engine/carry.rs`
//! (carry resolution: the `CarrySource` contract, `ExternalCarry`
//! hand-offs, the chained engine), `engine/drain.rs` (the scatter
//! epilogue + segmented engines), and `engine/tiled.rs` (the streaming
//! row-band executor). See [`super::engine`]'s module docs for the map.
//!
//! Every historical `crate::scan::fused::*` entry-point path is
//! preserved here as a re-export, so callers (and muscle memory) keep
//! working.

pub use super::engine::{
    fused_merged_4dir, fused_merged_4dir_chained, fused_merged_4dir_fan, fused_merged_4dir_par,
    fused_merged_4dir_pool, fused_merged_4dir_seg, fused_merged_4dir_seg_wave,
    fused_merged_4dir_seg_wave_twopass, fused_merged_canonical, fused_merged_canonical_ws,
    fused_scan_dir, fused_scan_dir_chained, fused_scan_dir_pool, fused_scan_dir_pool_ws,
    fused_scan_dir_seg, fused_scan_dir_seg_wave, fused_scan_dir_seg_wave_twopass, fused_scan_l2r,
    fused_scan_l2r_chained, fused_scan_l2r_par, fused_scan_l2r_pool, fused_scan_l2r_pool_ws,
    fused_scan_l2r_pool_ws_into, fused_scan_l2r_seg, fused_scan_l2r_seg_wave,
    fused_scan_l2r_seg_wave_twopass, ExternalCarry,
};
pub(crate) use super::engine::{plane_blocks, SLAB};
