//! # GSPN-2: Efficient Parallel Sequence Modeling — Rust coordinator
//!
//! Three-layer reproduction of *GSPN-2* (Wang et al., 2025):
//!
//! * **L1** — fused Pallas line-scan kernels (`python/compile/kernels/`),
//!   AOT-lowered to HLO text.
//! * **L2** — the GSPN model family in JAX (`python/compile/model.py`),
//!   lowered once by `python/compile/aot.py` into `artifacts/`.
//! * **L3** — this crate: the serving coordinator (router + dynamic
//!   batcher + worker pool), the PJRT runtime that loads and executes the
//!   artifacts, the training driver, the pure-Rust GSPN reference
//!   (`scan`), the A100 execution simulator (`gpusim`) that regenerates
//!   every table and figure of the paper's evaluation, and the substrate
//!   utilities everything is built on.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `gspn2` binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod scan;
pub mod tensor;
pub mod train;
pub mod util;

pub use tensor::Tensor;
