//! The Table 2 / Figure S1 model zoo: baseline rows as reported in the
//! paper (these are *published numbers*, reproduced verbatim for the
//! comparison tables) plus the GSPN rows computed from `arch.rs`.

use super::arch::{gspn1_of, gspn2_base, gspn2_small, gspn2_tiny};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backbone {
    Cnn,
    Transformer,
    RasterScan,
    LineScan,
}

impl Backbone {
    pub fn tag(self) -> &'static str {
        match self {
            Backbone::Cnn => "CN",
            Backbone::Transformer => "TF",
            Backbone::RasterScan => "RS",
            Backbone::LineScan => "Line",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ZooRow {
    pub model: String,
    pub backbone: Backbone,
    pub params_m: f64,
    pub macs_g: f64,
    pub acc: f64,
    /// Throughput (img/s) where the paper reports it (Fig. S1); 0 = n/a.
    pub throughput: f64,
    /// True for rows computed by this repo rather than quoted.
    pub computed: bool,
}

fn quoted(model: &str, b: Backbone, p: f64, m: f64, acc: f64, thr: f64) -> ZooRow {
    ZooRow {
        model: model.into(),
        backbone: b,
        params_m: p,
        macs_g: m,
        acc,
        throughput: thr,
        computed: false,
    }
}

/// Tiny-scale comparison group (Table 2 left column).
pub fn tiny_group() -> Vec<ZooRow> {
    use Backbone::*;
    let mut rows = vec![
        quoted("ConvNeXT-T", Cnn, 29.0, 4.5, 82.1, 1189.0),
        quoted("MambaOut-Tiny", Cnn, 27.0, 4.5, 82.7, 0.0),
        quoted("DeiT-S", Transformer, 22.0, 4.6, 79.8, 1759.0),
        quoted("T2T-ViT-14", Transformer, 22.0, 4.8, 81.5, 0.0),
        quoted("Swin-T", Transformer, 29.0, 4.5, 81.3, 0.0),
        quoted("SwinV2-T", Transformer, 28.0, 4.4, 81.8, 0.0),
        quoted("CSWin-T", Transformer, 23.0, 4.3, 82.7, 0.0),
        quoted("CoAtNet-0", Transformer, 25.0, 4.2, 81.6, 0.0),
        quoted("Vim-S", RasterScan, 26.0, 5.1, 80.5, 0.0),
        quoted("VMamba-T", RasterScan, 22.0, 5.6, 82.2, 1686.0),
        quoted("Mamba-2D-S", RasterScan, 24.0, 0.0, 81.7, 0.0),
        quoted("LocalVMamba-T", RasterScan, 26.0, 5.7, 82.7, 394.0),
        quoted("VRWKV-S", RasterScan, 24.0, 4.6, 80.1, 0.0),
        quoted("ViL-S", RasterScan, 23.0, 5.1, 81.5, 0.0),
        quoted("MambaVision-T", RasterScan, 32.0, 4.4, 82.3, 0.0),
        quoted("GSPN-T", LineScan, 30.0, 5.3, 83.0, 0.0),
    ];
    rows.push(gspn2_row(
        "GSPN-2-T (Ours)",
        &gspn2_tiny(),
        83.0,
        1544.0,
    ));
    rows
}

/// Small-scale comparison group (Table 2 middle column).
pub fn small_group() -> Vec<ZooRow> {
    use Backbone::*;
    let mut rows = vec![
        quoted("ConvNeXT-S", Cnn, 50.0, 8.7, 83.1, 0.0),
        quoted("CNFormer-S36", Cnn, 40.0, 7.6, 84.1, 0.0),
        quoted("MogaNet-B", Cnn, 44.0, 9.9, 84.3, 0.0),
        quoted("InternImage-S", Cnn, 50.0, 8.0, 84.2, 0.0),
        quoted("MambaOut-Small", Cnn, 48.0, 9.0, 84.1, 0.0),
        quoted("T2T-ViT-19", Transformer, 39.0, 8.5, 81.9, 0.0),
        quoted("Focal-Small", Transformer, 51.0, 9.1, 83.5, 0.0),
        quoted("BiFormer-B", Transformer, 57.0, 9.8, 84.3, 0.0),
        quoted("NextViT-B", Transformer, 45.0, 8.3, 83.2, 0.0),
        quoted("Twins-B", Transformer, 56.0, 8.3, 83.1, 0.0),
        quoted("MaxViT-Small", Transformer, 69.0, 11.7, 84.4, 0.0),
        quoted("Swin-S", Transformer, 50.0, 8.7, 83.0, 0.0),
        quoted("SwinV2-S", Transformer, 50.0, 8.5, 83.8, 0.0),
        quoted("CoAtNet-1", Transformer, 42.0, 8.4, 83.3, 0.0),
        quoted("UniFormer-B", Transformer, 50.0, 8.3, 83.9, 0.0),
        quoted("VMamba-S", RasterScan, 44.0, 11.2, 83.5, 0.0),
        quoted("LocalVMamba-S", RasterScan, 50.0, 11.4, 83.7, 0.0),
        quoted("MambaVision-S", RasterScan, 50.0, 7.5, 83.3, 0.0),
        quoted("GSPN-S", LineScan, 50.0, 9.0, 83.8, 0.0),
    ];
    rows.push(gspn2_row("GSPN-2-S (Ours)", &gspn2_small(), 84.4, 0.0));
    rows
}

/// Base-scale comparison group (Table 2 right column).
pub fn base_group() -> Vec<ZooRow> {
    use Backbone::*;
    let mut rows = vec![
        quoted("ConvNeXT-B", Cnn, 89.0, 15.4, 83.8, 435.0),
        quoted("CNFormer-M36", Cnn, 57.0, 12.8, 84.5, 0.0),
        quoted("MambaOut-Base", Cnn, 85.0, 15.8, 84.2, 0.0),
        quoted("SLaK-B", Cnn, 95.0, 17.1, 84.0, 0.0),
        quoted("DeiT-B", Transformer, 86.0, 17.5, 81.8, 0.0),
        quoted("T2T-ViT-24", Transformer, 64.0, 13.8, 82.3, 0.0),
        quoted("Swin-B", Transformer, 88.0, 15.4, 83.5, 458.0),
        quoted("SwinV2-B", Transformer, 88.0, 15.1, 84.6, 0.0),
        quoted("CSwin-B", Transformer, 78.0, 15.0, 84.2, 0.0),
        quoted("MViTv2-B", Transformer, 52.0, 10.2, 84.4, 0.0),
        quoted("CoAtNet-2", Transformer, 75.0, 15.7, 84.1, 0.0),
        quoted("Vim-B", RasterScan, 98.0, 17.5, 81.9, 0.0),
        quoted("VMamba-B", RasterScan, 89.0, 15.4, 83.9, 0.0),
        quoted("Mamba-2D-B", RasterScan, 92.0, 0.0, 83.0, 0.0),
        quoted("VRWKV-B", RasterScan, 94.0, 18.2, 82.0, 0.0),
        quoted("ViL-B", RasterScan, 89.0, 18.6, 82.4, 0.0),
        quoted("MambaVision-B", RasterScan, 98.0, 15.0, 84.2, 0.0),
        quoted("GSPN-B", LineScan, 89.0, 15.9, 84.3, 0.0),
    ];
    rows.push(gspn2_row("GSPN-2-B (Ours)", &gspn2_base(), 84.9, 0.0));
    rows
}

fn gspn2_row(name: &str, arch: &super::arch::GspnArch, acc: f64, thr: f64) -> ZooRow {
    ZooRow {
        model: name.into(),
        backbone: Backbone::LineScan,
        params_m: arch.params_m(224),
        macs_g: arch.macs_g(224),
        acc,
        throughput: thr,
        computed: true,
    }
}

/// Paper-reported target columns for the GSPN rows (for the
/// computed-vs-paper check in EXPERIMENTS.md).
pub fn paper_targets() -> Vec<(&'static str, f64, f64, f64)> {
    vec![
        // (model, params_m, macs_g, acc)
        ("GSPN-2-T (Ours)", 24.0, 4.2, 83.0),
        ("GSPN-2-S (Ours)", 50.0, 9.2, 84.4),
        ("GSPN-2-B (Ours)", 89.0, 14.2, 84.9),
    ]
}

/// GSPN-1 architecture analogs (per-channel weights) for ratio checks.
pub fn gspn1_rows() -> Vec<ZooRow> {
    let rows = [
        (gspn1_of(&gspn2_tiny(), "GSPN-T (computed)", 8), 83.0),
        (gspn1_of(&gspn2_small(), "GSPN-S (computed)", 8), 83.8),
        (gspn1_of(&gspn2_base(), "GSPN-B (computed)", 8), 84.3),
    ];
    rows.iter()
        .map(|(a, acc)| ZooRow {
            model: a.name.clone(),
            backbone: Backbone::LineScan,
            params_m: a.params_m(224),
            macs_g: a.macs_g(224),
            acc: *acc,
            throughput: 0.0,
            computed: true,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_nonempty_and_ours_last() {
        for g in [tiny_group(), small_group(), base_group()] {
            assert!(g.len() > 10);
            assert!(g.last().unwrap().model.contains("Ours"));
            assert!(g.last().unwrap().computed);
        }
    }

    #[test]
    fn computed_rows_close_to_paper_targets() {
        let groups = [tiny_group(), small_group(), base_group()];
        for (name, p, m, _acc) in paper_targets() {
            let row = groups
                .iter()
                .flatten()
                .find(|r| r.model == name)
                .unwrap_or_else(|| panic!("missing {name}"));
            let p_err = (row.params_m - p).abs() / p;
            let m_err = (row.macs_g - m).abs() / m;
            assert!(p_err < 0.15, "{name}: params {} vs paper {p}", row.params_m);
            assert!(m_err < 0.25, "{name}: macs {} vs paper {m}", row.macs_g);
        }
    }

    #[test]
    fn gspn2_beats_gspn1_on_efficiency() {
        // Table 2 claim: GSPN-2-T has fewer params and MACs than GSPN-T.
        let g2 = tiny_group().last().unwrap().clone();
        let g1 = gspn1_rows()[0].clone();
        assert!(g2.params_m < g1.params_m);
        assert!(g2.macs_g < g1.macs_g);
    }

    #[test]
    fn ours_accuracy_at_least_competitive() {
        for g in [tiny_group(), small_group(), base_group()] {
            let ours = g.last().unwrap().acc;
            let best_other = g[..g.len() - 1]
                .iter()
                .map(|r| r.acc)
                .fold(0.0f64, f64::max);
            assert!(ours >= best_other - 0.5, "ours {ours} vs best {best_other}");
        }
    }
}
