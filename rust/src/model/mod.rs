//! Model-level accounting and the Table-2 comparison zoo.
//!
//! `arch` computes exact Param/MAC costs of the GSPN macro-architecture
//! (the numbers the Python L2 model realises at small scale); `zoo` holds
//! the published baseline rows the paper compares against and the
//! computed GSPN-2 rows.

pub mod arch;
pub mod zoo;

pub use arch::{gspn1_of, gspn2_base, gspn2_small, gspn2_tiny, Cost, GspnArch, PropMode};
pub use zoo::{base_group, gspn1_rows, paper_targets, small_group, tiny_group, Backbone, ZooRow};
