//! Architecture description + exact parameter / MAC accounting for the
//! GSPN model family (the Param(M) and MAC(G) columns of Table 2).
//!
//! The accounting walks the same macro-architecture as
//! `python/compile/model.py` (stem -> stages of [LPU + GSPN + FFN] blocks
//! with strided downsampling -> head) and counts every weight and every
//! multiply. GSPN-1 vs GSPN-2 differ exactly where the paper says they
//! do: per-channel vs channel-shared propagation weights, and the
//! compressive proxy dimension C_proxy (§4.2).

/// Propagation flavour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PropMode {
    /// GSPN-1: per-channel propagation matrices (Cw = C_proxy).
    PerChannel,
    /// GSPN-2: channel-shared w_i (Cw = 1), §4.2.
    Shared,
}

#[derive(Clone, Debug)]
pub struct GspnArch {
    pub name: String,
    pub in_ch: usize,
    pub num_classes: usize,
    pub dims: Vec<usize>,
    pub depths: Vec<usize>,
    pub patch: usize,
    pub c_proxy: usize,
    pub ffn_ratio: usize,
    pub mode: PropMode,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub params: u64,
    pub macs: u64,
}

impl std::ops::AddAssign for Cost {
    fn add_assign(&mut self, o: Cost) {
        self.params += o.params;
        self.macs += o.macs;
    }
}

fn conv(cin: u64, cout: u64, k: u64, out_hw: u64, groups: u64) -> Cost {
    let params = cout * (cin / groups) * k * k + cout;
    Cost { params, macs: (params - cout) * out_hw }
}

fn linear(din: u64, dout: u64, n: u64) -> Cost {
    Cost { params: din * dout + dout, macs: din * dout * n }
}

impl GspnArch {
    /// Proxy-channel count seen by the scan (the weight-channel count Cw).
    pub fn cw(&self) -> usize {
        match self.mode {
            PropMode::PerChannel => self.c_proxy,
            PropMode::Shared => 1,
        }
    }

    /// Cost of one GSPN unit at channel width `c` and feature map `hw`.
    pub fn gspn_unit_cost(&self, c: u64, hw: u64) -> Cost {
        let p = self.c_proxy as u64;
        let cw = self.cw() as u64;
        let mut cost = Cost::default();
        cost += conv(c, p, 1, hw, 1); // down-projection
        for _ in 0..4 {
            cost += conv(p, 3 * cw, 1, hw, 1); // taps
            cost += conv(p, p, 1, hw, 1); // lambda
        }
        // Scan MACs: per pixel per proxy channel per direction, 4 multiplies
        // (3 tap x h_prev + 1 lam x x); the channel-shared case still runs
        // the recurrence per channel (weights shared, data per-channel).
        cost.macs += 4 * 4 * p * hw;
        // Output modulation u (per proxy channel) + merge logits.
        cost.params += p + 4;
        cost.macs += p * hw + 4 * p * hw;
        cost += conv(p, c, 1, hw, 1); // up-projection
        cost
    }

    /// Cost of one full block (LPU + norms + GSPN + FFN) at width c.
    pub fn block_cost(&self, c: u64, hw: u64) -> Cost {
        let mut cost = Cost::default();
        cost += conv(c, c, 3, hw, c); // LPU depthwise 3x3
        cost.params += c; // norm1
        cost += self.gspn_unit_cost(c, hw);
        cost.params += c; // norm2
        let hid = c * self.ffn_ratio as u64;
        cost += conv(c, hid, 1, hw, 1);
        cost += conv(hid, c, 1, hw, 1);
        cost
    }

    /// Full-network cost at `img` x `img` input resolution.
    pub fn cost(&self, img: usize) -> Cost {
        let mut cost = Cost::default();
        let mut res = img / self.patch;
        cost += conv(
            self.in_ch as u64,
            self.dims[0] as u64,
            self.patch as u64,
            (res * res) as u64,
            1,
        );
        for (si, (&dim, &depth)) in self.dims.iter().zip(&self.depths).enumerate() {
            if si > 0 {
                res /= 2;
                cost += conv(
                    self.dims[si - 1] as u64,
                    dim as u64,
                    2,
                    (res * res) as u64,
                    1,
                );
            }
            let hw = (res * res) as u64;
            for _ in 0..depth {
                cost += self.block_cost(dim as u64, hw);
            }
        }
        let last = *self.dims.last().unwrap() as u64;
        cost.params += last; // final norm
        cost += linear(last, self.num_classes as u64, 1);
        cost
    }

    pub fn params_m(&self, img: usize) -> f64 {
        self.cost(img).params as f64 / 1e6
    }

    pub fn macs_g(&self, img: usize) -> f64 {
        self.cost(img).macs as f64 / 1e9
    }
}

/// The three GSPN-2 scales of Table 2 (dims/depths chosen so the computed
/// Param(M)/MAC(G) columns land on the paper's reported 24M/4.2G, 50M/9.2G,
/// 89M/14.2G — see EXPERIMENTS.md §Table 2 for computed-vs-paper).
pub fn gspn2_tiny() -> GspnArch {
    GspnArch {
        name: "GSPN-2-T".into(),
        in_ch: 3,
        num_classes: 1000,
        dims: vec![72, 144, 324, 504],
        depths: vec![4, 4, 16, 4],
        patch: 4,
        c_proxy: 2,
        ffn_ratio: 4,
        mode: PropMode::Shared,
    }
}

pub fn gspn2_small() -> GspnArch {
    GspnArch {
        name: "GSPN-2-S".into(),
        in_ch: 3,
        num_classes: 1000,
        dims: vec![88, 176, 440, 704],
        depths: vec![4, 5, 22, 3],
        patch: 4,
        c_proxy: 2,
        ffn_ratio: 4,
        mode: PropMode::Shared,
    }
}

pub fn gspn2_base() -> GspnArch {
    GspnArch {
        name: "GSPN-2-B".into(),
        in_ch: 3,
        num_classes: 1000,
        dims: vec![128, 256, 512, 896],
        depths: vec![4, 4, 21, 6],
        patch: 4,
        c_proxy: 2,
        ffn_ratio: 4,
        mode: PropMode::Shared,
    }
}

/// GSPN-1 counterparts: per-channel weights, wider proxy (no compression),
/// matching the paper's 30M/5.3G, 50M/9.0G, 89M/15.9G rows.
pub fn gspn1_of(arch: &GspnArch, name: &str, c_proxy: usize) -> GspnArch {
    GspnArch {
        name: name.into(),
        c_proxy,
        mode: PropMode::PerChannel,
        ..arch.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_accounting() {
        // 3->8 conv 4x4 on 8x8 output: params 3*8*16+8 = 392, macs 384*64.
        let c = conv(3, 8, 4, 64, 1);
        assert_eq!(c.params, 392);
        assert_eq!(c.macs, 384 * 64);
    }

    #[test]
    fn shared_mode_cheaper_than_per_channel() {
        let t2 = gspn2_tiny();
        let t1 = gspn1_of(&t2, "GSPN-T-like", 8);
        let c2 = t2.cost(224);
        let c1 = t1.cost(224);
        assert!(c1.params > c2.params, "{} <= {}", c1.params, c2.params);
        assert!(c1.macs > c2.macs);
    }

    #[test]
    fn proxy_dim_monotone_in_cost() {
        let mut prev = 0u64;
        for p in [2usize, 4, 8, 16, 32] {
            let arch = GspnArch { c_proxy: p, ..gspn2_tiny() };
            let c = arch.cost(224);
            assert!(c.params > prev);
            prev = c.params;
        }
    }

    #[test]
    fn scale_ordering() {
        let t = gspn2_tiny().cost(224);
        let s = gspn2_small().cost(224);
        let b = gspn2_base().cost(224);
        assert!(t.params < s.params && s.params < b.params);
        assert!(t.macs < s.macs && s.macs < b.macs);
    }

    #[test]
    fn macs_scale_quadratically_with_resolution() {
        let arch = gspn2_tiny();
        let a = arch.cost(224).macs as f64;
        let b = arch.cost(448).macs as f64;
        let ratio = b / a;
        assert!((ratio - 4.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn params_resolution_independent() {
        let arch = gspn2_tiny();
        assert_eq!(arch.cost(224).params, arch.cost(448).params);
    }
}
