//! NCHW f32 tensor micro-library.
//!
//! The Rust reference implementation of GSPN (`crate::scan`), the
//! synthetic-data generators and the runtime's literal bridge all operate
//! on these tensors. Deliberately small: contiguous `Vec<f32>` storage,
//! row-major (last axis fastest), the few ops the CPU paths need —
//! indexing, flips, transposes of the trailing two axes, elementwise maps,
//! reductions, slicing along the last axis.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    // ------------------------------------------------------------------
    // Construction
    // ------------------------------------------------------------------

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], rng: &mut crate::util::Rng, std: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, std) }
    }

    // ------------------------------------------------------------------
    // Introspection
    // ------------------------------------------------------------------

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        let mut stride = 1;
        for d in (0..idx.len()).rev() {
            debug_assert!(idx[d] < self.shape[d], "index {idx:?} out of {:?}", self.shape);
            off += idx[d] * stride;
            stride *= self.shape[d];
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.offset(idx);
        &mut self.data[o]
    }

    // ------------------------------------------------------------------
    // Shape ops
    // ------------------------------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Flip along the last axis (used for r2l / b2t scans).
    pub fn flip_last(&self) -> Tensor {
        let w = *self.shape.last().expect("flip_last on rank-0");
        let rows = self.data.len() / w;
        let mut out = vec![0.0f32; self.data.len()];
        for r in 0..rows {
            let src = &self.data[r * w..(r + 1) * w];
            let dst = &mut out[r * w..(r + 1) * w];
            for i in 0..w {
                dst[i] = src[w - 1 - i];
            }
        }
        Tensor { shape: self.shape.clone(), data: out }
    }

    /// Swap the trailing two axes (used for t2b / b2t scans).
    pub fn swap_last2(&self) -> Tensor {
        let n = self.shape.len();
        assert!(n >= 2, "swap_last2 needs rank >= 2");
        let h = self.shape[n - 2];
        let w = self.shape[n - 1];
        let outer = self.data.len() / (h * w);
        let mut shape = self.shape.clone();
        shape.swap(n - 2, n - 1);
        let mut out = vec![0.0f32; self.data.len()];
        for o in 0..outer {
            let src = &self.data[o * h * w..(o + 1) * h * w];
            let dst = &mut out[o * h * w..(o + 1) * h * w];
            for r in 0..h {
                for c in 0..w {
                    dst[c * h + r] = src[r * w + c];
                }
            }
        }
        Tensor { shape, data: out }
    }

    /// Column i (last axis) as a contiguous (prefix) vector.
    pub fn take_last(&self, i: usize) -> Vec<f32> {
        let w = *self.shape.last().unwrap();
        assert!(i < w);
        let rows = self.data.len() / w;
        (0..rows).map(|r| self.data[r * w + i]).collect()
    }

    // ------------------------------------------------------------------
    // Elementwise + reductions
    // ------------------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, k: f32) -> Tensor {
        self.map(|x| x * k)
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(&a, &b)| {
            (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
        })
    }

    // ------------------------------------------------------------------
    // Raw bytes (little-endian f32) for the params.bin / literal bridge
    // ------------------------------------------------------------------

    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for &x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    pub fn from_le_bytes(shape: &[usize], bytes: &[u8]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(bytes.len(), n * 4, "byte length mismatch for {shape:?}");
        let data = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Tensor { shape: shape.to_vec(), data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, ensure, ensure_all_close};
    use crate::util::Rng;

    #[test]
    fn zeros_and_full() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.data.iter().all(|&x| x == 0.0));
        let f = Tensor::full(&[2, 2], 3.5);
        assert_eq!(f.sum(), 14.0);
    }

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn strides_match_offsets() {
        let t = Tensor::zeros(&[2, 3, 4, 5]);
        let s = t.strides();
        assert_eq!(s, vec![60, 20, 5, 1]);
        assert_eq!(t.offset(&[1, 2, 3, 4]), 60 + 40 + 15 + 4);
    }

    #[test]
    fn flip_last_involution() {
        check("flip_last is an involution", |g| {
            let h = g.int_in(1, 6);
            let w = g.int_in(1, 8);
            let t = Tensor::from_vec(&[h, w], g.normal_vec(h * w));
            let back = t.flip_last().flip_last();
            ensure_all_close(&t.data, &back.data, 0.0, "flip twice")
        });
    }

    #[test]
    fn swap_last2_transposes() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let s = t.swap_last2();
        assert_eq!(s.shape, vec![3, 2]);
        assert_eq!(s.at(&[0, 0]), 1.0);
        assert_eq!(s.at(&[0, 1]), 4.0);
        assert_eq!(s.at(&[2, 1]), 6.0);
    }

    #[test]
    fn swap_last2_involution_with_batch() {
        check("swap_last2 involution", |g| {
            let n = g.int_in(1, 3);
            let h = g.int_in(1, 5);
            let w = g.int_in(1, 5);
            let t = Tensor::from_vec(&[n, h, w], g.normal_vec(n * h * w));
            let back = t.swap_last2().swap_last2();
            ensure(back.shape == t.shape, "shape restored")?;
            ensure_all_close(&t.data, &back.data, 0.0, "data restored")
        });
    }

    #[test]
    fn take_last_column() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.take_last(1), vec![2.0, 5.0]);
    }

    #[test]
    fn elementwise_and_reductions() {
        let a = Tensor::from_vec(&[3], vec![1., -2., 3.]);
        let b = Tensor::from_vec(&[3], vec![10., 20., 30.]);
        assert_eq!(a.add(&b).data, vec![11., 18., 33.]);
        assert_eq!(a.mul(&b).data, vec![10., -40., 90.]);
        assert_eq!(a.abs_max(), 3.0);
        assert!((a.mean() - (2.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn byte_roundtrip() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(&[3, 4, 5], &mut rng, 2.0);
        let back = Tensor::from_le_bytes(&t.shape, &t.to_le_bytes());
        assert_eq!(t, back);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(&[2], vec![1.0, 100.0]);
        let b = Tensor::from_vec(&[2], vec![1.0001, 100.001]);
        assert!(a.allclose(&b, 1e-3, 1e-4));
        assert!(!a.allclose(&b, 1e-6, 1e-7));
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_length() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}

/// Concatenate tensors along axis 0 (batch assembly in the coordinator).
pub fn concat_axis0(parts: &[&Tensor]) -> Tensor {
    assert!(!parts.is_empty(), "concat_axis0 of nothing");
    let tail = &parts[0].shape[1..];
    let mut n0 = 0;
    let mut data = Vec::with_capacity(parts.iter().map(|t| t.len()).sum());
    for p in parts {
        assert_eq!(&p.shape[1..], tail, "concat_axis0 trailing-shape mismatch");
        n0 += p.shape[0];
        data.extend_from_slice(&p.data);
    }
    let mut shape = vec![n0];
    shape.extend_from_slice(tail);
    Tensor { shape, data }
}

/// Split a tensor along axis 0 into chunks of the given sizes.
pub fn split_axis0(t: &Tensor, sizes: &[usize]) -> Vec<Tensor> {
    assert_eq!(sizes.iter().sum::<usize>(), t.shape[0], "split sizes mismatch");
    let per = t.shape[1..].iter().product::<usize>();
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for &s in sizes {
        let mut shape = vec![s];
        shape.extend_from_slice(&t.shape[1..]);
        out.push(Tensor::from_vec(&shape, t.data[off..off + s * per].to_vec()));
        off += s * per;
    }
    out
}

#[cfg(test)]
mod concat_tests {
    use super::*;

    #[test]
    fn concat_then_split_roundtrip() {
        let a = Tensor::from_vec(&[1, 2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2, 2], (5..13).map(|x| x as f32).collect());
        let cat = concat_axis0(&[&a, &b]);
        assert_eq!(cat.shape, vec![3, 2, 2]);
        let parts = split_axis0(&cat, &[1, 2]);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    #[should_panic]
    fn concat_rejects_mismatched_tails() {
        let a = Tensor::zeros(&[1, 2]);
        let b = Tensor::zeros(&[1, 3]);
        concat_axis0(&[&a, &b]);
    }

    #[test]
    #[should_panic]
    fn split_rejects_bad_sizes() {
        let t = Tensor::zeros(&[3, 2]);
        split_axis0(&t, &[1, 1]);
    }
}
