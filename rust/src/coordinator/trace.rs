//! Synthetic client: open-loop Poisson arrivals over the scan buckets.
//!
//! Used by `gspn2 serve`, the serving example, and the coordinator
//! benches to drive the system at a configurable offered load, the way a
//! load generator would in a real deployment.
//!
//! Two arrival processes share one deterministic generator: the plain
//! open-loop Poisson trace ([`TraceConfig::burst`] = `None`, unchanged
//! byte-for-byte from before the bursty mode existed), and a two-state
//! Markov-modulated Poisson process — exponential gap/burst dwell times,
//! with the arrival rate multiplied by [`BurstConfig::mult`] inside a
//! burst. That is the standard bursty-traffic model for serving
//! benchmarks: same seed, same trace, but tail latencies now see queue
//! buildup instead of a smooth offered load.

use std::time::Duration;

use super::request::Priority;
use crate::util::Rng;
use crate::Tensor;

#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Time offset from trace start.
    pub at: Duration,
    pub x: Tensor,
    pub a_raw: Tensor,
    pub lam: Tensor,
    /// Priority class for SLO-aware serving (always `Normal` unless
    /// [`TraceConfig::classes`] is set).
    pub priority: Priority,
    /// Tenant id for quota accounting (0 unless classes are sampled).
    pub tenant: u64,
}

/// Burst modulation on top of the base arrival rate: a two-state
/// (gap/burst) Markov process with exponential dwell times.
#[derive(Clone, Copy, Debug)]
pub struct BurstConfig {
    /// Arrival-rate multiplier while inside a burst.
    pub mult: f64,
    /// Mean burst dwell time, seconds.
    pub mean_burst_s: f64,
    /// Mean gap (base-rate) dwell time, seconds.
    pub mean_gap_s: f64,
}

impl Default for BurstConfig {
    fn default() -> Self {
        Self { mult: 8.0, mean_burst_s: 0.05, mean_gap_s: 0.2 }
    }
}

/// Priority/tenant mix for SLO-aware traces: each event draws a class
/// (`high` / `low` fractions, remainder normal) and a tenant id
/// uniform in `0..tenants`.
#[derive(Clone, Copy, Debug)]
pub struct ClassMix {
    /// Fraction of high-priority arrivals.
    pub high: f64,
    /// Fraction of low-priority (sheddable) arrivals.
    pub low: f64,
    /// Number of distinct tenant ids to sample from.
    pub tenants: u64,
}

impl Default for ClassMix {
    fn default() -> Self {
        Self { high: 0.25, low: 0.5, tenants: 4 }
    }
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub rate_rps: f64,
    pub requests: usize,
    /// Geometry (c, h, w) choices with weights.
    pub shapes: Vec<((usize, usize, usize), f64)>,
    pub seed: u64,
    /// `Some` switches arrivals to the bursty (modulated) process.
    pub burst: Option<BurstConfig>,
    /// `Some` samples a priority class and tenant per event (from an
    /// independent RNG stream, so arrivals and tensors stay
    /// byte-identical to the classless trace at the same seed).
    pub classes: Option<ClassMix>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            rate_rps: 200.0,
            requests: 500,
            shapes: vec![((8, 64, 64), 0.8), ((8, 128, 128), 0.2)],
            seed: 0,
            burst: None,
            classes: None,
        }
    }
}

/// Generate a deterministic arrival trace (Poisson, or Markov-modulated
/// Poisson when [`TraceConfig::burst`] is set). With `burst = None` the
/// output is identical to the pre-burst generator for the same seed.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed ^ 0x7ace);
    // Class/tenant draws come from their own stream (seeded off the
    // trace seed, never forked from — and never advancing — the main
    // stream), so enabling `classes` leaves arrival times and tensor
    // contents byte-identical to the legacy trace.
    let mut class_rng = Rng::new(cfg.seed ^ 0xc1a5_5e5);
    let weights: Vec<f64> = cfg.shapes.iter().map(|(_, w)| *w).collect();
    let mut t = 0.0f64;
    // Burst state machine: trace starts in a gap; `boundary` is the next
    // state flip (infinitely far for the plain Poisson trace, which also
    // keeps its RNG stream untouched).
    let mut in_burst = false;
    let mut boundary = match cfg.burst {
        Some(b) => rng.exponential(1.0 / b.mean_gap_s),
        None => f64::INFINITY,
    };
    let mut out = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        loop {
            let rate = match (in_burst, cfg.burst) {
                (true, Some(b)) => cfg.rate_rps * b.mult,
                _ => cfg.rate_rps,
            };
            let dt = rng.exponential(rate);
            if t + dt <= boundary {
                t += dt;
                break;
            }
            // The candidate arrival crosses the state flip: jump to the
            // boundary and redraw under the new rate. Exact, not an
            // approximation — the exponential is memoryless, so the
            // residual wait past the boundary is a fresh draw.
            let b = cfg.burst.expect("finite boundary implies burst config");
            t = boundary;
            in_burst = !in_burst;
            let mean_dwell = if in_burst { b.mean_burst_s } else { b.mean_gap_s };
            boundary = t + rng.exponential(1.0 / mean_dwell);
        }
        let (c, h, w) = cfg.shapes[rng.weighted(&weights)].0;
        let (priority, tenant) = match cfg.classes {
            None => (Priority::Normal, 0),
            Some(mix) => {
                let u = class_rng.uniform();
                let p = if u < mix.high {
                    Priority::High
                } else if u < mix.high + mix.low {
                    Priority::Low
                } else {
                    Priority::Normal
                };
                (p, class_rng.below(mix.tenants.max(1)))
            }
        };
        out.push(TraceEvent {
            at: Duration::from_secs_f64(t),
            x: Tensor::randn(&[1, c, h, w], &mut rng, 1.0),
            a_raw: Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0),
            lam: Tensor::randn(&[1, c, h, w], &mut rng, 1.0),
            priority,
            tenant,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let cfg = TraceConfig { requests: 20, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.x, y.x);
        }
    }

    #[test]
    fn arrivals_are_increasing_and_rate_roughly_matches() {
        let cfg = TraceConfig { rate_rps: 1000.0, requests: 2000, ..Default::default() };
        let tr = generate(&cfg);
        for w in tr.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = tr.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate / 1000.0 - 1.0).abs() < 0.15, "rate {rate}");
    }

    #[test]
    fn bursty_mode_is_deterministic_and_clusters_arrivals() {
        let steady = TraceConfig { rate_rps: 200.0, requests: 2000, ..Default::default() };
        let bursty =
            TraceConfig { burst: Some(BurstConfig::default()), ..steady.clone() };
        let a = generate(&bursty);
        let b = generate(&bursty);
        assert_eq!(a.len(), 2000);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.x, y.x);
        }
        for w in a.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        // Bursts raise the average offered rate (0.2 of the time at 8x
        // here, ~2.4x overall), so the same request count finishes in
        // well under the steady trace's span...
        let s = generate(&steady);
        let dur_a = a.last().unwrap().at.as_secs_f64();
        let dur_s = s.last().unwrap().at.as_secs_f64();
        assert!(dur_a < dur_s * 0.75, "bursty {dur_a:.2}s vs steady {dur_s:.2}s");
        // ...and concentrate arrivals: far more tight inter-arrival gaps
        // than the open-loop trace at the same base rate.
        let tight = |tr: &[TraceEvent]| {
            tr.windows(2)
                .filter(|w| (w[1].at - w[0].at).as_secs_f64() < 1.0 / (4.0 * 200.0))
                .count()
        };
        assert!(tight(&a) > 2 * tight(&s), "{} vs {}", tight(&a), tight(&s));
    }

    /// Class sampling must be a pure overlay: the same seed yields
    /// byte-identical arrivals and tensors with classes on or off (the
    /// class stream is independent, so the legacy trace is unchanged),
    /// the mix fractions are roughly honoured, and tenants stay in
    /// range.
    #[test]
    fn class_sampling_leaves_legacy_stream_untouched() {
        let plain = TraceConfig { requests: 400, ..Default::default() };
        let classed = TraceConfig {
            classes: Some(ClassMix { high: 0.25, low: 0.5, tenants: 4 }),
            ..plain.clone()
        };
        let a = generate(&plain);
        let b = generate(&classed);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.x, y.x);
            assert_eq!(x.a_raw, y.a_raw);
            assert_eq!(x.lam, y.lam);
        }
        assert!(a.iter().all(|e| e.priority == Priority::Normal && e.tenant == 0));
        let count = |p: Priority| b.iter().filter(|e| e.priority == p).count();
        let (hi, lo) = (count(Priority::High), count(Priority::Low));
        assert!((60..140).contains(&hi), "high fraction {hi}/400");
        assert!((140..260).contains(&lo), "low fraction {lo}/400");
        assert!(b.iter().all(|e| e.tenant < 4));
        assert!((0..4).all(|t| b.iter().any(|e| e.tenant == t)));
    }

    #[test]
    fn shapes_follow_weights() {
        let cfg = TraceConfig { requests: 1000, ..Default::default() };
        let tr = generate(&cfg);
        let big = tr.iter().filter(|e| e.x.shape[2] == 128).count();
        assert!((100..350).contains(&big), "128^2 fraction {big}/1000");
    }
}
