//! Synthetic client: open-loop Poisson arrivals over the scan buckets.
//!
//! Used by `gspn2 serve`, the serving example, and the coordinator
//! benches to drive the system at a configurable offered load, the way a
//! load generator would in a real deployment.

use std::time::Duration;

use crate::util::Rng;
use crate::Tensor;

#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Time offset from trace start.
    pub at: Duration,
    pub x: Tensor,
    pub a_raw: Tensor,
    pub lam: Tensor,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub rate_rps: f64,
    pub requests: usize,
    /// Geometry (c, h, w) choices with weights.
    pub shapes: Vec<((usize, usize, usize), f64)>,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            rate_rps: 200.0,
            requests: 500,
            shapes: vec![((8, 64, 64), 0.8), ((8, 128, 128), 0.2)],
            seed: 0,
        }
    }
}

/// Generate a deterministic Poisson-arrival trace.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceEvent> {
    let mut rng = Rng::new(cfg.seed ^ 0x7ace);
    let weights: Vec<f64> = cfg.shapes.iter().map(|(_, w)| *w).collect();
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        t += rng.exponential(cfg.rate_rps);
        let (c, h, w) = cfg.shapes[rng.weighted(&weights)].0;
        out.push(TraceEvent {
            at: Duration::from_secs_f64(t),
            x: Tensor::randn(&[1, c, h, w], &mut rng, 1.0),
            a_raw: Tensor::randn(&[1, 1, 3, h, w], &mut rng, 1.0),
            lam: Tensor::randn(&[1, c, h, w], &mut rng, 1.0),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic() {
        let cfg = TraceConfig { requests: 20, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.x, y.x);
        }
    }

    #[test]
    fn arrivals_are_increasing_and_rate_roughly_matches() {
        let cfg = TraceConfig { rate_rps: 1000.0, requests: 2000, ..Default::default() };
        let tr = generate(&cfg);
        for w in tr.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = tr.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate / 1000.0 - 1.0).abs() < 0.15, "rate {rate}");
    }

    #[test]
    fn shapes_follow_weights() {
        let cfg = TraceConfig { requests: 1000, ..Default::default() };
        let tr = generate(&cfg);
        let big = tr.iter().filter(|e| e.x.shape[2] == 128).count();
        assert!((100..350).contains(&big), "128^2 fraction {big}/1000");
    }
}
