//! Serving metrics: latency histograms per stage (aggregate, per
//! priority class, and per shape bucket), throughput, queue/batching
//! statistics, split rejection counters (backpressure / shed / expired
//! / quota / invalid), and a rolling SLO error budget — the fraction of
//! recently completed requests whose total latency violated the
//! configured p99 SLO. Shared across workers behind a mutex; snapshots
//! are cheap copies for reporting.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use super::request::{Bucket, Priority};
use crate::util::stats::{fmt_time_ns, LatencyHistogram, Summary};
use crate::util::PoolStats;

/// Completed-request window the error budget is computed over.
const SLO_WINDOW: usize = 512;
/// Per-bucket histogram cap: beyond this many distinct buckets, new
/// geometries fold into the aggregate only (bounds snapshot cost under
/// the dynamic-registration churn the batcher allows).
const MAX_BUCKET_HISTS: usize = 128;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub queue_wait: LatencyHistogram,
    pub execute: LatencyHistogram,
    pub total: LatencyHistogram,
    pub batch_sizes: Summary,
    pub completed: u64,
    /// Aggregate admission rejections (back-compat): the sum of the
    /// split counters below.
    pub rejected: u64,
    pub errors: u64,
    pub padded_slots: u64,
    /// Split rejection counters — why traffic was refused.
    pub rej_backpressure: u64,
    pub rej_shed: u64,
    pub rej_expired: u64,
    pub rej_quota: u64,
    pub rej_invalid: u64,
    /// Requests whose workspace footprint exceeded `serve.max_request_mb`
    /// while tiling was disabled, answered with a structured
    /// `RequestError::TooLarge` reply.
    pub rej_too_large: u64,
    /// Requests answered with a structured `Closed` reply at shutdown
    /// (not an admission rejection: they were admitted, then drained).
    pub closed: u64,
    /// Per-priority-class total-latency histograms and outcome counters
    /// (indexed by [`Priority::index`]).
    pub class_total: [LatencyHistogram; 3],
    pub class_completed: [u64; 3],
    pub class_shed: [u64; 3],
    pub class_expired: [u64; 3],
    /// Per-shape-bucket total-latency histograms (capped).
    pub bucket_total: BTreeMap<Bucket, LatencyHistogram>,
    /// Workspace pool counters, snapshotted once per served batch (the
    /// pool's counters are cumulative, so the latest snapshot is the
    /// current truth; `ws_peak_leased` keeps its own high-water mark so
    /// a late snapshot cannot lower it).
    pub ws_hits: u64,
    pub ws_misses: u64,
    pub ws_bytes_pooled: u64,
    pub ws_peak_leased: u64,
    /// Per-request peak-workspace accounting: the distribution of each
    /// served request's peak bytes on lease (from the pool's rebased
    /// high-water windows — see `BufferPool::rebase_peak`). Under
    /// tiling this is what stays bounded by one band while the
    /// geometry itself is over-cap; its max also feeds
    /// `ws_peak_leased` so the lifetime high-water mark survives the
    /// per-request rebasing.
    pub ws_req_peak: Summary,
    /// p99 SLO threshold the error budget is measured against (0 = no
    /// SLO configured, budget always 0).
    slo_ns: u64,
    /// Ring of the last [`SLO_WINDOW`] completions: did each violate
    /// the SLO?
    slo_window: VecDeque<bool>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Metrics with an SLO threshold for the rolling error budget.
    pub fn with_slo(slo_ns: u64) -> Metrics {
        Metrics { slo_ns, ..Metrics::default() }
    }

    pub fn record_request(
        &mut self,
        class: Priority,
        bucket: Option<&Bucket>,
        queue_ns: u64,
        execute_ns: u64,
        total_ns: u64,
        batch: usize,
    ) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        self.finished = Some(Instant::now());
        self.queue_wait.record_ns(queue_ns);
        self.execute.record_ns(execute_ns);
        self.total.record_ns(total_ns);
        self.batch_sizes.add(batch as f64);
        self.completed += 1;
        self.class_total[class.index()].record_ns(total_ns);
        self.class_completed[class.index()] += 1;
        if let Some(b) = bucket {
            if let Some(h) = self.bucket_total.get_mut(b) {
                h.record_ns(total_ns);
            } else if self.bucket_total.len() < MAX_BUCKET_HISTS {
                let mut h = LatencyHistogram::default();
                h.record_ns(total_ns);
                self.bucket_total.insert(b.clone(), h);
            }
        }
        if self.slo_ns > 0 {
            if self.slo_window.len() == SLO_WINDOW {
                self.slo_window.pop_front();
            }
            self.slo_window.push_back(total_ns > self.slo_ns);
        }
    }

    pub fn record_backpressure(&mut self) {
        self.rejected += 1;
        self.rej_backpressure += 1;
    }

    /// Admission-time load shed (low-priority traffic under overload).
    pub fn record_shed(&mut self, class: Priority) {
        self.rejected += 1;
        self.rej_shed += 1;
        self.class_shed[class.index()] += 1;
    }

    /// Deadline expiry: the request was shed from the queue (or at the
    /// executor) after its deadline passed, answered `Deadline`.
    pub fn record_expired(&mut self, class: Priority) {
        self.rejected += 1;
        self.rej_expired += 1;
        self.class_expired[class.index()] += 1;
    }

    pub fn record_quota(&mut self) {
        self.rejected += 1;
        self.rej_quota += 1;
    }

    pub fn record_invalid(&mut self) {
        self.rejected += 1;
        self.rej_invalid += 1;
    }

    /// Admission guard: the request's workspace footprint exceeded
    /// `serve.max_request_mb` with tiling disabled.
    pub fn record_too_large(&mut self) {
        self.rejected += 1;
        self.rej_too_large += 1;
    }

    /// One served request's peak workspace bytes (a rebased pool
    /// high-water window around its execution).
    pub fn record_request_ws_peak(&mut self, bytes: u64) {
        self.ws_req_peak.add(bytes as f64);
        self.ws_peak_leased = self.ws_peak_leased.max(bytes);
    }

    /// A queued/in-flight request resolved with `Closed` at shutdown.
    pub fn record_closed(&mut self) {
        self.closed += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn record_padding(&mut self, slots: usize) {
        self.padded_slots += slots as u64;
    }

    /// Fold in a workspace pool snapshot (called once per served batch).
    pub fn record_workspace(&mut self, ws: PoolStats) {
        self.ws_hits = ws.hits;
        self.ws_misses = ws.misses;
        self.ws_bytes_pooled = ws.bytes_pooled;
        self.ws_peak_leased = self.ws_peak_leased.max(ws.peak_leased);
    }

    /// Fraction of workspace acquires served from the pool (0.0 before
    /// any batch has recorded).
    pub fn ws_hit_rate(&self) -> f64 {
        let total = self.ws_hits + self.ws_misses;
        if total == 0 {
            0.0
        } else {
            self.ws_hits as f64 / total as f64
        }
    }

    /// Rolling error budget: the fraction of the last [`SLO_WINDOW`]
    /// completions whose total latency exceeded the configured SLO.
    /// 0.0 with no SLO configured or before any completion.
    pub fn error_budget(&self) -> f64 {
        if self.slo_window.is_empty() {
            return 0.0;
        }
        let bad = self.slo_window.iter().filter(|&&v| v).count();
        bad as f64 / self.slo_window.len() as f64
    }

    /// The configured p99 SLO threshold (0 = none).
    pub fn slo_ns(&self) -> u64 {
        self.slo_ns
    }

    /// Completed requests per second over the serving window.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.completed as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {} completed, {} rejected, {} errors, {} closed\n",
            self.completed, self.rejected, self.errors, self.closed
        ));
        if self.rejected > 0 {
            s.push_str(&format!(
                "rejections: {} backpressure, {} shed, {} expired, {} quota, {} invalid, \
                 {} too-large\n",
                self.rej_backpressure,
                self.rej_shed,
                self.rej_expired,
                self.rej_quota,
                self.rej_invalid,
                self.rej_too_large
            ));
        }
        s.push_str(&format!(
            "throughput: {:.1} req/s; mean batch {:.2} (padded slots {})\n",
            self.throughput_rps(),
            self.batch_sizes.mean(),
            self.padded_slots
        ));
        for (name, h) in [
            ("queue ", &self.queue_wait),
            ("exec  ", &self.execute),
            ("total ", &self.total),
        ] {
            s.push_str(&format!(
                "{name}: p50 {} | p95 {} | p99 {} | p999 {} | max {}\n",
                fmt_time_ns(h.percentile_ns(50.0)),
                fmt_time_ns(h.percentile_ns(95.0)),
                fmt_time_ns(h.percentile_ns(99.0)),
                fmt_time_ns(h.percentile_ns(99.9)),
                fmt_time_ns(h.max_ns() as f64),
            ));
        }
        for p in Priority::ALL {
            let i = p.index();
            if self.class_completed[i] + self.class_shed[i] + self.class_expired[i] == 0 {
                continue;
            }
            let h = &self.class_total[i];
            s.push_str(&format!(
                "class {:<6}: {} completed, {} shed, {} expired | p50 {} | p99 {} | p999 {}\n",
                p.label(),
                self.class_completed[i],
                self.class_shed[i],
                self.class_expired[i],
                fmt_time_ns(h.percentile_ns(50.0)),
                fmt_time_ns(h.percentile_ns(99.0)),
                fmt_time_ns(h.percentile_ns(99.9)),
            ));
        }
        if self.slo_ns > 0 {
            s.push_str(&format!(
                "slo: p99 target {}, error budget spent {:.1}% (window {})\n",
                fmt_time_ns(self.slo_ns as f64),
                self.error_budget() * 100.0,
                self.slo_window.len(),
            ));
        }
        s.push_str(&format!(
            "workspace: {:.1}% hit rate ({} hits, {} misses); {} pooled, {} peak leased\n",
            self.ws_hit_rate() * 100.0,
            self.ws_hits,
            self.ws_misses,
            fmt_bytes(self.ws_bytes_pooled),
            fmt_bytes(self.ws_peak_leased),
        ));
        if self.ws_req_peak.count() > 0 {
            s.push_str(&format!(
                "per-request peak workspace: mean {}, max {} over {} requests\n",
                fmt_bytes(self.ws_req_peak.mean() as u64),
                fmt_bytes(self.ws_req_peak.max() as u64),
                self.ws_req_peak.count(),
            ));
        }
        s
    }
}

/// Pretty-print byte counts: B/KiB/MiB/GiB.
fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b < KIB {
        format!("{b:.0} B")
    } else if b < KIB * KIB {
        format!("{:.1} KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bucket() -> Bucket {
        Bucket { c: 8, h: 64, w: 64, kchunk: 0, per_channel: false }
    }

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::new();
        for i in 0..100u64 {
            m.record_request(Priority::Normal, Some(&bucket()), 1000 + i, 5000, 7000 + i, 4);
        }
        m.record_backpressure();
        assert_eq!(m.completed, 100);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.rej_backpressure, 1);
        assert_eq!(m.batch_sizes.mean(), 4.0);
        assert!(m.total.percentile_ns(50.0) > 6000.0);
        assert_eq!(m.class_completed[Priority::Normal.index()], 100);
        assert_eq!(m.bucket_total[&bucket()].max_ns(), 7099);
    }

    #[test]
    fn split_rejection_counters_sum_into_aggregate() {
        let mut m = Metrics::new();
        m.record_backpressure();
        m.record_shed(Priority::Low);
        m.record_shed(Priority::Low);
        m.record_expired(Priority::Normal);
        m.record_quota();
        m.record_invalid();
        m.record_too_large();
        m.record_closed();
        assert_eq!(m.rejected, 7, "aggregate = sum of split counters");
        assert_eq!(
            (m.rej_backpressure, m.rej_shed, m.rej_expired, m.rej_quota, m.rej_invalid),
            (1, 2, 1, 1, 1)
        );
        assert_eq!(m.rej_too_large, 1);
        assert_eq!(m.closed, 1, "closed is not an admission rejection");
        assert_eq!(m.class_shed[Priority::Low.index()], 2);
        assert_eq!(m.class_expired[Priority::Normal.index()], 1);
        let r = m.report();
        assert!(
            r.contains("1 backpressure, 2 shed, 1 expired, 1 quota, 1 invalid, 1 too-large"),
            "{r}"
        );
        assert!(r.contains("1 closed"), "{r}");
    }

    #[test]
    fn report_contains_key_lines() {
        let mut m = Metrics::new();
        m.record_request(Priority::Normal, None, 100, 200, 400, 2);
        let r = m.report();
        assert!(r.contains("completed"));
        assert!(r.contains("p95"));
        assert!(r.contains("p999"));
        assert!(r.contains("throughput"));
        assert!(r.contains("workspace"));
        assert!(r.contains("class normal"), "{r}");
        assert!(!r.contains("class high"), "classes without traffic stay silent: {r}");
        assert!(!r.contains("slo:"), "no SLO configured: {r}");
    }

    #[test]
    fn report_max_is_exact_not_bucket_bound() {
        let mut m = Metrics::new();
        // 1.5 ms lands mid-bucket: the log-bucketed p100 would round up,
        // the true max must print the recorded value exactly.
        m.record_request(Priority::Normal, None, 100, 1_500_000, 1_500_100, 1);
        assert_eq!(m.execute.max_ns(), 1_500_000);
        assert!(m.report().contains("max 1.50 ms"), "{}", m.report());
    }

    #[test]
    fn error_budget_tracks_slo_violations_over_window() {
        let mut m = Metrics::with_slo(1_000_000); // 1 ms SLO
        assert_eq!(m.error_budget(), 0.0);
        for _ in 0..90 {
            m.record_request(Priority::High, None, 0, 500_000, 500_000, 1);
        }
        for _ in 0..10 {
            m.record_request(Priority::Low, None, 0, 2_000_000, 2_000_000, 1);
        }
        assert!((m.error_budget() - 0.1).abs() < 1e-9, "{}", m.error_budget());
        let r = m.report();
        assert!(r.contains("slo:"), "{r}");
        assert!(r.contains("error budget"), "{r}");
        // The window is bounded: flooding with good completions washes
        // the violations out.
        for _ in 0..SLO_WINDOW {
            m.record_request(Priority::High, None, 0, 1, 2, 1);
        }
        assert_eq!(m.error_budget(), 0.0);
        assert_eq!(m.slo_ns(), 1_000_000);
        // No-SLO metrics never accumulate a window.
        let mut plain = Metrics::new();
        plain.record_request(Priority::Low, None, 0, u64::MAX / 2, u64::MAX / 2, 1);
        assert_eq!(plain.error_budget(), 0.0);
    }

    #[test]
    fn bucket_histograms_are_capped() {
        let mut m = Metrics::new();
        for i in 0..(MAX_BUCKET_HISTS + 40) {
            let b = Bucket { c: 1 + i, h: 8, w: 8, kchunk: 0, per_channel: false };
            m.record_request(Priority::Normal, Some(&b), 0, 100, 100, 1);
        }
        assert_eq!(m.bucket_total.len(), MAX_BUCKET_HISTS);
        assert_eq!(m.completed as usize, MAX_BUCKET_HISTS + 40, "aggregate still counts all");
    }

    #[test]
    fn workspace_counters_snapshot_and_keep_peak() {
        let mut m = Metrics::new();
        assert_eq!(m.ws_hit_rate(), 0.0);
        m.record_workspace(PoolStats {
            hits: 3,
            misses: 1,
            bytes_pooled: 4096,
            bytes_leased: 0,
            peak_leased: 8192,
        });
        m.record_workspace(PoolStats {
            hits: 9,
            misses: 1,
            bytes_pooled: 2048,
            bytes_leased: 0,
            peak_leased: 1024,
        });
        assert_eq!((m.ws_hits, m.ws_misses), (9, 1));
        assert_eq!(m.ws_bytes_pooled, 2048);
        assert_eq!(m.ws_peak_leased, 8192, "peak must never regress");
        assert!((m.ws_hit_rate() - 0.9).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("90.0% hit rate"), "{r}");
        assert!(r.contains("2.0 KiB pooled"), "{r}");
        assert!(!r.contains("per-request peak"), "no per-request peaks recorded yet: {r}");
    }

    #[test]
    fn per_request_peaks_accumulate_and_raise_the_high_water_mark() {
        let mut m = Metrics::new();
        m.record_workspace(PoolStats {
            hits: 1,
            misses: 1,
            bytes_pooled: 0,
            bytes_leased: 0,
            peak_leased: 1024,
        });
        m.record_request_ws_peak(4096);
        m.record_request_ws_peak(2048);
        assert_eq!(m.ws_req_peak.count(), 2);
        assert_eq!(m.ws_req_peak.max(), 4096.0);
        assert_eq!(
            m.ws_peak_leased, 4096,
            "per-request peaks must feed the lifetime high-water mark"
        );
        let r = m.report();
        assert!(r.contains("per-request peak workspace"), "{r}");
        assert!(r.contains("max 4.0 KiB"), "{r}");
    }

    #[test]
    fn throughput_zero_when_empty() {
        assert_eq!(Metrics::new().throughput_rps(), 0.0);
    }
}
