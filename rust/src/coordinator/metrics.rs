//! Serving metrics: latency histograms per stage, throughput, queue and
//! batching statistics. Shared across workers behind a mutex; snapshots
//! are cheap copies for reporting.

use std::time::Instant;

use crate::util::stats::{fmt_time_ns, LatencyHistogram, Summary};
use crate::util::PoolStats;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub queue_wait: LatencyHistogram,
    pub execute: LatencyHistogram,
    pub total: LatencyHistogram,
    pub batch_sizes: Summary,
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub padded_slots: u64,
    /// Workspace pool counters, snapshotted once per served batch (the
    /// pool's counters are cumulative, so the latest snapshot is the
    /// current truth; `ws_peak_leased` keeps its own high-water mark so
    /// a late snapshot cannot lower it).
    pub ws_hits: u64,
    pub ws_misses: u64,
    pub ws_bytes_pooled: u64,
    pub ws_peak_leased: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&mut self, queue_ns: u64, execute_ns: u64, total_ns: u64, batch: usize) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        self.finished = Some(Instant::now());
        self.queue_wait.record_ns(queue_ns);
        self.execute.record_ns(execute_ns);
        self.total.record_ns(total_ns);
        self.batch_sizes.add(batch as f64);
        self.completed += 1;
    }

    pub fn record_rejection(&mut self) {
        self.rejected += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn record_padding(&mut self, slots: usize) {
        self.padded_slots += slots as u64;
    }

    /// Fold in a workspace pool snapshot (called once per served batch).
    pub fn record_workspace(&mut self, ws: PoolStats) {
        self.ws_hits = ws.hits;
        self.ws_misses = ws.misses;
        self.ws_bytes_pooled = ws.bytes_pooled;
        self.ws_peak_leased = self.ws_peak_leased.max(ws.peak_leased);
    }

    /// Fraction of workspace acquires served from the pool (0.0 before
    /// any batch has recorded).
    pub fn ws_hit_rate(&self) -> f64 {
        let total = self.ws_hits + self.ws_misses;
        if total == 0 {
            0.0
        } else {
            self.ws_hits as f64 / total as f64
        }
    }

    /// Completed requests per second over the serving window.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.completed as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {} completed, {} rejected, {} errors\n",
            self.completed, self.rejected, self.errors
        ));
        s.push_str(&format!(
            "throughput: {:.1} req/s; mean batch {:.2} (padded slots {})\n",
            self.throughput_rps(),
            self.batch_sizes.mean(),
            self.padded_slots
        ));
        for (name, h) in [
            ("queue ", &self.queue_wait),
            ("exec  ", &self.execute),
            ("total ", &self.total),
        ] {
            s.push_str(&format!(
                "{name}: p50 {} | p95 {} | p99 {} | p999 {} | max {}\n",
                fmt_time_ns(h.percentile_ns(50.0)),
                fmt_time_ns(h.percentile_ns(95.0)),
                fmt_time_ns(h.percentile_ns(99.0)),
                fmt_time_ns(h.percentile_ns(99.9)),
                fmt_time_ns(h.max_ns() as f64),
            ));
        }
        s.push_str(&format!(
            "workspace: {:.1}% hit rate ({} hits, {} misses); {} pooled, {} peak leased\n",
            self.ws_hit_rate() * 100.0,
            self.ws_hits,
            self.ws_misses,
            fmt_bytes(self.ws_bytes_pooled),
            fmt_bytes(self.ws_peak_leased),
        ));
        s
    }
}

/// Pretty-print byte counts: B/KiB/MiB/GiB.
fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b < KIB {
        format!("{b:.0} B")
    } else if b < KIB * KIB {
        format!("{:.1} KiB", b / KIB)
    } else if b < KIB * KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::new();
        for i in 0..100u64 {
            m.record_request(1000 + i, 5000, 7000 + i, 4);
        }
        m.record_rejection();
        assert_eq!(m.completed, 100);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.batch_sizes.mean(), 4.0);
        assert!(m.total.percentile_ns(50.0) > 6000.0);
    }

    #[test]
    fn report_contains_key_lines() {
        let mut m = Metrics::new();
        m.record_request(100, 200, 400, 2);
        let r = m.report();
        assert!(r.contains("completed"));
        assert!(r.contains("p95"));
        assert!(r.contains("p999"));
        assert!(r.contains("throughput"));
        assert!(r.contains("workspace"));
    }

    #[test]
    fn report_max_is_exact_not_bucket_bound() {
        let mut m = Metrics::new();
        // 1.5 ms lands mid-bucket: the log-bucketed p100 would round up,
        // the true max must print the recorded value exactly.
        m.record_request(100, 1_500_000, 1_500_100, 1);
        assert_eq!(m.execute.max_ns(), 1_500_000);
        assert!(m.report().contains("max 1.50 ms"), "{}", m.report());
    }

    #[test]
    fn workspace_counters_snapshot_and_keep_peak() {
        let mut m = Metrics::new();
        assert_eq!(m.ws_hit_rate(), 0.0);
        m.record_workspace(PoolStats {
            hits: 3,
            misses: 1,
            bytes_pooled: 4096,
            bytes_leased: 0,
            peak_leased: 8192,
        });
        m.record_workspace(PoolStats {
            hits: 9,
            misses: 1,
            bytes_pooled: 2048,
            bytes_leased: 0,
            peak_leased: 1024,
        });
        assert_eq!((m.ws_hits, m.ws_misses), (9, 1));
        assert_eq!(m.ws_bytes_pooled, 2048);
        assert_eq!(m.ws_peak_leased, 8192, "peak must never regress");
        assert!((m.ws_hit_rate() - 0.9).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("90.0% hit rate"), "{r}");
        assert!(r.contains("2.0 KiB pooled"), "{r}");
    }

    #[test]
    fn throughput_zero_when_empty() {
        assert_eq!(Metrics::new().throughput_rps(), 0.0);
    }
}
