//! Serving metrics: latency histograms per stage, throughput, queue and
//! batching statistics. Shared across workers behind a mutex; snapshots
//! are cheap copies for reporting.

use std::time::Instant;

use crate::util::stats::{fmt_time_ns, LatencyHistogram, Summary};

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub queue_wait: LatencyHistogram,
    pub execute: LatencyHistogram,
    pub total: LatencyHistogram,
    pub batch_sizes: Summary,
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    pub padded_slots: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&mut self, queue_ns: u64, execute_ns: u64, total_ns: u64, batch: usize) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        self.finished = Some(Instant::now());
        self.queue_wait.record_ns(queue_ns);
        self.execute.record_ns(execute_ns);
        self.total.record_ns(total_ns);
        self.batch_sizes.add(batch as f64);
        self.completed += 1;
    }

    pub fn record_rejection(&mut self) {
        self.rejected += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn record_padding(&mut self, slots: usize) {
        self.padded_slots += slots as u64;
    }

    /// Completed requests per second over the serving window.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.completed as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {} completed, {} rejected, {} errors\n",
            self.completed, self.rejected, self.errors
        ));
        s.push_str(&format!(
            "throughput: {:.1} req/s; mean batch {:.2} (padded slots {})\n",
            self.throughput_rps(),
            self.batch_sizes.mean(),
            self.padded_slots
        ));
        for (name, h) in [
            ("queue ", &self.queue_wait),
            ("exec  ", &self.execute),
            ("total ", &self.total),
        ] {
            s.push_str(&format!(
                "{name}: p50 {} | p95 {} | p99 {} | max-ish {}\n",
                fmt_time_ns(h.percentile_ns(50.0)),
                fmt_time_ns(h.percentile_ns(95.0)),
                fmt_time_ns(h.percentile_ns(99.0)),
                fmt_time_ns(h.percentile_ns(100.0)),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = Metrics::new();
        for i in 0..100u64 {
            m.record_request(1000 + i, 5000, 7000 + i, 4);
        }
        m.record_rejection();
        assert_eq!(m.completed, 100);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.batch_sizes.mean(), 4.0);
        assert!(m.total.percentile_ns(50.0) > 6000.0);
    }

    #[test]
    fn report_contains_key_lines() {
        let mut m = Metrics::new();
        m.record_request(100, 200, 400, 2);
        let r = m.report();
        assert!(r.contains("completed"));
        assert!(r.contains("p95"));
        assert!(r.contains("throughput"));
    }

    #[test]
    fn throughput_zero_when_empty() {
        assert_eq!(Metrics::new().throughput_rps(), 0.0);
    }
}
