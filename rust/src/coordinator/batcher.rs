//! Shape-bucketed dynamic batching policy.
//!
//! Requests accumulate in per-bucket FIFO queues. A batch is released
//! when (a) the head request has waited `max_wait`, or (b) the queue
//! holds at least `max_batch` requests. Released batches are fused to
//! the largest compiled batch size that fits (artifact batch sizes come
//! from the manifest, e.g. {1, 2, 4}), splitting greedily: 7 queued ->
//! 4 + 2 + 1 if the caller keeps draining.
//!
//! The policy is deliberately separate from the execution loop so it can
//! be unit-tested (and criterion-benched) without PJRT.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use super::request::{Bucket, Request};

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Total queued-request cap across buckets (admission control).
    pub queue_cap: usize,
    /// Release partial batches immediately when a worker would otherwise
    /// idle: batch formation only pays when the executor is busy, so an
    /// idle worker takes whatever is queued instead of letting the head
    /// request age out `max_wait` (latency-under-idleness). The serving
    /// worker additionally sizes eager releases off shared-pool
    /// occupancy via [`Batcher::pop_eager_min`]: a saturated pool holds
    /// partials back so batches come out larger.
    pub eager_idle: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_micros(2000),
            queue_cap: 256,
            eager_idle: true,
        }
    }
}

/// Per-bucket queues + round-robin fairness cursor.
pub struct Batcher {
    pub policy: BatchPolicy,
    queues: BTreeMap<Bucket, VecDeque<Request>>,
    /// Supported artifact batch sizes per bucket (sorted ascending).
    batch_sizes: BTreeMap<Bucket, Vec<usize>>,
    rr_cursor: usize,
    queued: usize,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher {
            policy,
            queues: BTreeMap::new(),
            batch_sizes: BTreeMap::new(),
            rr_cursor: 0,
            queued: 0,
        }
    }

    /// Register a bucket with the artifact batch sizes available for it.
    pub fn register_bucket(&mut self, bucket: Bucket, mut sizes: Vec<usize>) {
        sizes.sort_unstable();
        self.batch_sizes.insert(bucket.clone(), sizes);
        self.queues.entry(bucket).or_default();
    }

    pub fn known_bucket(&self, bucket: &Bucket) -> bool {
        self.batch_sizes.contains_key(bucket)
    }

    /// Number of registered buckets (used to cap dynamic registration).
    pub fn bucket_count(&self) -> usize {
        self.batch_sizes.len()
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn has_capacity(&self) -> bool {
        self.policy.queue_cap == 0 || self.queued < self.policy.queue_cap
    }

    /// Enqueue into a *registered* bucket. Unknown buckets hand the
    /// request back as `Err` instead of silently creating a queue (the
    /// old behaviour — such queues then fell back to a fabricated
    /// artifact batch size of 1 in `pop_batch` and produced executions
    /// against artifacts that do not exist).
    pub fn enqueue(&mut self, bucket: Bucket, req: Request) -> Result<(), Request> {
        match self.queues.get_mut(&bucket) {
            Some(q) => {
                q.push_back(req);
                self.queued += 1;
                Ok(())
            }
            None => Err(req),
        }
    }

    /// Next deadline at which some queue becomes releasable by age (for
    /// condvar timeouts). None when everything is empty.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|r| r.arrived + self.policy.max_wait)
            .min()
    }

    /// Pop a releasable batch, preferring (fairly, round-robin) buckets
    /// that are full or whose head has aged out. `now` is injectable for
    /// tests. Returns the bucket, the fused artifact batch size, and the
    /// requests (len <= fused size; len == fused size unless the bucket
    /// only offers larger artifacts — callers pad in that case).
    pub fn pop_batch(&mut self, now: Instant) -> Option<(Bucket, usize, Vec<Request>)> {
        self.pop_releasable(now, 1)
    }

    fn pop_releasable(
        &mut self,
        now: Instant,
        min_len: usize,
    ) -> Option<(Bucket, usize, Vec<Request>)> {
        let keys: Vec<Bucket> = self.queues.keys().cloned().collect();
        if keys.is_empty() {
            return None;
        }
        let n = keys.len();
        for i in 0..n {
            let k = &keys[(self.rr_cursor + i) % n];
            let q = self.queues.get_mut(k).unwrap();
            if q.is_empty() || q.len() < min_len {
                continue;
            }
            let head_aged =
                now.duration_since(q.front().unwrap().arrived) >= self.policy.max_wait;
            let full = q.len() >= self.policy.max_batch;
            if !(head_aged || full) {
                continue;
            }
            let sizes = self
                .batch_sizes
                .get(k)
                .cloned()
                .expect("every queued bucket was registered at enqueue");
            let want = q.len().min(self.policy.max_batch);
            // Largest artifact size <= want, else the smallest artifact
            // (padding case when want < min size).
            let fused = sizes
                .iter()
                .rev()
                .find(|&&s| s <= want)
                .copied()
                .unwrap_or_else(|| sizes[0]);
            let take = fused.min(q.len());
            let batch: Vec<Request> = q.drain(..take).collect();
            self.queued -= batch.len();
            self.rr_cursor = (self.rr_cursor + i + 1) % n;
            return Some((k.clone(), fused, batch));
        }
        None
    }

    /// Pop regardless of head age (the eager-idle path): equivalent to
    /// `pop_batch` at a time when every head has aged out.
    pub fn pop_eager(&mut self, now: Instant) -> Option<(Bucket, usize, Vec<Request>)> {
        self.pop_eager_min(now, 1)
    }

    /// Pool-occupancy-aware eager pop: like [`Batcher::pop_eager`], but
    /// only releases buckets holding at least `min_len` requests. The
    /// serving worker raises `min_len` to `max_batch` while the shared
    /// thread pool is saturated — an eager partial release buys no
    /// latency when the executor would only queue behind the pool, so
    /// the batcher keeps accumulating toward a larger fused batch
    /// instead. Truly aged heads are never starved: callers release them
    /// through [`Batcher::pop_batch`] first, where age always wins.
    /// `min_len` is clamped to `max_batch` so a full bucket always
    /// releases.
    pub fn pop_eager_min(
        &mut self,
        now: Instant,
        min_len: usize,
    ) -> Option<(Bucket, usize, Vec<Request>)> {
        let min_len = min_len.clamp(1, self.policy.max_batch.max(1));
        self.pop_releasable(now + self.policy.max_wait + Duration::from_nanos(1), min_len)
    }

    /// Drain everything regardless of age (shutdown path).
    pub fn drain_all(&mut self, mut f: impl FnMut(Bucket, usize, Vec<Request>)) {
        loop {
            let far_future = Instant::now() + Duration::from_secs(3600);
            match self.pop_batch(far_future) {
                Some((b, fused, reqs)) => f(b, fused, reqs),
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Payload, Response};
    use crate::Tensor;
    use std::sync::mpsc;

    fn bucket(c: usize) -> Bucket {
        Bucket { c, h: 64, w: 64, kchunk: 0, per_channel: false }
    }

    fn req(id: u64, c: usize, arrived: Instant) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            id,
            payload: Payload::Scan {
                x: Tensor::zeros(&[1, c, 64, 64]),
                a_raw: Tensor::zeros(&[1, 1, 3, 64, 64]),
                lam: Tensor::zeros(&[1, c, 64, 64]),
            },
            kchunk: 0,
            arrived,
            reply: tx,
        };
        (r, rx)
    }

    fn mk_batcher(max_batch: usize, wait_us: u64) -> Batcher {
        let mut b = Batcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
            queue_cap: 16,
            eager_idle: false,
        });
        b.register_bucket(bucket(8), vec![1, 2, 4]);
        b
    }

    #[test]
    fn young_queue_not_released() {
        let mut b = mk_batcher(4, 10_000);
        let now = Instant::now();
        let (r, _rx) = req(1, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        assert!(b.pop_batch(now).is_none());
    }

    #[test]
    fn aged_head_releases_partial_batch() {
        let mut b = mk_batcher(4, 1_000);
        let t0 = Instant::now();
        let (r, _rx) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let later = t0 + Duration::from_micros(2_000);
        let (bk, fused, reqs) = b.pop_batch(later).expect("aged release");
        assert_eq!(bk, bucket(8));
        assert_eq!(fused, 1);
        assert_eq!(reqs.len(), 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn full_queue_releases_immediately() {
        let mut b = mk_batcher(4, 1_000_000);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (_, fused, reqs) = b.pop_batch(now).expect("full release");
        assert_eq!(fused, 4);
        assert_eq!(reqs.len(), 4);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn fused_size_is_largest_artifact_leq_queue() {
        let mut b = mk_batcher(8, 0); // release instantly
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        // 3 queued with artifacts {1,2,4} -> fuse 2, leave 1.
        let (_, fused, reqs) = b.pop_batch(now).unwrap();
        assert_eq!(fused, 2);
        assert_eq!(reqs.len(), 2);
        let (_, fused2, reqs2) = b.pop_batch(now).unwrap();
        assert_eq!(fused2, 1);
        assert_eq!(reqs2.len(), 1);
    }

    #[test]
    fn never_exceeds_max_batch() {
        let mut b = mk_batcher(2, 0);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..10 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        while let Some((_, _fused, reqs)) = b.pop_batch(now) {
            assert!(reqs.len() <= 2);
        }
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn round_robin_is_fair_across_buckets() {
        let mut b = mk_batcher(1, 0);
        b.register_bucket(bucket(16), vec![1]);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let c = if i % 2 == 0 { 8 } else { 16 };
            let (r, rx) = req(i, c, now);
            b.enqueue(bucket(c), r).expect("registered");
            rxs.push(rx);
        }
        let mut seen = Vec::new();
        while let Some((bk, _, _)) = b.pop_batch(now) {
            seen.push(bk.c);
        }
        // Strict alternation between the two buckets.
        assert_eq!(seen.len(), 4);
        assert_ne!(seen[0], seen[1]);
        assert_ne!(seen[1], seen[2]);
        assert_ne!(seen[2], seen[3]);
    }

    #[test]
    fn capacity_accounting() {
        let mut b = mk_batcher(4, 1000);
        assert!(b.has_capacity());
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..16 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        assert!(!b.has_capacity());
    }

    #[test]
    fn fifo_order_within_bucket() {
        let mut b = mk_batcher(2, 0);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (_, _, first) = b.pop_batch(now).unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let (_, _, second) = b.pop_batch(now).unwrap();
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn unknown_bucket_enqueue_is_rejected() {
        let mut b = mk_batcher(4, 0);
        let now = Instant::now();
        // bucket(16) was never registered: the request comes back and
        // nothing is queued (previously this silently created a queue
        // that pop_batch served with a fabricated batch size of 1).
        let (r, _rx) = req(1, 16, now);
        let rejected = b.enqueue(bucket(16), r).unwrap_err();
        assert_eq!(rejected.id, 1);
        assert_eq!(b.queued(), 0);
        assert!(b.pop_eager(now).is_none());
        // After registration the same bucket is accepted.
        b.register_bucket(bucket(16), vec![1]);
        let (r, _rx2) = req(2, 16, now);
        b.enqueue(bucket(16), r).expect("registered now");
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn eager_min_holds_small_batches_until_sized() {
        let mut b = mk_batcher(4, 1_000_000);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        // Saturated-pool setting (min_len = max_batch): 3 of 4 queued
        // are held back by the eager path.
        assert!(b.pop_eager_min(now, 4).is_none());
        assert_eq!(b.queued(), 3);
        // The 4th request fills the bucket: the sized eager pop fires
        // with the full fused batch.
        let (r, rx) = req(9, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        rxs.push(rx);
        let (_, fused, reqs) = b.pop_eager_min(now, 4).expect("sized release");
        assert_eq!(fused, 4);
        assert_eq!(reqs.len(), 4);
        // An idle pool (min_len = 1) keeps releasing partials instantly.
        let (r, rx) = req(10, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        rxs.push(rx);
        let (_, fused, reqs) = b.pop_eager_min(now, 1).expect("idle release");
        assert_eq!(fused, 1);
        assert_eq!(reqs.len(), 1);
    }

    #[test]
    fn eager_min_clamps_to_max_batch() {
        // A min_len larger than max_batch must not wedge full queues.
        let mut b = mk_batcher(2, 1_000_000);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..2 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (_, _, reqs) = b.pop_eager_min(now, 100).expect("clamped release");
        assert_eq!(reqs.len(), 2);
    }

    #[test]
    fn aged_heads_release_regardless_of_min_len_via_pop_batch() {
        // The no-starvation invariant: pop_batch (the age path) ignores
        // eager sizing entirely.
        let mut b = mk_batcher(4, 1_000);
        let t0 = Instant::now();
        let (r, _rx) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let later = t0 + Duration::from_micros(2_000);
        assert!(b.pop_batch(later).is_some());
    }

    #[test]
    fn next_deadline_tracks_oldest_head() {
        let mut b = mk_batcher(4, 5_000);
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        let (r, _rx) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let d = b.next_deadline().unwrap();
        assert_eq!(d, t0 + Duration::from_micros(5_000));
    }
}
