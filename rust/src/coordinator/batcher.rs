//! Shape-bucketed dynamic batching policy.
//!
//! Requests accumulate in per-bucket queues ordered by *effective
//! release instant* (earliest-deadline-first): a deadline-less request
//! releases when it has aged `max_wait`, a deadlined one releases at
//! least `max_wait` before its deadline (see
//! [`Request::release_at`]), so latency-critical requests jump the
//! line without starving aged peers. A batch is released when (a) the
//! head request's release instant has passed, or (b) the queue holds
//! at least `max_batch` requests. Released batches are fused to the
//! largest compiled batch size that fits (artifact batch sizes come
//! from the manifest, e.g. {1, 2, 4}), splitting greedily: 7 queued ->
//! 4 + 2 + 1 if the caller keeps draining.
//!
//! Expired requests (deadline already passed at pop time) are never
//! executed dead: the clocked pop paths shed them into an internal
//! side list the serving worker drains via [`Batcher::take_expired`]
//! and answers with a structured `Deadline` reply.
//!
//! The policy is deliberately separate from the execution loop so it can
//! be unit-tested (and criterion-benched) without PJRT.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::time::{Duration, Instant};

use super::request::{Bucket, Request};

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Total queued-request cap across buckets (admission control).
    pub queue_cap: usize,
    /// Release partial batches immediately when a worker would otherwise
    /// idle: batch formation only pays when the executor is busy, so an
    /// idle worker takes whatever is queued instead of letting the head
    /// request age out `max_wait` (latency-under-idleness). The serving
    /// worker additionally sizes eager releases off the bucket's scan
    /// execution plan via [`Batcher::pop_eager_by`]: a request whose
    /// planned fan exceeds the pool's idle capacity is held back so
    /// batches come out larger exactly when batching is free.
    pub eager_idle: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_micros(2000),
            queue_cap: 256,
            eager_idle: true,
        }
    }
}

/// Per-bucket queues + round-robin fairness cursor.
///
/// Scaling: poll-path operations ([`Batcher::pop_batch`],
/// [`Batcher::next_deadline`]) walk a **non-empty index** instead of
/// every registered bucket, so a server with the full dynamic
/// registration cap (1024 buckets, mostly idle) polls in O(active
/// buckets), not O(registered). Dynamically registered buckets
/// ([`Batcher::register_bucket_dynamic`]) are additionally *pruned* when
/// their queue drains — their registration cap measures live state, and
/// a client cycling through geometries can no longer grow batcher state
/// without bound. Statically registered (manifest/artifact) buckets are
/// never pruned.
pub struct Batcher {
    pub policy: BatchPolicy,
    queues: BTreeMap<Bucket, VecDeque<Request>>,
    /// Supported artifact batch sizes per bucket (sorted ascending).
    batch_sizes: BTreeMap<Bucket, Vec<usize>>,
    /// Buckets whose queue currently holds at least one request — the
    /// only buckets the poll paths touch.
    nonempty: BTreeSet<Bucket>,
    /// Dynamically registered buckets, pruned once drained.
    dynamic: BTreeSet<Bucket>,
    /// Requests whose deadline passed before release: shed out of the
    /// queues by the clocked pop paths, awaiting [`Batcher::take_expired`].
    expired: Vec<Request>,
    rr_cursor: usize,
    queued: usize,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher {
            policy,
            queues: BTreeMap::new(),
            batch_sizes: BTreeMap::new(),
            nonempty: BTreeSet::new(),
            dynamic: BTreeSet::new(),
            expired: Vec::new(),
            rr_cursor: 0,
            queued: 0,
        }
    }

    /// Register a bucket with the artifact batch sizes available for it.
    /// Static registration: the bucket stays registered for the
    /// batcher's lifetime (manifest-backed artifacts).
    pub fn register_bucket(&mut self, bucket: Bucket, mut sizes: Vec<usize>) {
        sizes.sort_unstable();
        self.dynamic.remove(&bucket);
        self.batch_sizes.insert(bucket.clone(), sizes);
        self.queues.entry(bucket).or_default();
    }

    /// Register a bucket discovered from traffic (the cpu backend's
    /// on-first-use path): identical to [`Batcher::register_bucket`],
    /// except the bucket is pruned — queue, sizes, and registration —
    /// as soon as its queue drains, so idle geometries stop occupying
    /// the registration cap and the poll paths.
    pub fn register_bucket_dynamic(&mut self, bucket: Bucket, sizes: Vec<usize>) {
        self.register_bucket(bucket.clone(), sizes);
        self.dynamic.insert(bucket);
    }

    pub fn known_bucket(&self, bucket: &Bucket) -> bool {
        self.batch_sizes.contains_key(bucket)
    }

    /// Number of registered buckets (used to cap dynamic registration;
    /// drained dynamic buckets no longer count).
    pub fn bucket_count(&self) -> usize {
        self.batch_sizes.len()
    }

    /// Buckets currently holding queued requests (the poll-path working
    /// set).
    pub fn nonempty_buckets(&self) -> usize {
        self.nonempty.len()
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn has_capacity(&self) -> bool {
        self.policy.queue_cap == 0 || self.queued < self.policy.queue_cap
    }

    /// Enqueue into a *registered* bucket. Unknown buckets hand the
    /// request back as `Err` instead of silently creating a queue (the
    /// old behaviour — such queues then fell back to a fabricated
    /// artifact batch size of 1 in `pop_batch` and produced executions
    /// against artifacts that do not exist).
    pub fn enqueue(&mut self, bucket: Bucket, req: Request) -> Result<(), Request> {
        let max_wait = self.policy.max_wait;
        match self.queues.get_mut(&bucket) {
            Some(q) => {
                // Earliest-deadline-first insert: keep the queue sorted
                // by effective release instant, stable (FIFO) for equal
                // keys — deadline-less traffic at the same arrival
                // keeps its age order, a tight deadline moves up.
                let key = req.release_at(max_wait);
                let mut at = q.len();
                while at > 0 && q[at - 1].release_at(max_wait) > key {
                    at -= 1;
                }
                q.insert(at, req);
                self.queued += 1;
                self.nonempty.insert(bucket);
                Ok(())
            }
            None => Err(req),
        }
    }

    /// Next instant at which some queue head becomes releasable —
    /// by age or by deadline pressure (for condvar timeouts). None when
    /// everything is empty. Walks the non-empty index only.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.nonempty
            .iter()
            .filter_map(|k| self.queues.get(k).and_then(|q| q.front()))
            .map(|r| r.release_at(self.policy.max_wait))
            .min()
    }

    /// Drain the requests the clocked pop paths shed for passing their
    /// deadline while queued. The serving worker answers each with a
    /// structured `Deadline` reply; tests use it to observe shedding.
    pub fn take_expired(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.expired)
    }

    /// Pop a releasable batch, preferring (fairly, round-robin) buckets
    /// that are full or whose head has aged out. `now` is injectable for
    /// tests. Returns the bucket, the fused artifact batch size, and the
    /// requests (len <= fused size; len == fused size unless the bucket
    /// only offers larger artifacts — callers pad in that case).
    pub fn pop_batch(&mut self, now: Instant) -> Option<(Bucket, usize, Vec<Request>)> {
        self.pop_releasable(Some(now), |_, _, _| 1)
    }

    /// The shared pop core: round-robin over the *non-empty* buckets
    /// only, releasing the first that is full or whose head's effective
    /// release instant has passed (`now = None` treats every head as
    /// releasable — the clock-free eager path) and that holds at least
    /// `min_for(bucket, queue_len, head_deadline)` requests (clamped to
    /// `[1, max_batch]`; `head_deadline` is the head request's explicit
    /// deadline, the hook eager release sizing uses for deadline
    /// pressure). A bucket drained to empty leaves the index; a drained
    /// *dynamic* bucket is pruned entirely.
    ///
    /// Clocked pops first shed every *expired* request in each visited
    /// bucket into the [`Batcher::take_expired`] side list — a dead
    /// request must never be executed, and must not hold a batch slot.
    ///
    /// Instant comparisons stay order-based (never `duration_since`
    /// subtraction): callers race `Instant::now()` against enqueuers
    /// taking timestamps under a different lock ordering, so a `now`
    /// slightly earlier than a head's `arrived` is legal and must read
    /// as "not yet releasable", not an underflow panic that poisons the
    /// batcher.
    fn pop_releasable<F: Fn(&Bucket, usize, Option<Instant>) -> usize>(
        &mut self,
        now: Option<Instant>,
        min_for: F,
    ) -> Option<(Bucket, usize, Vec<Request>)> {
        if self.nonempty.is_empty() {
            return None;
        }
        let keys: Vec<Bucket> = self.nonempty.iter().cloned().collect();
        let n = keys.len();
        let max_batch = self.policy.max_batch.max(1);
        let max_wait = self.policy.max_wait;
        for i in 0..n {
            let k = &keys[(self.rr_cursor + i) % n];
            let q = self.queues.get_mut(k).unwrap();
            debug_assert!(!q.is_empty(), "indexed bucket with empty queue");
            if let Some(now) = now {
                // Shed expired requests before sizing the release.
                let mut j = 0;
                while j < q.len() {
                    if q[j].expired(now) {
                        let r = q.remove(j).expect("index in bounds");
                        self.queued -= 1;
                        self.expired.push(r);
                    } else {
                        j += 1;
                    }
                }
                if q.is_empty() {
                    self.nonempty.remove(k);
                    if self.dynamic.remove(k) {
                        self.queues.remove(k);
                        self.batch_sizes.remove(k);
                    }
                    continue;
                }
            }
            let head_deadline = q.front().and_then(|r| r.deadline);
            let min_len = min_for(k, q.len(), head_deadline).clamp(1, max_batch);
            if q.len() < min_len {
                continue;
            }
            let head_aged = match now {
                None => true,
                Some(now) => now >= q.front().unwrap().release_at(max_wait),
            };
            let full = q.len() >= self.policy.max_batch;
            if !(head_aged || full) {
                continue;
            }
            let sizes = self
                .batch_sizes
                .get(k)
                .cloned()
                .expect("every queued bucket was registered at enqueue");
            let want = q.len().min(self.policy.max_batch);
            // Largest artifact size <= want, else the smallest artifact
            // (padding case when want < min size).
            let fused = sizes
                .iter()
                .rev()
                .find(|&&s| s <= want)
                .copied()
                .unwrap_or_else(|| sizes[0]);
            let take = fused.min(q.len());
            let batch: Vec<Request> = q.drain(..take).collect();
            self.queued -= batch.len();
            if q.is_empty() {
                self.nonempty.remove(k);
                if self.dynamic.remove(k) {
                    self.queues.remove(k);
                    self.batch_sizes.remove(k);
                }
            }
            self.rr_cursor = (self.rr_cursor + i + 1) % n;
            return Some((k.clone(), fused, batch));
        }
        None
    }

    /// Pop regardless of head age (the eager-idle path): `pop_batch`
    /// with every head treated as aged, so it takes no clock at all.
    /// Convenience shim over [`Batcher::pop_eager_by`] — the serving
    /// worker uses the per-bucket plan-cost form directly.
    pub fn pop_eager(&mut self) -> Option<(Bucket, usize, Vec<Request>)> {
        self.pop_eager_min(1)
    }

    /// Eager pop with one global minimum release size: like
    /// [`Batcher::pop_eager`], but only releases buckets holding at
    /// least `min_len` requests (clamped to `max_batch` so a full
    /// bucket always releases). A fixed-threshold shim over
    /// [`Batcher::pop_eager_by`], kept for tests and callers without a
    /// per-bucket cost model; truly aged heads are never starved —
    /// callers release them through [`Batcher::pop_batch`] first, where
    /// age always wins.
    pub fn pop_eager_min(&mut self, min_len: usize) -> Option<(Bucket, usize, Vec<Request>)> {
        self.pop_eager_by(|_, _, _| min_len)
    }

    /// Plan-cost-aware eager pop: like [`Batcher::pop_eager_min`], but
    /// the minimum release size is computed *per bucket* by `min_for`
    /// (given the bucket, its queue length, and the head request's
    /// explicit deadline if any). The serving worker passes
    /// [`crate::scan::plan::eager_release_min_slo`] over the
    /// bucket-geometry's execution plan, so release sizing follows the
    /// plan's cost estimate — how much of the pool one request's fan
    /// would actually cover — tightened by deadline pressure: a head
    /// close to its deadline releases early instead of holding for a
    /// fuller batch.
    pub fn pop_eager_by<F: Fn(&Bucket, usize, Option<Instant>) -> usize>(
        &mut self,
        min_for: F,
    ) -> Option<(Bucket, usize, Vec<Request>)> {
        // Age is ignored outright (previously emulated by shifting a
        // caller-supplied `now` past max_wait, which silently broke for
        // a stale `now` — eager pops take no clock at all).
        self.pop_releasable(None, min_for)
    }

    /// Drain everything regardless of age (shutdown path) — clock-free,
    /// like the eager pops (the old far-future-instant emulation broke
    /// for any `max_wait` past the shifted horizon).
    pub fn drain_all(&mut self, mut f: impl FnMut(Bucket, usize, Vec<Request>)) {
        while let Some((b, fused, reqs)) = self.pop_eager() {
            f(b, fused, reqs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Payload, Response};
    use crate::Tensor;
    use std::sync::mpsc;

    fn bucket(c: usize) -> Bucket {
        Bucket { c, h: 64, w: 64, kchunk: 0, per_channel: false }
    }

    fn req(id: u64, c: usize, arrived: Instant) -> (Request, mpsc::Receiver<Response>) {
        req_deadline(id, c, arrived, None)
    }

    fn req_deadline(
        id: u64,
        c: usize,
        arrived: Instant,
        deadline: Option<Instant>,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            id,
            payload: Payload::Scan {
                x: Tensor::zeros(&[1, c, 64, 64]),
                a_raw: Tensor::zeros(&[1, 1, 3, 64, 64]),
                lam: Tensor::zeros(&[1, c, 64, 64]),
            },
            kchunk: 0,
            arrived,
            priority: Default::default(),
            deadline,
            tenant: 0,
            reply: tx,
        };
        (r, rx)
    }

    fn mk_batcher(max_batch: usize, wait_us: u64) -> Batcher {
        let mut b = Batcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
            queue_cap: 16,
            eager_idle: false,
        });
        b.register_bucket(bucket(8), vec![1, 2, 4]);
        b
    }

    #[test]
    fn young_queue_not_released() {
        let mut b = mk_batcher(4, 10_000);
        let now = Instant::now();
        let (r, _rx) = req(1, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        assert!(b.pop_batch(now).is_none());
    }

    #[test]
    fn aged_head_releases_partial_batch() {
        let mut b = mk_batcher(4, 1_000);
        let t0 = Instant::now();
        let (r, _rx) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let later = t0 + Duration::from_micros(2_000);
        let (bk, fused, reqs) = b.pop_batch(later).expect("aged release");
        assert_eq!(bk, bucket(8));
        assert_eq!(fused, 1);
        assert_eq!(reqs.len(), 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn full_queue_releases_immediately() {
        let mut b = mk_batcher(4, 1_000_000);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (_, fused, reqs) = b.pop_batch(now).expect("full release");
        assert_eq!(fused, 4);
        assert_eq!(reqs.len(), 4);
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn fused_size_is_largest_artifact_leq_queue() {
        let mut b = mk_batcher(8, 0); // release instantly
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        // 3 queued with artifacts {1,2,4} -> fuse 2, leave 1.
        let (_, fused, reqs) = b.pop_batch(now).unwrap();
        assert_eq!(fused, 2);
        assert_eq!(reqs.len(), 2);
        let (_, fused2, reqs2) = b.pop_batch(now).unwrap();
        assert_eq!(fused2, 1);
        assert_eq!(reqs2.len(), 1);
    }

    #[test]
    fn never_exceeds_max_batch() {
        let mut b = mk_batcher(2, 0);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..10 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        while let Some((_, _fused, reqs)) = b.pop_batch(now) {
            assert!(reqs.len() <= 2);
        }
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn round_robin_is_fair_across_buckets() {
        let mut b = mk_batcher(1, 0);
        b.register_bucket(bucket(16), vec![1]);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let c = if i % 2 == 0 { 8 } else { 16 };
            let (r, rx) = req(i, c, now);
            b.enqueue(bucket(c), r).expect("registered");
            rxs.push(rx);
        }
        let mut seen = Vec::new();
        while let Some((bk, _, _)) = b.pop_batch(now) {
            seen.push(bk.c);
        }
        // Strict alternation between the two buckets.
        assert_eq!(seen.len(), 4);
        assert_ne!(seen[0], seen[1]);
        assert_ne!(seen[1], seen[2]);
        assert_ne!(seen[2], seen[3]);
    }

    #[test]
    fn capacity_accounting() {
        let mut b = mk_batcher(4, 1000);
        assert!(b.has_capacity());
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..16 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        assert!(!b.has_capacity());
    }

    #[test]
    fn fifo_order_within_bucket() {
        let mut b = mk_batcher(2, 0);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (_, _, first) = b.pop_batch(now).unwrap();
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let (_, _, second) = b.pop_batch(now).unwrap();
        assert_eq!(second.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn unknown_bucket_enqueue_is_rejected() {
        let mut b = mk_batcher(4, 0);
        let now = Instant::now();
        // bucket(16) was never registered: the request comes back and
        // nothing is queued (previously this silently created a queue
        // that pop_batch served with a fabricated batch size of 1).
        let (r, _rx) = req(1, 16, now);
        let rejected = b.enqueue(bucket(16), r).unwrap_err();
        assert_eq!(rejected.id, 1);
        assert_eq!(b.queued(), 0);
        assert!(b.pop_eager().is_none());
        // After registration the same bucket is accepted.
        b.register_bucket(bucket(16), vec![1]);
        let (r, _rx2) = req(2, 16, now);
        b.enqueue(bucket(16), r).expect("registered now");
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn eager_min_holds_small_batches_until_sized() {
        let mut b = mk_batcher(4, 1_000_000);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        // Saturated-pool setting (min_len = max_batch): 3 of 4 queued
        // are held back by the eager path.
        assert!(b.pop_eager_min(4).is_none());
        assert_eq!(b.queued(), 3);
        // The 4th request fills the bucket: the sized eager pop fires
        // with the full fused batch.
        let (r, rx) = req(9, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        rxs.push(rx);
        let (_, fused, reqs) = b.pop_eager_min(4).expect("sized release");
        assert_eq!(fused, 4);
        assert_eq!(reqs.len(), 4);
        // An idle pool (min_len = 1) keeps releasing partials instantly.
        let (r, rx) = req(10, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        rxs.push(rx);
        let (_, fused, reqs) = b.pop_eager_min(1).expect("idle release");
        assert_eq!(fused, 1);
        assert_eq!(reqs.len(), 1);
    }

    #[test]
    fn eager_min_clamps_to_max_batch() {
        // A min_len larger than max_batch must not wedge full queues.
        let mut b = mk_batcher(2, 1_000_000);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..2 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (_, _, reqs) = b.pop_eager_min(100).expect("clamped release");
        assert_eq!(reqs.len(), 2);
    }

    #[test]
    fn aged_heads_release_regardless_of_min_len_via_pop_batch() {
        // The no-starvation invariant: pop_batch (the age path) ignores
        // eager sizing entirely.
        let mut b = mk_batcher(4, 1_000);
        let t0 = Instant::now();
        let (r, _rx) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let later = t0 + Duration::from_micros(2_000);
        assert!(b.pop_batch(later).is_some());
    }

    /// The stale-`now` regression: a caller that took `Instant::now()`
    /// *before* racing an enqueuer to the lock can hand the batcher a
    /// `now` earlier than a head's `arrived`. Every compare must
    /// saturate — not panic mid-poll (which poisoned the batcher mutex
    /// and bricked the server) — and eager pops must still release
    /// regardless of age.
    #[test]
    fn stale_now_never_panics_and_eager_still_releases() {
        let mut b = mk_batcher(4, 1_000);
        let now = Instant::now();
        let arrived_later = now + Duration::from_millis(50);
        let (r, _rx) = req(1, 8, arrived_later);
        b.enqueue(bucket(8), r).expect("registered");
        // Age path: a stale now reads as zero wait -> not aged, no panic.
        assert!(b.pop_batch(now).is_none());
        assert_eq!(b.queued(), 1);
        // Eager path ignores age outright — releases even though the
        // head "arrives" in the future relative to the wall clock (the
        // old now + max_wait shift quietly failed exactly here).
        let (_, fused, reqs) = b.pop_eager().expect("eager ignores age");
        assert_eq!((fused, reqs.len()), (1, 1));
        // And a stale now with queued heads keeps next_deadline sane.
        let (r, _rx2) = req(2, 8, arrived_later);
        b.enqueue(bucket(8), r).expect("registered");
        assert_eq!(b.next_deadline().unwrap(), arrived_later + Duration::from_micros(1_000));
    }

    #[test]
    fn next_deadline_tracks_oldest_head() {
        let mut b = mk_batcher(4, 5_000);
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        let (r, _rx) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let d = b.next_deadline().unwrap();
        assert_eq!(d, t0 + Duration::from_micros(5_000));
    }

    fn bucket_hw(i: usize) -> Bucket {
        // Distinct geometries, like the cpu backend's dynamic traffic.
        Bucket { c: 1 + i % 16, h: 8 + i / 16, w: 8, kchunk: 0, per_channel: false }
    }

    /// The scaling regression at the cpu backend's registration cap:
    /// with 1024 dynamic buckets registered, the poll paths walk only
    /// the non-empty index, and drained dynamic queues are pruned so
    /// registration state tracks live traffic instead of history.
    #[test]
    fn dynamic_buckets_index_and_prune_at_1024() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(1),
            queue_cap: 0,
            eager_idle: false,
        });
        for i in 0..1024 {
            b.register_bucket_dynamic(bucket_hw(i), vec![1, 2, 4]);
        }
        assert_eq!(b.bucket_count(), 1024);
        assert_eq!(b.nonempty_buckets(), 0);
        // All-idle polls are index-driven no-ops, not 1024-key scans.
        let t0 = Instant::now();
        assert!(b.pop_batch(t0).is_none());
        assert!(b.next_deadline().is_none());
        // Traffic lands in 3 of the 1024.
        let mut rxs = Vec::new();
        for (id, bi) in [(1u64, 5usize), (2, 700), (3, 1023), (4, 5), (5, 700), (6, 1023)] {
            let (r, rx) = mk_req_for(id, bucket_hw(bi), t0);
            b.enqueue(bucket_hw(bi), r).expect("registered");
            rxs.push(rx);
        }
        assert_eq!(b.nonempty_buckets(), 3);
        assert!(b.next_deadline().is_some());
        // Drain (heads aged): exactly the three active buckets release.
        let later = t0 + Duration::from_micros(10);
        let mut seen = Vec::new();
        while let Some((bk, _, reqs)) = b.pop_batch(later) {
            assert_eq!(reqs.len(), 2);
            seen.push(bk);
        }
        seen.sort();
        let mut want = vec![bucket_hw(5), bucket_hw(700), bucket_hw(1023)];
        want.sort();
        assert_eq!(seen, want);
        // Drained dynamic buckets are pruned: registration shrank and
        // the index is empty again.
        assert_eq!(b.nonempty_buckets(), 0);
        assert_eq!(b.bucket_count(), 1021);
        assert!(!b.known_bucket(&bucket_hw(5)));
        // Pruned geometries re-register cleanly on their next use.
        b.register_bucket_dynamic(bucket_hw(5), vec![1]);
        let (r, _rx) = mk_req_for(7, bucket_hw(5), t0);
        b.enqueue(bucket_hw(5), r).expect("re-registered");
        assert_eq!(b.queued(), 1);
    }

    /// Static (manifest) buckets are never pruned, drained or not.
    #[test]
    fn static_buckets_survive_draining() {
        let mut b = mk_batcher(4, 0);
        let now = Instant::now();
        let (r, _rx) = req(1, 8, now);
        b.enqueue(bucket(8), r).expect("registered");
        let (_, _, reqs) = b.pop_batch(now).expect("aged release");
        assert_eq!(reqs.len(), 1);
        assert!(b.known_bucket(&bucket(8)));
        assert_eq!(b.bucket_count(), 1);
        // And a re-registration as static un-marks a dynamic bucket.
        b.register_bucket_dynamic(bucket(16), vec![1]);
        b.register_bucket(bucket(16), vec![1]);
        let (r, _rx2) = req(2, 16, now);
        b.enqueue(bucket(16), r).expect("registered");
        b.pop_batch(now).expect("release");
        assert!(b.known_bucket(&bucket(16)), "static re-registration was pruned");
    }

    fn mk_req_for(id: u64, bk: Bucket, arrived: Instant) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let r = Request {
            id,
            payload: Payload::Scan {
                x: Tensor::zeros(&[1, bk.c, bk.h, bk.w]),
                a_raw: Tensor::zeros(&[1, 1, 3, bk.h, bk.w]),
                lam: Tensor::zeros(&[1, bk.c, bk.h, bk.w]),
            },
            kchunk: 0,
            arrived,
            priority: Default::default(),
            deadline: None,
            tenant: 0,
            reply: tx,
        };
        (r, rx)
    }

    /// Per-bucket eager sizing (the plan-cost hook): a closure can hold
    /// one bucket back for a full batch while releasing another's
    /// partials immediately.
    #[test]
    fn eager_by_sizes_per_bucket() {
        let mut b = mk_batcher(4, 1_000_000);
        b.register_bucket(bucket(16), vec![1, 2, 4]);
        let now = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..2 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        for i in 10..12 {
            let (r, rx) = req(i, 16, now);
            b.enqueue(bucket(16), r).expect("registered");
            rxs.push(rx);
        }
        // Hold the c8 bucket for a full batch, release c16 partials.
        let sized =
            |bk: &Bucket, _len: usize, _dl: Option<Instant>| if bk.c == 8 { 4 } else { 1 };
        let (bk, _, reqs) = b.pop_eager_by(sized).expect("c16 releases");
        assert_eq!(bk.c, 16);
        assert_eq!(reqs.len(), 2);
        assert!(b.pop_eager_by(sized).is_none(), "c8 held for a full batch");
        assert_eq!(b.queued(), 2);
        // Once full, the held bucket releases through the same closure.
        for i in 2..4 {
            let (r, rx) = req(i, 8, now);
            b.enqueue(bucket(8), r).expect("registered");
            rxs.push(rx);
        }
        let (bk, fused, reqs) = b.pop_eager_by(sized).expect("full c8");
        assert_eq!((bk.c, fused, reqs.len()), (8, 4, 4));
    }

    /// Earliest-deadline-first release: a later-arriving request with a
    /// tight deadline jumps ahead of an older deadline-less peer, and
    /// becomes releasable `max_wait` before its deadline.
    #[test]
    fn deadline_orders_release_ahead_of_age() {
        // Artifact sizes {1} so every pop releases exactly the head,
        // while max_batch 4 keeps the queue from counting as full.
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(1_000),
            queue_cap: 16,
            eager_idle: false,
        });
        b.register_bucket(bucket(8), vec![1]);
        let t0 = Instant::now();
        let (r1, _rx1) = req(1, 8, t0);
        b.enqueue(bucket(8), r1).expect("registered");
        // Arrives after r1 but must release first: deadline t0+1500µs
        // -> effective release t0+500µs, vs r1's aged t0+1000µs.
        let (r2, _rx2) = req_deadline(2, 8, t0, Some(t0 + Duration::from_micros(1_500)));
        b.enqueue(bucket(8), r2).expect("registered");
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_micros(500));
        // Before either release instant: nothing pops.
        assert!(b.pop_batch(t0 + Duration::from_micros(400)).is_none());
        // Past the deadlined head's release instant (but before its
        // deadline and before r1 ages): r2 releases first.
        let (_, _, reqs) = b.pop_batch(t0 + Duration::from_micros(600)).expect("EDF head");
        assert_eq!(reqs[0].id, 2);
        assert!(b.pop_batch(t0 + Duration::from_micros(600)).is_none(), "r1 not aged yet");
        let (_, _, reqs) = b.pop_batch(t0 + Duration::from_micros(1_100)).expect("aged");
        assert_eq!(reqs[0].id, 1);
        assert!(b.take_expired().is_empty(), "nothing expired in this run");
    }

    /// Expired requests are shed at pop time — never handed out as a
    /// batch — including ones already expired when they were enqueued.
    #[test]
    fn expired_requests_shed_at_pop_not_executed() {
        let mut b = mk_batcher(4, 1_000);
        let t0 = Instant::now();
        // Expired at enqueue (deadline == arrival).
        let (dead, _rx) = req_deadline(1, 8, t0, Some(t0));
        b.enqueue(bucket(8), dead).expect("registered");
        // A live peer in the same bucket.
        let (live, _rx2) = req(2, 8, t0);
        b.enqueue(bucket(8), live).expect("registered");
        assert_eq!(b.queued(), 2);
        let (_, _, reqs) = b.pop_batch(t0 + Duration::from_micros(2_000)).expect("live head");
        assert_eq!(reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        let shed = b.take_expired();
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 1);
        assert_eq!(b.queued(), 0);
        assert!(b.take_expired().is_empty(), "take_expired drains");
    }

    /// A dynamic bucket whose queue expires wholesale is pruned from the
    /// non-empty index *and* its registration, exactly like a drained
    /// one — expiry must not leave ghost index entries behind.
    #[test]
    fn all_expired_dynamic_bucket_pruned_from_index() {
        let mut b = mk_batcher(4, 1_000);
        b.register_bucket_dynamic(bucket(16), vec![1, 2, 4]);
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (r, rx) = req_deadline(i, 16, t0, Some(t0 + Duration::from_micros(10)));
            b.enqueue(bucket(16), r).expect("registered");
            rxs.push(rx);
        }
        assert_eq!(b.nonempty_buckets(), 1);
        // All three expired: the pop sheds them, finds the bucket empty,
        // prunes it, and returns None (nothing releasable).
        assert!(b.pop_batch(t0 + Duration::from_micros(50)).is_none());
        assert_eq!(b.take_expired().len(), 3);
        assert_eq!((b.queued(), b.nonempty_buckets()), (0, 0));
        assert!(!b.known_bucket(&bucket(16)), "expired-out dynamic bucket pruned");
        // Static buckets survive wholesale expiry (bucket(8) is static).
        let (r, _rx) = req_deadline(9, 8, t0, Some(t0 + Duration::from_micros(10)));
        b.enqueue(bucket(8), r).expect("registered");
        assert!(b.pop_batch(t0 + Duration::from_micros(50)).is_none());
        assert_eq!(b.take_expired().len(), 1);
        assert!(b.known_bucket(&bucket(8)));
    }

    /// `next_deadline` with mixed deadline/no-deadline heads: always the
    /// minimum effective release instant, and non-increasing as more
    /// urgent requests join (the stale-`now` regression family — a
    /// deadline in the past must yield a past instant, not a panic).
    #[test]
    fn next_deadline_mixed_heads_is_min_and_monotone() {
        let mut b = mk_batcher(4, 1_000);
        b.register_bucket(bucket(16), vec![1]);
        let t0 = Instant::now();
        let (r, _rx1) = req(1, 8, t0);
        b.enqueue(bucket(8), r).expect("registered");
        let d1 = b.next_deadline().unwrap();
        assert_eq!(d1, t0 + Duration::from_micros(1_000));
        // A deadlined head in another bucket pulls the minimum down.
        let (r, _rx2) = req_deadline(2, 16, t0, Some(t0 + Duration::from_micros(1_400)));
        b.enqueue(bucket(16), r).expect("registered");
        let d2 = b.next_deadline().unwrap();
        assert_eq!(d2, t0 + Duration::from_micros(400));
        assert!(d2 <= d1, "next_deadline must be non-increasing as urgency joins");
        // An even tighter deadline (already releasable — effective
        // instant at or before arrival) pulls it past t0, no panic.
        let (r, _rx3) = req_deadline(3, 8, t0, Some(t0 + Duration::from_micros(500)));
        b.enqueue(bucket(8), r).expect("registered");
        let d3 = b.next_deadline().unwrap();
        assert!(d3 <= t0, "tight deadline clamps to arrival");
        assert!(d3 <= d2);
        // A later, deadline-less arrival must not move it at all.
        let (r, _rx4) = req(4, 16, t0 + Duration::from_micros(300));
        b.enqueue(bucket(16), r).expect("registered");
        assert_eq!(b.next_deadline().unwrap(), d3);
    }
}
